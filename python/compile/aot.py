"""AOT export: lower the JAX/Pallas model to HLO text artifacts.

Run once by ``make artifacts``; Python never runs on the serving path.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ``artifacts/``):

- ``model_meta.json``         — the shape contract consumed by
                                ``rust/src/runtime/mod.rs``
- ``init.hlo.txt``            — () -> weights tuple
- ``generate_{L}.hlo.txt``    — one per prefill bucket L

Usage: ``python -m compile.aot [--out-dir DIR] [--tiny]``
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelConfig, TINY, make_generate_fn, make_init_fn


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_init(cfg: ModelConfig) -> str:
    return to_hlo_text(jax.jit(make_init_fn(cfg)).lower())


def lower_generate(cfg: ModelConfig, bucket: int) -> str:
    fn = make_generate_fn(cfg)
    weight_specs = [
        jax.ShapeDtypeStruct(w.shape, w.dtype) for w in jax.eval_shape(make_init_fn(cfg))
    ]
    args = weight_specs + [
        jax.ShapeDtypeStruct((bucket,), jnp.int32),  # tokens
        jax.ShapeDtypeStruct((), jnp.int32),  # length
        jax.ShapeDtypeStruct((), jnp.int32),  # max_new
        jax.ShapeDtypeStruct((), jnp.int32),  # stop_id
    ]
    return to_hlo_text(jax.jit(fn).lower(*args))


def write_meta(cfg: ModelConfig, out_dir: str) -> None:
    meta = {
        "vocab_size": cfg.vocab_size,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "ffn": cfg.ffn,
        "max_new": cfg.max_new,
        "seed": cfg.seed,
        "buckets": list(cfg.buckets),
    }
    with open(os.path.join(out_dir, "model_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)


def export(cfg: ModelConfig, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    write_meta(cfg, out_dir)

    t = time.time()
    path = os.path.join(out_dir, "init.hlo.txt")
    text = lower_init(cfg)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text) / 1e6:.2f} MB, {time.time() - t:.1f}s)")

    for bucket in cfg.buckets:
        t = time.time()
        path = os.path.join(out_dir, f"generate_{bucket}.hlo.txt")
        text = lower_generate(cfg, bucket)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text) / 1e6:.2f} MB, {time.time() - t:.1f}s)")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    p.add_argument(
        "--tiny",
        action="store_true",
        help="export the test-scale model instead of the serving model",
    )
    args = p.parse_args(argv)
    cfg = TINY if args.tiny else ModelConfig()
    export(cfg, args.out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
