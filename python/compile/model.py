"""Layer-2 JAX model: a Qwen-style decoder-only transformer.

Stands in for the paper's Qwen1.5-0.5B-Chat running under llama.cpp (the
paper measures context management, not model quality — §4.2: "we focus not
on the model's output"). Architecture mirrors the Qwen/Llama family at
reproduction scale: RMSNorm, rotary position embeddings, SwiGLU MLP,
multi-head attention with a KV cache. Weights are deterministic random
(seed 123, the paper's seed); generation is greedy (temperature 0).

The attention hot-spot calls the Layer-1 Pallas kernels
(``kernels.attention``). Entry points, all AOT-lowered by ``aot.py``:

``init_weights``      -> the weights tuple (run once at node startup)
``prefill``           -> context pass; fills the KV cache
``decode_step``       -> one cached decode step
``generate``          -> full turn: prefill + greedy while-loop decode,
                         KV cache never leaves the device

Static-shape contract (mirrored in ``rust/src/runtime``): contexts are
padded to bucket sizes and masked by true ``length``; the KV cache holds
``bucket + max_new`` slots.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attend, flash_prefill


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters; the values here are the artifact contract."""

    vocab_size: int = 4096
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    head_dim: int = 32
    ffn: int = 352
    max_new: int = 128
    rope_base: float = 10000.0
    seed: int = 123
    buckets: tuple = (128, 256, 512, 1024, 2048)

    @property
    def qkv_dim(self):
        return self.n_heads * self.head_dim

    def weights_per_layer(self):
        return 9  # ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down

    def n_weights(self):
        return 1 + self.n_layers * self.weights_per_layer() + 2  # embed .. final_ln, lm_head


# Test-scale config: one layer, small dims (keeps pytest fast).
TINY = ModelConfig(
    vocab_size=64,
    d_model=16,
    n_layers=1,
    n_heads=2,
    head_dim=8,
    ffn=32,
    max_new=8,
    buckets=(16, 32),
)


def init_weights(cfg: ModelConfig):
    """Deterministic weight tuple (flat, fixed order)."""
    key = jax.random.PRNGKey(cfg.seed)
    scale = 0.02
    ws = []
    key, k = jax.random.split(key)
    ws.append(jax.random.normal(k, (cfg.vocab_size, cfg.d_model)) * scale)  # embed
    for _ in range(cfg.n_layers):
        key, k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 8)
        ws.append(jnp.ones((cfg.d_model,)))  # ln1
        ws.append(jax.random.normal(k1, (cfg.d_model, cfg.qkv_dim)) * scale)  # wq
        ws.append(jax.random.normal(k2, (cfg.d_model, cfg.qkv_dim)) * scale)  # wk
        ws.append(jax.random.normal(k3, (cfg.d_model, cfg.qkv_dim)) * scale)  # wv
        ws.append(jax.random.normal(k4, (cfg.qkv_dim, cfg.d_model)) * scale)  # wo
        ws.append(jnp.ones((cfg.d_model,)))  # ln2
        ws.append(jax.random.normal(k5, (cfg.d_model, cfg.ffn)) * scale)  # w_gate
        ws.append(jax.random.normal(k6, (cfg.d_model, cfg.ffn)) * scale)  # w_up
        ws.append(jax.random.normal(k7, (cfg.ffn, cfg.d_model)) * scale)  # w_down
    key, k1, k2 = jax.random.split(key, 3)
    ws.append(jnp.ones((cfg.d_model,)))  # final_ln
    ws.append(jax.random.normal(k2, (cfg.d_model, cfg.vocab_size)) * scale)  # lm_head
    return tuple(ws)


def _layer_weights(cfg: ModelConfig, weights, layer: int):
    base = 1 + layer * cfg.weights_per_layer()
    (ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down) = weights[
        base : base + cfg.weights_per_layer()
    ]
    return ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down


def rmsnorm(x, w, eps=1e-6):
    """Root-mean-square layer norm over the last axis."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: (silu(x @ Wg) * (x @ Wu)) @ Wd."""
    g = x @ w_gate
    return (jax.nn.silu(g) * (x @ w_up)) @ w_down


def rope(x, positions, base: float):
    """Rotary embedding. x: [..., H, D]; positions broadcastable to x's
    leading axes."""
    d = x.shape[-1]
    half = d // 2
    inv_freq = 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [..., half]
    cos = jnp.cos(angles)[..., None, :]  # [..., 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def prefill(cfg: ModelConfig, weights, tokens, length):
    """Context pass over padded ``tokens`` [L].

    Returns ``(k_cache, v_cache, last_logits)`` with caches
    [n_layers, L + max_new, H, D]; rows past ``length`` are garbage and
    masked out by every later attention (decode masks by current length;
    prefill is causal and only the ``length-1`` logit row is used).
    """
    l = tokens.shape[0]
    cl = l + cfg.max_new
    embed, final_ln, lm_head = weights[0], weights[-2], weights[-1]
    x = embed[tokens]  # [L, d]
    positions = jnp.arange(l)
    k_caches, v_caches = [], []
    for layer in range(cfg.n_layers):
        ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down = _layer_weights(cfg, weights, layer)
        h = rmsnorm(x, ln1)
        q = (h @ wq).reshape(l, cfg.n_heads, cfg.head_dim)
        k = (h @ wk).reshape(l, cfg.n_heads, cfg.head_dim)
        v = (h @ wv).reshape(l, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_base)
        k = rope(k, positions, cfg.rope_base)
        attn = flash_prefill(q, k, v)  # L1 Pallas kernel
        x = x + attn.reshape(l, cfg.qkv_dim) @ wo
        x = x + swiglu(rmsnorm(x, ln2), w_gate, w_up, w_down)
        pad = ((0, cl - l), (0, 0), (0, 0))
        k_caches.append(jnp.pad(k, pad))
        v_caches.append(jnp.pad(v, pad))
    x_last = jax.lax.dynamic_index_in_dim(x, length - 1, axis=0, keepdims=False)
    logits = rmsnorm(x_last, final_ln) @ lm_head  # [V]
    return jnp.stack(k_caches), jnp.stack(v_caches), logits


def decode_step(cfg: ModelConfig, weights, k_cache, v_cache, token, pos):
    """One decode step for ``token`` at position ``pos``.

    Writes the token's K/V into cache slot ``pos`` and attends over slots
    ``[0, pos]``. Returns updated caches and the next-token logits.
    """
    embed, final_ln, lm_head = weights[0], weights[-2], weights[-1]
    x = embed[token]  # [d]
    pos_arr = jnp.asarray(pos, dtype=jnp.int32)
    for layer in range(cfg.n_layers):
        ln1, wq, wk, wv, wo, ln2, w_gate, w_up, w_down = _layer_weights(cfg, weights, layer)
        h = rmsnorm(x, ln1)
        q = (h @ wq).reshape(cfg.n_heads, cfg.head_dim)
        k = (h @ wk).reshape(cfg.n_heads, cfg.head_dim)
        v = (h @ wv).reshape(cfg.n_heads, cfg.head_dim)
        q = rope(q, pos_arr, cfg.rope_base)
        k = rope(k, pos_arr, cfg.rope_base)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None, None], (layer, pos_arr, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None, None], (layer, pos_arr, 0, 0)
        )
        attn = decode_attend(q, k_cache[layer], v_cache[layer], pos_arr + 1)  # L1 kernel
        x = x + attn.reshape(cfg.qkv_dim) @ wo
        x = x + swiglu(rmsnorm(x, ln2), w_gate, w_up, w_down)
    logits = rmsnorm(x, final_ln) @ lm_head
    return k_cache, v_cache, logits


def generate(cfg: ModelConfig, weights, tokens, length, max_new, stop_id):
    """Full turn: prefill + greedy decode loop, all on device.

    Returns ``(out_ids [cfg.max_new], n_generated)``; ids past
    ``n_generated`` are zero. Decoding stops early when the model emits
    ``stop_id`` (not included in the output) or after ``max_new`` tokens.
    """
    k_cache, v_cache, logits = prefill(cfg, weights, tokens, length)
    first = jnp.argmax(logits).astype(jnp.int32)
    out0 = jnp.zeros((cfg.max_new,), dtype=jnp.int32)
    limit = jnp.minimum(max_new, cfg.max_new).astype(jnp.int32)

    def cond(carry):
        _, _, _, cur, i, done = carry
        return jnp.logical_and(i < limit, jnp.logical_not(done))

    def body(carry):
        k_cache, v_cache, out, cur, i, _ = carry
        out = jax.lax.dynamic_update_slice(out, cur[None], (i,))
        k_cache, v_cache, logits = decode_step(
            cfg, weights, k_cache, v_cache, cur, length + i
        )
        nxt = jnp.argmax(logits).astype(jnp.int32)
        done = nxt == stop_id
        return k_cache, v_cache, out, nxt, i + 1, done

    init = (k_cache, v_cache, out0, first, jnp.int32(0), first == stop_id)
    _, _, out, _, n, _ = jax.lax.while_loop(cond, body, init)
    return out, n


def generate_ref(cfg: ModelConfig, weights, tokens, length, max_new, stop_id):
    """Reference generation that re-runs ``decode_step`` eagerly in Python
    (no while_loop) — used by tests to pin down ``generate``."""
    k_cache, v_cache, logits = prefill(cfg, weights, tokens, length)
    cur = int(jnp.argmax(logits))
    out = []
    for i in range(int(max_new)):
        if cur == stop_id:
            break
        out.append(cur)
        k_cache, v_cache, logits = decode_step(
            cfg, weights, k_cache, v_cache, jnp.int32(cur), jnp.int32(length + i)
        )
        cur = int(jnp.argmax(logits))
    return out


def make_generate_fn(cfg: ModelConfig):
    """The AOT entry point: flat positional signature
    ``(w_0..w_{n-1}, tokens, length, max_new, stop_id)``."""
    n = cfg.n_weights()

    def fn(*args):
        weights = args[:n]
        tokens, length, max_new, stop_id = args[n:]
        out, count = generate(cfg, weights, tokens, length, max_new, stop_id)
        return out, count

    return fn


def make_init_fn(cfg: ModelConfig):
    """AOT entry point producing the weights tuple."""

    def fn():
        return init_weights(cfg)

    return fn


@functools.lru_cache(maxsize=4)
def cached_weights(cfg: ModelConfig):
    """Memoized weights for tests."""
    return init_weights(cfg)
