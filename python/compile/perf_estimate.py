"""Analytic TPU performance estimates for the L1 Pallas kernels.

``interpret=True`` gives CPU-numpy semantics only, so real-TPU efficiency
is *estimated* from the kernel structure (DESIGN.md §Perf): VMEM
footprints from the BlockSpecs, MXU utilization from the contraction
shapes, and an HBM-bandwidth roofline for the bandwidth-bound decode
kernel. Reference chip: TPU v4 lite-ish numbers (275 TFLOP/s bf16 MXU,
1.2 TB/s HBM, 16 MiB VMEM/core) — the point is the *ratio* analysis, not
absolute TFLOPs.

Run: ``python -m compile.perf_estimate``
"""

import dataclasses

from .kernels.attention import BLOCK_K, BLOCK_Q, vmem_bytes_decode, vmem_bytes_prefill
from .model import ModelConfig

VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128  # systolic array edge
HBM_BPS = 1.2e12
MXU_FLOPS = 275e12  # bf16


@dataclasses.dataclass
class KernelEstimate:
    """Static performance model of one kernel launch."""

    name: str
    vmem_bytes: int
    flops: float
    hbm_bytes: float
    mxu_utilization: float  # fraction of MXU lanes busy during matmuls

    @property
    def vmem_ok(self):
        return self.vmem_bytes < VMEM_BYTES

    @property
    def compute_s(self):
        return self.flops / (MXU_FLOPS * max(self.mxu_utilization, 1e-9))

    @property
    def memory_s(self):
        return self.hbm_bytes / HBM_BPS

    @property
    def bound(self):
        return "compute" if self.compute_s > self.memory_s else "memory"

    @property
    def roofline_efficiency(self):
        """Achievable fraction of the MXU peak given the memory roofline."""
        t = max(self.compute_s, self.memory_s)
        return (self.flops / MXU_FLOPS) / t if t > 0 else 0.0


def mxu_util(m: int, n: int, k: int) -> float:
    """Lane occupancy of an (m×k)@(k×n) contraction on a 128×128 MXU."""

    def occ(d):
        full, rem = divmod(d, MXU_DIM)
        tiles = full + (1 if rem else 0)
        return d / (tiles * MXU_DIM) if tiles else 0.0

    return occ(m) * occ(n)


def prefill_estimate(cfg: ModelConfig, l: int) -> KernelEstimate:
    """One (head, q-tile) flash-prefill program, aggregated over the grid."""
    d = cfg.head_dim
    bq, bk = min(BLOCK_Q, l), min(BLOCK_K, l)
    n_q_tiles = l // bq
    # Causal: tile t sees t+1 KV tiles.
    kv_tiles_total = n_q_tiles * (n_q_tiles + 1) // 2
    # Per (q-tile, kv-tile): QK^T (bq×d @ d×bk) + PV (bq×bk @ bk×d).
    flops = cfg.n_heads * kv_tiles_total * (2 * bq * bk * d + 2 * bq * bk * d)
    # HBM: Q,K,V read once per head (K/V panels resident per program), O written.
    hbm = 4 * (3 * l * cfg.n_heads * d + l * cfg.n_heads * d)
    return KernelEstimate(
        name=f"flash_prefill L={l}",
        vmem_bytes=vmem_bytes_prefill(l, d),
        flops=flops,
        hbm_bytes=hbm,
        # Contractions are (bq×d)@(d×bk): m=bq=128 n=bk=128 full lanes,
        # but k=d=32 pipelines at depth 32/128 on the systolic array.
        mxu_utilization=mxu_util(bq, bk, d) * (d / MXU_DIM),
    )


def decode_estimate(cfg: ModelConfig, cache_len: int) -> KernelEstimate:
    """One decode_attend launch (all heads)."""
    d = cfg.head_dim
    # scores: CL×d @ d×1; out: 1×CL @ CL×d  per head.
    flops = cfg.n_heads * (2 * cache_len * d + 2 * cache_len * d)
    hbm = 4 * cfg.n_heads * (2 * cache_len * d + d + d)
    return KernelEstimate(
        name=f"decode_attend CL={cache_len}",
        vmem_bytes=vmem_bytes_decode(cache_len, d),
        flops=flops,
        hbm_bytes=hbm,
        # Matrix-vector: one output column -> 1/128 of MXU width; on real
        # TPU this runs on the VPU instead, which is the right choice for
        # a memory-bound kernel.
        mxu_utilization=mxu_util(cache_len, 1, d),
    )


def report(cfg: ModelConfig = None) -> str:
    cfg = cfg or ModelConfig()
    lines = [
        f"kernel                     VMEM      fit  bound    roofline-eff",
    ]
    for l in cfg.buckets:
        e = prefill_estimate(cfg, l)
        lines.append(
            f"{e.name:<24} {e.vmem_bytes/2**20:7.2f}MiB  {str(e.vmem_ok):<5}"
            f"{e.bound:<8} {e.roofline_efficiency*100:6.1f}%"
        )
    for cl in [cfg.buckets[0] + cfg.max_new, cfg.buckets[-1] + cfg.max_new]:
        e = decode_estimate(cfg, cl)
        lines.append(
            f"{e.name:<24} {e.vmem_bytes/2**20:7.2f}MiB  {str(e.vmem_ok):<5}"
            f"{e.bound:<8} {e.roofline_efficiency*100:6.1f}% (memory-bound by design)"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
