"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Everything here is deliberately naive: full score matrices, no tiling, no
numerical tricks beyond the standard max-subtraction softmax. The pytest
suite asserts the Pallas kernels match these to tight tolerances across
shape/dtype sweeps.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def attention_prefill_ref(q, k, v):
    """Causal attention, full-matrix reference. q, k, v: [L, H, D]."""
    l, h, d = q.shape
    scale = 1.0 / (d**0.5)
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale  # [H, L, L]
    qpos = jnp.arange(l)[None, :, None]
    kpos = jnp.arange(l)[None, None, :]
    scores = jnp.where(kpos <= qpos, scores, NEG_INF)
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / jnp.maximum(weights.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hqk,khd->qhd", weights, v)


def attention_decode_ref(q, k_cache, v_cache, cur_len):
    """Single-query attention over a masked cache.

    q: [H, D]; caches: [CL, H, D]; cur_len: scalar count of valid slots.
    """
    cl, h, d = k_cache.shape
    scale = 1.0 / (d**0.5)
    scores = jnp.einsum("hd,khd->hk", q, k_cache) * scale  # [H, CL]
    mask = jnp.arange(cl)[None, :] < cur_len
    scores = jnp.where(mask, scores, NEG_INF)
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / jnp.maximum(weights.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hk,khd->hd", weights, v_cache)
