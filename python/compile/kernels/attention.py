"""Layer-1 Pallas attention kernels.

The paper's LLM service (llama.cpp) runs its attention in hand-written
C/C++/Metal kernels; here the same hot-spot is expressed as Pallas kernels
designed TPU-first and executed in ``interpret=True`` mode so they lower to
plain HLO runnable on the CPU PJRT client (real-TPU lowering would emit a
Mosaic custom-call the CPU plugin cannot execute; see DESIGN.md
§Hardware-Adaptation).

Two kernels cover the serving pipeline:

``flash_prefill``
    Causal attention over the (padded) context. Grid is ``(heads,
    L // BLOCK_Q)``; each program holds one query tile plus that head's
    full K/V panels in VMEM and runs an online-softmax (flash) recurrence
    over K/V tiles — scores never materialize beyond one
    ``BLOCK_Q x BLOCK_K`` tile. On TPU the ``q_tile @ k_tile.T``
    contraction maps onto the MXU; tiles are multiples of the 8x128
    vector-lane shape.

``decode_attend``
    Single-query attention against the KV cache, masked by the true cache
    length. Grid is ``(heads,)``; one cache panel per head stays in VMEM
    (cache_len x head_dim f32 = 2176 x 32 x 4B = 278 KiB, comfortably
    under the ~16 MiB VMEM budget).

Both are checked against the pure-jnp oracle in ``ref.py`` by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes and dtypes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Query/KV tile sizes for the prefill kernel. 128 matches the TPU lane
# width; smaller contexts fall back to a single tile.
BLOCK_Q = 128
BLOCK_K = 128


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    """One (head, q-tile) program of causal flash attention.

    q_ref: [BQ, 1, D]   this head's query tile
    k_ref: [L, 1, D]    this head's full key panel
    v_ref: [L, 1, D]    this head's full value panel
    o_ref: [BQ, 1, D]   output tile
    """
    bq, _, d = q_ref.shape
    q_tile_idx = pl.program_id(1)
    q_pos = q_tile_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    q = q_ref[:, 0, :] * (1.0 / (d**0.5))

    def body(kt, carry):
        m_prev, l_prev, acc = carry
        k_tile = k_ref[pl.ds(kt * block_k, block_k), 0, :]
        v_tile = v_ref[pl.ds(kt * block_k, block_k), 0, :]
        s = q @ k_tile.T  # [BQ, BK] -> MXU contraction on TPU
        k_pos = kt * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)  # causal mask
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + p @ v_tile
        return m_new, l_new, acc

    m0 = jnp.full((bq, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)
    # Causality: query tile t only needs KV tiles 0..t (bq == bk).
    n_k_tiles = q_tile_idx + 1
    m, l_sum, acc = jax.lax.fori_loop(0, n_k_tiles, body, (m0, l0, acc0))
    o_ref[:, 0, :] = acc / jnp.maximum(l_sum, 1e-30)


def flash_prefill(q, k, v):
    """Causal attention. q, k, v: [L, H, D] -> [L, H, D].

    L must be a multiple of BLOCK_Q (the AOT pipeline pads contexts to
    bucket sizes that are).
    """
    l, h, d = q.shape
    bq = min(BLOCK_Q, l)
    bk = min(BLOCK_K, l)
    assert l % bq == 0, f"L={l} not a multiple of the query tile {bq}"
    assert bq == bk, "causal tile skipping assumes bq == bk"
    grid = (h, l // bq)
    return pl.pallas_call(
        functools.partial(_prefill_kernel, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1, d), lambda hh, i: (i, hh, 0)),
            pl.BlockSpec((l, 1, d), lambda hh, i: (0, hh, 0)),
            pl.BlockSpec((l, 1, d), lambda hh, i: (0, hh, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, d), lambda hh, i: (i, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((l, h, d), q.dtype),
        interpret=True,
    )(
        q, k, v
    )


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref):
    """One head's single-query attention over the cache.

    len_ref: [1, 1]     number of valid cache slots (positions < len attend)
    q_ref:   [1, 1, D]
    k_ref:   [CL, 1, D]
    v_ref:   [CL, 1, D]
    o_ref:   [1, 1, D]
    """
    cl, _, d = k_ref.shape
    cur_len = len_ref[0, 0]
    q = q_ref[:, 0, :] * (1.0 / (d**0.5))  # [1, D]
    s = (k_ref[:, 0, :] @ q.T).T  # [1, CL]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, cl), 1)
    s = jnp.where(pos < cur_len, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    o_ref[:, 0, :] = (p @ v_ref[:, 0, :]) / denom


def decode_attend(q, k_cache, v_cache, cur_len):
    """Single-token attention. q: [H, D]; caches: [CL, H, D]; cur_len:
    scalar i32 count of valid slots. Returns [H, D]."""
    h, d = q.shape
    cl = k_cache.shape[0]
    len_arr = jnp.reshape(cur_len.astype(jnp.int32), (1, 1))
    out = pl.pallas_call(
        _decode_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda hh: (0, 0)),
            pl.BlockSpec((1, 1, d), lambda hh: (0, hh, 0)),
            pl.BlockSpec((cl, 1, d), lambda hh: (0, hh, 0)),
            pl.BlockSpec((cl, 1, d), lambda hh: (0, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda hh: (0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((1, h, d), q.dtype),
        interpret=True,
    )(len_arr, q[None, :, :], k_cache, v_cache)
    return out[0]


def vmem_bytes_prefill(l: int, d: int) -> int:
    """Analytic VMEM footprint of one prefill program (perf estimate)."""
    bq = min(BLOCK_Q, l)
    bk = min(BLOCK_K, l)
    # q tile + K panel + V panel + score tile + softmax stats + acc
    return 4 * (bq * d + 2 * l * d + bq * bk + 2 * bq + bq * d)


def vmem_bytes_decode(cl: int, d: int) -> int:
    """Analytic VMEM footprint of one decode program."""
    return 4 * (d + 2 * cl * d + cl + d)
