"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/mask lengths; every case asserts
``assert_allclose`` against ``kernels/ref.py``. This is the core numeric
signal the AOT pipeline builds on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    BLOCK_Q,
    decode_attend,
    flash_prefill,
    vmem_bytes_decode,
    vmem_bytes_prefill,
)
from compile.kernels.ref import attention_decode_ref, attention_prefill_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------- prefill

@pytest.mark.parametrize("l", [1, 2, 8, 64, 128, 256, 384])
@pytest.mark.parametrize("h,d", [(1, 8), (4, 32)])
def test_prefill_matches_ref_shapes(l, h, d):
    if l > BLOCK_Q and l % BLOCK_Q != 0:
        pytest.skip("bucketed lengths only")
    key = jax.random.PRNGKey(l * 1000 + h * 10 + d)
    kq, kk, kv = jax.random.split(key, 3)
    q, k, v = rand(kq, (l, h, d)), rand(kk, (l, h, d)), rand(kv, (l, h, d))
    out = flash_prefill(q, k, v)
    ref = attention_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_prefill_is_causal():
    # Changing a future token must not change earlier outputs.
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    l, h, d = 32, 2, 16
    q, k, v = rand(kq, (l, h, d)), rand(kk, (l, h, d)), rand(kv, (l, h, d))
    base = flash_prefill(q, k, v)
    k2 = k.at[-1].set(99.0)
    v2 = v.at[-1].set(-99.0)
    pert = flash_prefill(q, k2, v2)
    np.testing.assert_allclose(base[: l - 1], pert[: l - 1], rtol=1e-6, atol=1e-6)


def test_prefill_softmax_stability_large_logits():
    # Online softmax must survive large score magnitudes.
    key = jax.random.PRNGKey(9)
    kq, kk, kv = jax.random.split(key, 3)
    l, h, d = 64, 2, 8
    q = rand(kq, (l, h, d), scale=30.0)
    k = rand(kk, (l, h, d), scale=30.0)
    v = rand(kv, (l, h, d))
    out = flash_prefill(q, k, v)
    ref = attention_prefill_ref(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    l_pow=st.integers(min_value=0, max_value=7),
    h=st.integers(min_value=1, max_value=4),
    d_pow=st.integers(min_value=3, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_prefill_hypothesis_sweep(l_pow, h, d_pow, seed):
    l, d = 2**l_pow, 2**d_pow
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q, k, v = rand(kq, (l, h, d)), rand(kk, (l, h, d)), rand(kv, (l, h, d))
    out = flash_prefill(q, k, v)
    ref = attention_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


# ----------------------------------------------------------------- decode

@pytest.mark.parametrize("cl,cur", [(8, 1), (8, 8), (144, 1), (144, 100), (2176, 1500)])
@pytest.mark.parametrize("h,d", [(1, 8), (4, 32)])
def test_decode_matches_ref(cl, cur, h, d):
    key = jax.random.PRNGKey(cl + cur)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (h, d))
    kc, vc = rand(kk, (cl, h, d)), rand(kv, (cl, h, d))
    out = decode_attend(q, kc, vc, jnp.int32(cur))
    ref = attention_decode_ref(q, kc, vc, cur)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_decode_ignores_garbage_beyond_len():
    # Slots >= cur_len must not affect the output at all — the property
    # the padded-cache design depends on.
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    cl, h, d, cur = 64, 2, 16, 20
    q = rand(kq, (h, d))
    kc, vc = rand(kk, (cl, h, d)), rand(kv, (cl, h, d))
    base = decode_attend(q, kc, vc, jnp.int32(cur))
    kc2 = kc.at[cur:].set(1e6)
    vc2 = vc.at[cur:].set(-1e6)
    pert = decode_attend(q, kc2, vc2, jnp.int32(cur))
    np.testing.assert_allclose(base, pert, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    cl=st.integers(min_value=1, max_value=300),
    frac=st.floats(min_value=0.01, max_value=1.0),
    h=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_decode_hypothesis_sweep(cl, frac, h, seed):
    d = 16
    cur = max(1, int(cl * frac))
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (h, d))
    kc, vc = rand(kk, (cl, h, d)), rand(kv, (cl, h, d))
    out = decode_attend(q, kc, vc, jnp.int32(cur))
    ref = attention_decode_ref(q, kc, vc, cur)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_decode_single_valid_slot_is_value_passthrough():
    # cur_len=1: softmax over one slot -> output == v[0].
    key = jax.random.PRNGKey(11)
    kq, kk, kv = jax.random.split(key, 3)
    cl, h, d = 16, 2, 8
    q = rand(kq, (h, d))
    kc, vc = rand(kk, (cl, h, d)), rand(kv, (cl, h, d))
    out = decode_attend(q, kc, vc, jnp.int32(1))
    np.testing.assert_allclose(out, vc[0], rtol=1e-6, atol=1e-6)


# ------------------------------------------------------- perf estimators

def test_vmem_estimates_under_budget():
    # The serving shapes must fit a TPU core's ~16 MiB VMEM.
    vmem = 16 * 1024 * 1024
    assert vmem_bytes_prefill(2048, 32) < vmem
    assert vmem_bytes_decode(2048 + 128, 32) < vmem


def test_jit_composes():
    # Kernels must lower inside jit (the AOT path does exactly this).
    l, h, d = 16, 2, 8
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q, k, v = rand(kq, (l, h, d)), rand(kk, (l, h, d)), rand(kv, (l, h, d))
    jitted = jax.jit(flash_prefill)
    np.testing.assert_allclose(jitted(q, k, v), flash_prefill(q, k, v), rtol=1e-6)
