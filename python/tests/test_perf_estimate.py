"""Sanity checks for the analytic TPU performance model (§Perf)."""

from compile.model import ModelConfig
from compile.perf_estimate import (
    decode_estimate,
    mxu_util,
    prefill_estimate,
    report,
    VMEM_BYTES,
)


def test_all_serving_shapes_fit_vmem():
    cfg = ModelConfig()
    for l in cfg.buckets:
        assert prefill_estimate(cfg, l).vmem_bytes < VMEM_BYTES
    assert decode_estimate(cfg, cfg.buckets[-1] + cfg.max_new).vmem_bytes < VMEM_BYTES


def test_mxu_util_bounds():
    assert mxu_util(128, 128, 32) == 1.0
    assert 0.0 < mxu_util(100, 128, 32) < 1.0
    assert mxu_util(1, 1, 32) < 0.01


def test_decode_is_memory_bound():
    cfg = ModelConfig()
    e = decode_estimate(cfg, 2176)
    assert e.bound == "memory"
    assert e.memory_s > 0


def test_prefill_efficiency_grows_then_saturates():
    cfg = ModelConfig()
    effs = [prefill_estimate(cfg, l).roofline_efficiency for l in cfg.buckets]
    assert effs[0] <= effs[-1] + 1e-9
    # Saturation: limited by head_dim / MXU depth = 32/128 = 25%.
    assert abs(effs[-1] - 0.25) < 0.02


def test_report_renders():
    r = report()
    assert "flash_prefill L=2048" in r
    assert "decode_attend" in r
