"""Layer-2 correctness: transformer shapes, prefill/decode agreement, and
the fused ``generate`` against its eager reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.TINY
STOP = CFG.vocab_size - 1


def weights():
    return M.cached_weights(CFG)


def rand_tokens(seed, l):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (l,), 0, CFG.vocab_size, dtype=jnp.int32
    )


def test_weight_inventory():
    w = weights()
    assert len(w) == CFG.n_weights()
    assert w[0].shape == (CFG.vocab_size, CFG.d_model)
    assert w[-1].shape == (CFG.d_model, CFG.vocab_size)


def test_weights_deterministic():
    a = M.init_weights(CFG)
    b = M.init_weights(CFG)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_prefill_shapes():
    l = CFG.buckets[0]
    kc, vc, logits = M.prefill(CFG, weights(), rand_tokens(0, l), jnp.int32(l - 3))
    cl = l + CFG.max_new
    assert kc.shape == (CFG.n_layers, cl, CFG.n_heads, CFG.head_dim)
    assert vc.shape == kc.shape
    assert logits.shape == (CFG.vocab_size,)
    assert np.isfinite(np.asarray(logits)).all()


def test_padding_does_not_change_logits():
    # The static-shape contract: padding tokens beyond `length` must not
    # affect the last-valid-position logits.
    l = CFG.buckets[0]
    length = l - 5
    t1 = rand_tokens(1, l)
    t2 = t1.at[length:].set(7)  # different garbage in the pad region
    _, _, lg1 = M.prefill(CFG, weights(), t1, jnp.int32(length))
    _, _, lg2 = M.prefill(CFG, weights(), t2, jnp.int32(length))
    np.testing.assert_allclose(lg1, lg2, rtol=1e-6, atol=1e-6)


def test_prefill_decode_agree():
    # Next-token logits from (prefill of n+1 tokens) must equal
    # (prefill of n tokens, then one decode_step).
    l = CFG.buckets[0]
    length = l - 4
    tokens = rand_tokens(2, l)
    kc, vc, lg = M.prefill(CFG, weights(), tokens, jnp.int32(length))
    nxt = jnp.argmax(lg).astype(jnp.int32)

    extended = tokens.at[length].set(nxt)
    _, _, lg_prefill = M.prefill(CFG, weights(), extended, jnp.int32(length + 1))
    _, _, lg_decode = M.decode_step(CFG, weights(), kc, vc, nxt, jnp.int32(length))
    np.testing.assert_allclose(lg_prefill, lg_decode, rtol=2e-4, atol=2e-4)


def test_generate_matches_reference():
    l = CFG.buckets[0]
    length = l - 6
    tokens = rand_tokens(3, l)
    ref = M.generate_ref(CFG, weights(), tokens, length, CFG.max_new, STOP)
    out, n = M.generate(
        CFG, weights(), tokens, jnp.int32(length), jnp.int32(CFG.max_new), jnp.int32(STOP)
    )
    assert list(np.asarray(out[: int(n)])) == ref
    # Slots past n are zero.
    assert (np.asarray(out[int(n):]) == 0).all()


def test_generate_respects_max_new():
    l = CFG.buckets[0]
    tokens = rand_tokens(4, l)
    out, n = M.generate(
        CFG, weights(), tokens, jnp.int32(l - 2), jnp.int32(3), jnp.int32(STOP)
    )
    assert int(n) <= 3


def test_generate_stops_on_stop_id():
    # Force the stop id to be whatever the model would emit first; then
    # generation must stop immediately with n == 0.
    l = CFG.buckets[0]
    length = l - 2
    tokens = rand_tokens(5, l)
    _, _, lg = M.prefill(CFG, weights(), tokens, jnp.int32(length))
    first = int(jnp.argmax(lg))
    out, n = M.generate(
        CFG, weights(), tokens, jnp.int32(length), jnp.int32(8), jnp.int32(first)
    )
    assert int(n) == 0


def test_rope_position_sensitivity():
    # The same token at different positions must produce different K.
    x = jnp.ones((1, CFG.n_heads, CFG.head_dim))
    a = M.rope(x, jnp.array([1]), CFG.rope_base)
    b = M.rope(x, jnp.array([2]), CFG.rope_base)
    assert float(jnp.abs(a - b).max()) > 1e-3
    # Position 0 is identity (cos=1, sin=0).
    z = M.rope(x, jnp.array([0]), CFG.rope_base)
    np.testing.assert_allclose(z, x, rtol=1e-6)


def test_rmsnorm_unit_scale():
    x = jnp.array([[3.0, -4.0]])
    out = M.rmsnorm(x, jnp.ones((2,)))
    # RMS of [3,-4] is sqrt(12.5); output RMS must be ~1.
    rms = float(jnp.sqrt(jnp.mean(out * out)))
    assert abs(rms - 1.0) < 1e-4


@settings(max_examples=10, deadline=None)
@given(
    length_frac=st.floats(min_value=0.2, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_generate_hypothesis_never_overflows(length_frac, seed):
    l = CFG.buckets[0]
    length = max(1, int(l * length_frac))
    tokens = rand_tokens(seed, l)
    out, n = M.generate(
        CFG, weights(), tokens, jnp.int32(length), jnp.int32(CFG.max_new), jnp.int32(STOP)
    )
    assert 0 <= int(n) <= CFG.max_new
    ids = np.asarray(out)
    assert (ids >= 0).all() and (ids < CFG.vocab_size).all()
