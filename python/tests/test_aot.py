"""AOT export sanity: the HLO-text pipeline produces loadable, complete
artifacts whose declared contract matches the Rust side's expectations."""

import json
import os

import jax
import pytest

from compile import aot
from compile.model import TINY, ModelConfig

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("tiny_artifacts")
    aot.export(TINY, str(d))
    return d


def test_export_writes_all_artifacts(tiny_dir):
    names = os.listdir(tiny_dir)
    assert "model_meta.json" in names
    assert "init.hlo.txt" in names
    for b in TINY.buckets:
        assert f"generate_{b}.hlo.txt" in names


def test_meta_contract(tiny_dir):
    meta = json.load(open(tiny_dir / "model_meta.json"))
    for key in (
        "vocab_size",
        "d_model",
        "n_layers",
        "n_heads",
        "head_dim",
        "ffn",
        "max_new",
        "seed",
        "buckets",
    ):
        assert key in meta, key
    assert meta["buckets"] == sorted(meta["buckets"])
    assert meta["vocab_size"] == TINY.vocab_size


def test_hlo_text_is_parseable_hlo(tiny_dir):
    text = (tiny_dir / "init.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Bucket shapes must appear in the generate modules.
    for b in TINY.buckets:
        gtext = (tiny_dir / f"generate_{b}.hlo.txt").read_text()
        assert f"s32[{b}]" in gtext, f"tokens arg shape missing for bucket {b}"
        assert f"s32[{TINY.max_new}]" in gtext, "output ids shape missing"


def test_hlo_has_no_64bit_id_issue(tiny_dir):
    # The interchange contract: text must round-trip through the XLA text
    # parser (which reassigns ids). Smoke-check by re-parsing with the
    # local xla_client.
    from jax._src.lib import xla_client as xc

    text = (tiny_dir / f"generate_{TINY.buckets[0]}.hlo.txt").read_text()
    # jaxlib's client can't parse HLO text directly; assert the known-bad
    # pattern (proto serialization) was not used instead.
    assert not text.startswith(b"\x08".decode("latin1")), "binary proto, not text"
    assert "f32[" in text
    _ = xc  # imported to pin the dependency the AOT path relies on


def test_generate_signature_arity(tiny_dir):
    # weights + tokens + length + max_new + stop_id parameters.
    text = (tiny_dir / f"generate_{TINY.buckets[0]}.hlo.txt").read_text()
    entry = [l for l in text.splitlines() if "ENTRY" in l or "entry_computation_layout" in l]
    assert entry, "no entry computation found"
    expected_args = TINY.n_weights() + 4
    header = entry[0]
    assert header.count("f32[") + header.count("s32[") >= expected_args


def test_export_is_deterministic(tmp_path):
    d1, d2 = tmp_path / "a", tmp_path / "b"
    aot.export(TINY, str(d1))
    aot.export(TINY, str(d2))
    a = (d1 / "init.hlo.txt").read_text()
    b = (d2 / "init.hlo.txt").read_text()
    assert a == b


def test_production_config_contract():
    cfg = ModelConfig()
    assert cfg.vocab_size == 4096
    assert cfg.buckets == (128, 256, 512, 1024, 2048)
    assert cfg.max_new == 128
    assert cfg.seed == 123  # the paper's seed
