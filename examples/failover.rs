//! Failover end to end: kill an edge node mid-conversation, watch the
//! heartbeat detector declare it down and swap an epoch-stamped placement
//! that skips it, keep chatting while its writes park as hints, then
//! restart it and watch the hints replay until the fleet reconverges.
//!
//! ```sh
//! cargo run --release --example failover
//! ```
//!
//! Uses the zero-cost mock engine: the interesting part here is the
//! cluster machinery, not the model.

use std::time::Duration;

use discedge::client::{Client, MobilityPolicy};
use discedge::cluster::NodeState;
use discedge::config::{ClusterConfig, ContextMode};
use discedge::server::EdgeCluster;

const MODEL: &str = "discedge/tiny-chat";

fn main() -> discedge::Result<()> {
    let mut cfg = ClusterConfig::mock_fleet(3, Some(2));
    cfg.enable_fast_membership();
    cfg.replication.max_attempts = 2;
    cfg.replication.retry_backoff = Duration::from_millis(1);

    eprintln!("[failover] launching a 3-node fleet (rf=2, membership on)...");
    let mut cluster = EdgeCluster::launch(cfg)?;
    let view = cluster
        .membership()
        .expect("membership enabled")
        .clone();
    println!("fleet up: epoch {}, {} alive", view.epoch(), view.alive_count());

    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(16);

    for t in 1..=3 {
        let r = client.chat(&format!("turn {t}: what do edge robots need?"))?;
        println!("turn {t} served by {} ({} ctx tokens)", r.node, r.response.prefill_tokens);
        cluster.quiesce();
    }

    // Find a home replica of this session that is not the serving node
    // and crash it.
    let (user, session) = client.session();
    let key = format!("{}/{}", user.unwrap(), session.unwrap());
    let placement = cluster.current_placement().unwrap();
    let victim = placement
        .replicas(MODEL, &key)
        .into_iter()
        .map(|(name, _)| name)
        .find(|name| name != "edge-0")
        .expect("some home replica is not the serving node");
    println!("\n*** killing home replica {victim} ***");
    let victim_cfg = cluster.kill_node(&victim).unwrap();

    // The conversation continues; outage-window writes park as hints.
    for t in 4..=5 {
        let r = client.chat(&format!("turn {t}: and during failures?"))?;
        println!("turn {t} served by {} (outage in progress)", r.node);
        cluster.quiesce();
    }
    let edge0 = cluster.node("edge-0").unwrap();
    println!(
        "edge-0 parked {} hint(s) for the dead replica, dropped {}",
        edge0.kv.hints_queued(),
        edge0.kv.repl_dropped_total()
    );

    assert!(view.wait_for_state(&victim, NodeState::Down, Duration::from_secs(10)));
    println!(
        "detector declared {victim} down: epoch {} -> placement now {:?}",
        view.epoch(),
        cluster
            .current_placement()
            .unwrap()
            .replicas(MODEL, &key)
            .into_iter()
            .map(|(name, _)| name)
            .collect::<Vec<_>>()
    );

    println!("\n*** restarting {victim} ***");
    cluster.add_node(victim_cfg)?;
    view.wait_for_state(&victim, NodeState::Alive, Duration::from_secs(10));
    // Wait for hint replay to land on the restarted replica.
    let restarted = cluster.node(&victim).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !restarted.kv.get(MODEL, &key).is_some_and(|e| e.version >= 5) {
        if std::time::Instant::now() > deadline {
            panic!("hint replay did not converge");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let entry = restarted.kv.get(MODEL, &key).unwrap();
    println!(
        "{} rejoined at epoch {} and replayed to v{} ({} hint(s) replayed by edge-0)",
        victim,
        view.epoch(),
        entry.version,
        cluster.node("edge-0").unwrap().kv.hints_replayed()
    );

    let r = client.chat("turn 6: summarize what survived the crash")?;
    cluster.quiesce();
    println!("turn 6 served by {} — conversation never lost a turn", r.node);
    Ok(())
}
