//! Anti-entropy repair end to end: partition a replica long enough that
//! the bounded hint queues overflow (evicted hints are data the push
//! pipeline can never deliver again), restart it, and watch the Merkle
//! digest walk find and heal exactly the divergence that hint replay
//! could not — byte-for-byte convergence, unconditionally.
//!
//! ```sh
//! cargo run --release --example anti_entropy
//! ```
//!
//! Uses the zero-cost mock engine: the interesting part is the repair
//! machinery, not the model.

use std::time::Duration;

use discedge::client::{Client, MobilityPolicy};
use discedge::cluster::NodeState;
use discedge::config::{ClusterConfig, ContextMode};
use discedge::server::EdgeCluster;

const MODEL: &str = "discedge/tiny-chat";
const SESSIONS: usize = 5;
const HINT_CAP: usize = 2;

fn main() -> discedge::Result<()> {
    let mut cfg = ClusterConfig::mock_fleet(2, None);
    cfg.enable_fast_membership();
    cfg.membership.down_after = Duration::from_millis(400);
    cfg.replication.max_attempts = 2;
    cfg.replication.retry_backoff = Duration::from_millis(1);
    // A deliberately tiny hint bound: the outage below overflows it.
    cfg.hints.max_per_peer = HINT_CAP;
    cfg.antientropy.enabled = true;
    cfg.antientropy.interval = Duration::from_millis(200);

    eprintln!("[anti-entropy] launching a 2-node fleet (hints capped at {HINT_CAP})...");
    let mut cluster = EdgeCluster::launch(cfg)?;
    let view = cluster.membership().expect("membership enabled").clone();

    // One independent conversation per session, all served by edge-0.
    let mut clients: Vec<Client> = (0..SESSIONS)
        .map(|_| {
            Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
                .with_mode(ContextMode::Tokenized)
                .with_model(MODEL)
                .with_max_tokens(8)
        })
        .collect();
    for (i, client) in clients.iter_mut().enumerate() {
        client.chat(&format!("session {i}, turn 1: what do edge robots need?"))?;
        cluster.quiesce();
    }
    let keys: Vec<String> = clients
        .iter()
        .map(|c| {
            let (user, session) = c.session();
            format!("{}/{}", user.unwrap(), session.unwrap())
        })
        .collect();
    println!("{SESSIONS} sessions replicated to both nodes");

    println!("\n*** killing edge-1, then writing turn 2 of every session ***");
    let victim_cfg = cluster.kill_node("edge-1").unwrap();
    std::thread::sleep(Duration::from_millis(30));
    for (i, client) in clients.iter_mut().enumerate() {
        client.chat(&format!("session {i}, turn 2: and during failures?"))?;
        cluster.quiesce();
    }
    let edge0 = cluster.node("edge-0").unwrap();
    println!(
        "outage parked {} hint(s); the {HINT_CAP}-slot bound evicted {} — \
         replay alone can no longer converge this fleet \
         ({} update(s) handed to anti-entropy)",
        edge0.kv.hints_queued(),
        edge0.kv.hints_dropped(),
        edge0.kv.ae_lost_updates(),
    );
    assert_eq!(edge0.kv.hints_dropped() as usize, SESSIONS - HINT_CAP);
    assert!(view.wait_for_state("edge-1", NodeState::Down, Duration::from_secs(10)));

    println!("\n*** restarting edge-1: hint replay + a kicked repair round ***");
    cluster.add_node(victim_cfg)?;
    assert!(view.wait_for_state("edge-1", NodeState::Alive, Duration::from_secs(10)));
    cluster.quiesce();
    for node in &cluster.nodes {
        node.kv.run_antientropy_round();
    }

    // Byte-for-byte convergence of every session, including the evicted
    // ones no hint could restore.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    for key in &keys {
        loop {
            let a = cluster.node("edge-0").unwrap().kv.get(MODEL, key);
            let b = cluster.node("edge-1").unwrap().kv.get(MODEL, key);
            match (&a, &b) {
                (Some(ea), Some(eb)) if ea.version == 2 && ea.value == eb.value => break,
                _ if std::time::Instant::now() > deadline => {
                    panic!("repair did not converge {key}: {a:?} vs {b:?}")
                }
                _ => std::thread::sleep(Duration::from_millis(10)),
            }
        }
    }
    let repaired: u64 = cluster.nodes.iter().map(|n| n.kv.ae_keys_repaired()).sum();
    let digest: u64 = cluster.nodes.iter().map(|n| n.kv.ae_digest_bytes()).sum();
    println!(
        "fleet converged byte-for-byte: {repaired} entr(ies) repaired, \
         {digest} digest byte(s) — the replication-port accounting never \
         saw the walk"
    );
    Ok(())
}
