//! The transport layer end to end: keep-alive connection pooling
//! (one TCP connect amortized over many requests, transparent
//! reconnect after an idle reap) and the bounded server (at the
//! `max_server_conns` budget, extra clients get an immediate clean
//! `503` instead of an unbounded thread each).
//!
//! ```sh
//! cargo run --release --example transport
//! ```

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use discedge::http::{read_response, Request, Response, Server, ServerLimits};
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::transport::PeerPool;

fn main() -> discedge::Result<()> {
    // A small server: budget of 2 live connections, fast idle reaping.
    let limits = ServerLimits {
        max_conns: 2,
        idle_timeout: Duration::from_millis(200),
        ..ServerLimits::default()
    };
    let server = Server::serve_with(
        0,
        LinkModel::ideal(),
        limits,
        Arc::new(|req: &Request| Response::json(req.body_str().unwrap_or("{}"))),
    )?;
    println!("server up at {} (budget 2 conns, 200 ms idle reap)", server.addr);

    // 1. Pool reuse: five requests, one connect.
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    for i in 0..5 {
        let req = Request::post_json("/echo", &format!("{{\"i\":{i}}}"));
        let resp = pool.round_trip(server.addr, &req)?;
        assert_eq!(resp.status, 200);
    }
    println!(
        "5 requests: {} connect(s), {} reuse(s)",
        pool.stats().opened.get(),
        pool.stats().reused.get()
    );
    assert_eq!(pool.stats().opened.get(), 1);

    // 2. Saturation: two held keep-alive clients fill the budget; the
    // next client is answered 503 on accept — no thread, no hang.
    let mut held = Vec::new();
    for _ in 0..2 {
        let mut conn = pool.checkout(server.addr)?;
        conn.round_trip(&Request::post_json("/echo", "{}"))?;
        held.push(conn);
    }
    println!("budget filled: {} live connection(s)", server.live_conns());
    let raw = TcpStream::connect(server.addr)?;
    raw.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut reader = BufReader::new(raw);
    let refused = read_response(&mut reader)?;
    println!("3rd client refused with {}", refused.status);
    assert_eq!(refused.status, 503);
    assert!(server.live_conns() <= 2, "budget never exceeded");

    // 3. Releasing the held clients: their connections return to the
    // pool and the next request rides one of them — no new connect, no
    // 503.
    drop(held);
    let resp = pool.round_trip(server.addr, &Request::post_json("/echo", "{}"))?;
    assert_eq!(resp.status, 200);
    println!("clients released: request served over a pooled connection");

    // 4. Idle reap + transparent reconnect: the server reaps the pooled
    // socket; the next request replaces it with one fresh connect
    // instead of failing (the wedge the pool exists to prevent).
    let opened_before = pool.stats().opened.get();
    std::thread::sleep(Duration::from_millis(500));
    let resp = pool.round_trip(server.addr, &Request::post_json("/echo", "{\"back\":1}"))?;
    assert_eq!(resp.status, 200);
    println!(
        "after idle reap: request served via transparent reconnect \
         (+{} connect(s), {} eviction(s) total)",
        pool.stats().opened.get() - opened_before,
        pool.stats().evicted.get()
    );
    Ok(())
}
