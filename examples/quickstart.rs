//! Quickstart: launch a single DisCEdge node with the real AOT-compiled
//! model (PJRT) and hold a short conversation.
//!
//! ```sh
//! make artifacts            # once: AOT model + tokenizer
//! cargo run --release --example quickstart
//! ```
//!
//! Falls back to the mock engine when artifacts are missing so the example
//! always runs.

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode, EngineKind};
use discedge::server::EdgeCluster;

fn main() -> discedge::Result<()> {
    let mut cfg = ClusterConfig::two_node_testbed();
    cfg.nodes.truncate(1); // one edge node is enough here
    if !cfg.artifacts_dir.join("model_meta.json").exists() {
        eprintln!("[quickstart] no artifacts found -> using the mock engine");
        cfg.engine = EngineKind::Mock {
            prefill_ns_per_token: 2_000,
            decode_ns_per_token: 200_000,
        };
    }

    eprintln!("[quickstart] launching edge node (compiling model)...");
    let cluster = EdgeCluster::launch(cfg)?;
    let (name, addr) = &cluster.endpoints()[0];
    println!("edge node `{name}` serving at http://{addr}\n");

    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_max_tokens(48);

    for prompt in [
        "What are the fundamental components of an autonomous mobile robot?",
        "You mentioned sensors. What are the most common types for obstacle avoidance?",
        "Can you explain the concept of a PID controller?",
    ] {
        println!("user> {prompt}");
        let r = client.chat(prompt)?;
        println!(
            "assistant ({} tok, {:.2}s, ctx {} tok)> {}\n",
            r.response.tokens_generated,
            r.e2e_s,
            r.response.prefill_tokens,
            preview(&r.response.text, 120),
        );
    }

    let (user, session) = client.session();
    println!(
        "session {} for user {} stored pre-tokenized on the edge node \
         ({} KV entries)",
        session.unwrap_or("?"),
        user.unwrap_or("?"),
        cluster.nodes[0].kv.len()
    );
    Ok(())
}

fn preview(s: &str, n: usize) -> String {
    let clean: String = s.chars().take(n).collect();
    if s.chars().count() > n {
        format!("{clean}…")
    } else {
        clean
    }
}
