//! Mobile roaming — the paper's headline scenario, end-to-end on the real
//! stack (PJRT model, two heterogeneous edge nodes, KV replication over
//! TCP, turn-counter consistency protocol).
//!
//! A client runs the 9-turn robotics conversation while switching edge
//! nodes on turns 3, 5 and 7 (paper §4.2.2). The session context follows
//! the client through the distributed KV store; the Context Manager's
//! retry protocol absorbs replication lag at each handover.
//!
//! ```sh
//! make artifacts && cargo run --release --example mobile_roaming
//! ```

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode, EngineKind};
use discedge::metrics::Series;
use discedge::netsim::LinkModel;
use discedge::server::EdgeCluster;
use discedge::workload::Scenario;

fn main() -> discedge::Result<()> {
    let mut cfg = ClusterConfig::two_node_testbed();
    cfg.client_link = LinkModel::mobile_uplink();
    if !cfg.artifacts_dir.join("model_meta.json").exists() {
        eprintln!("[mobile_roaming] no artifacts -> mock engine");
        cfg.engine = EngineKind::Mock {
            prefill_ns_per_token: 300_000,
            decode_ns_per_token: 2_000_000,
        };
    }
    eprintln!("[mobile_roaming] launching the two-node testbed...");
    let cluster = EdgeCluster::launch(cfg)?;
    for (name, addr) in cluster.endpoints() {
        println!("  {name} @ http://{addr}");
    }

    let scenario = Scenario::robotics_9turn();
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::paper_alternate())
        .with_mode(ContextMode::Tokenized)
        .with_link(LinkModel::mobile_uplink())
        .with_max_tokens(128);

    println!(
        "\nturn | node      | e2e_s  | ctx_tok | retries | req_B | handover?"
    );
    let mut last_node = String::new();
    let mut e2e = Series::new();
    for turn in scenario.turns() {
        let r = client.chat(&turn.prompt)?;
        let handover = if !last_node.is_empty() && r.node != last_node {
            "  <-- switched"
        } else {
            ""
        };
        println!(
            "{:>4} | {:<9} | {:>6.2} | {:>7} | {:>7} | {:>5} |{handover}",
            turn.number,
            r.node,
            r.e2e_s,
            r.response.prefill_tokens,
            r.response.timings.retries,
            r.request_bytes,
        );
        last_node = r.node.clone();
        e2e.push(r.e2e_s);
    }

    cluster.quiesce();
    println!("\nsummary:");
    println!("  median response time : {:.3}s", e2e.median());
    println!(
        "  sync traffic          : m2 {} B, tx2 {} B",
        cluster.nodes[0].sync_bytes(),
        cluster.nodes[1].sync_bytes()
    );
    println!(
        "  consistency retries   : m2 {} / tx2 {}",
        cluster.nodes[0].cm.registry.counter("cm_retries_total"),
        cluster.nodes[1].cm.registry.counter("cm_retries_total"),
    );
    println!(
        "  both replicas converged to {} session entr{}",
        cluster.nodes[0].kv.len(),
        if cluster.nodes[0].kv.len() == 1 { "y" } else { "ies" },
    );
    Ok(())
}
