//! Multi-tenant serving — the scalability question the paper's §5 leaves
//! open: several concurrent clients, two models with *isolated* keygroups,
//! sessions interleaving on both nodes.
//!
//! Demonstrates: per-model keygroup isolation (context never replicates to
//! nodes not serving that model), engine request serialization (the
//! single-executor PJRT thread), and per-session consistency under
//! concurrency. Reports aggregate throughput and tail latency.
//!
//! ```sh
//! make artifacts && cargo run --release --example multi_tenant
//! ```

use std::sync::Arc;

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode, EngineKind, NodeConfig};
use discedge::metrics::Series;
use discedge::profile::NodeProfile;
use discedge::server::EdgeCluster;
use discedge::workload::Scenario;

const CLIENTS: usize = 6;
const TURNS: usize = 4;

fn main() -> discedge::Result<()> {
    let mut cfg = ClusterConfig::two_node_testbed();
    // Both nodes serve the chat model; a third node serves an "assist"
    // model only (separate keygroup — no cross-replication expected).
    cfg.nodes.push(NodeConfig {
        name: "edge-assist".into(),
        profile: NodeProfile::m2(),
        api_port: 0,
        kv_port: 0,
        models: vec!["discedge/tiny-assist".into()],
    });
    if !cfg.artifacts_dir.join("model_meta.json").exists() {
        eprintln!("[multi_tenant] no artifacts -> mock engine");
        cfg.engine = EngineKind::Mock {
            prefill_ns_per_token: 100_000,
            decode_ns_per_token: 500_000,
        };
    }
    // The assist model reuses the same artifacts (same architecture) under
    // a different model name — a second engine instance and keygroup.
    eprintln!("[multi_tenant] launching 3-node cluster, 2 models...");
    let cluster = Arc::new(EdgeCluster::launch(cfg)?);
    for (name, addr) in cluster.endpoints() {
        println!("  {name} @ http://{addr}");
    }

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let endpoints = cluster.endpoints();
        handles.push(std::thread::spawn(move || {
            // Chat clients roam across the two chat nodes; assist clients
            // pin to the assist node.
            let assist = c % 3 == 2;
            let (model, policy) = if assist {
                ("discedge/tiny-assist", MobilityPolicy::Sticky(2))
            } else {
                (
                    "discedge/tiny-chat",
                    MobilityPolicy::Alternate {
                        nodes: vec![0, 1],
                        every: 2,
                    },
                )
            };
            let mut client = Client::connect(endpoints, policy)
                .with_mode(ContextMode::Tokenized)
                .with_model(model)
                .with_max_tokens(32);
            let scenario = Scenario::synthetic(c as u64, TURNS, 10);
            let mut lat = Vec::new();
            let mut retries = 0;
            for turn in scenario.turns() {
                // No quiesce: clients race replication; the consistency
                // protocol covers the handovers.
                match client.chat(&turn.prompt) {
                    Ok(r) => {
                        lat.push(r.e2e_s);
                        retries += r.response.timings.retries;
                    }
                    Err(e) => {
                        eprintln!("client {c} turn {} failed: {e}", turn.number);
                        // Strict consistency can reject a raced handover;
                        // a real client would retry the turn. Do that.
                        std::thread::sleep(std::time::Duration::from_millis(50));
                        let r = client.chat(&turn.prompt).expect("retry");
                        lat.push(r.e2e_s);
                        retries += r.response.timings.retries;
                    }
                }
            }
            (c, model, lat, retries)
        }));
    }

    let mut all = Series::new();
    let mut total_turns = 0usize;
    for h in handles {
        let (c, model, lat, retries) = h.join().expect("client thread");
        let s = Series::from(lat.iter().copied());
        println!(
            "client {c} ({model}): {} turns, median {:.2}s, p95 {:.2}s, {} retries",
            lat.len(),
            s.median(),
            s.percentile(95.0),
            retries
        );
        total_turns += lat.len();
        all.extend(&s);
    }
    let wall = t0.elapsed().as_secs_f64();

    cluster.quiesce();
    println!("\naggregate:");
    println!(
        "  {total_turns} turns / {wall:.1}s wall = {:.2} turns/s; median {:.2}s, p95 {:.2}s",
        total_turns as f64 / wall,
        all.median(),
        all.percentile(95.0)
    );
    println!(
        "  keygroup isolation: edge-assist sync bytes = {} (expected 0: no peer shares its model)",
        cluster.nodes[2].sync_bytes()
    );
    println!(
        "  chat replicas hold {} + {} sessions; assist holds {}",
        cluster.nodes[0].kv.len(),
        cluster.nodes[1].kv.len(),
        cluster.nodes[2].kv.len()
    );
    Ok(())
}
