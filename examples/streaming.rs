//! Streaming + continuous batching: launch one edge node with the
//! inference scheduler on, fire a burst of concurrent conversations,
//! and show what the scheduler buys — time-to-first-token stays close
//! to a single decode step while the full responses still take their
//! end-to-end time.
//!
//! ```sh
//! cargo run --release --example streaming
//! ```
//!
//! Runs on the mock engine (deterministic, emulated per-step costs) so
//! it works without artifacts; the same config drives the PJRT engine,
//! where the scheduler falls back to sequential decode.

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode, EngineKind};
use discedge::server::EdgeCluster;

const CLIENTS: usize = 6;
const TURNS: usize = 3;

fn main() -> discedge::Result<()> {
    let mut cfg = ClusterConfig::single_node_mock();
    cfg.engine = EngineKind::Mock {
        prefill_ns_per_token: 50_000,
        decode_ns_per_token: 1_000_000,
    };
    cfg.inference.enabled = true;
    cfg.inference.max_batch = 8;
    cfg.inference.queue_depth = 64;
    cfg.inference.stream = true;

    eprintln!("[streaming] launching edge node (batching on, streamed responses)...");
    let cluster = EdgeCluster::launch(cfg)?;
    let (name, addr) = &cluster.endpoints()[0];
    println!("edge node `{name}` at http://{addr}: max_batch 8, chunked /completion\n");

    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let endpoints = cluster.endpoints();
            std::thread::spawn(move || -> discedge::Result<Vec<(f64, f64, usize)>> {
                let mut client = Client::connect(endpoints, MobilityPolicy::Sticky(0))
                    .with_mode(ContextMode::Tokenized)
                    .with_max_tokens(32);
                let mut turns = Vec::new();
                for t in 1..=TURNS {
                    let r = client.chat(&format!(
                        "client {c} turn {t}: describe the rover's next waypoint"
                    ))?;
                    turns.push((r.ttft_s, r.e2e_s, r.response.tokens_generated));
                }
                Ok(turns)
            })
        })
        .collect();

    println!("{:<8} {:>6} {:>10} {:>10} {:>8}", "client", "turn", "ttft", "e2e", "tokens");
    let (mut ttft_sum, mut e2e_sum, mut n) = (0.0, 0.0, 0);
    for (c, h) in handles.into_iter().enumerate() {
        let turns = h.join().expect("client thread")?;
        for (t, (ttft, e2e, tokens)) in turns.iter().enumerate() {
            println!(
                "{c:<8} {:>6} {:>9.3}s {:>9.3}s {tokens:>8}",
                t + 1,
                ttft,
                e2e
            );
            ttft_sum += ttft;
            e2e_sum += e2e;
            n += 1;
        }
    }
    println!(
        "\n{CLIENTS} concurrent clients x {TURNS} turns: mean ttft {:.3}s vs mean e2e {:.3}s \
         — the first token streams out while the rest of the batch is still decoding",
        ttft_sum / n as f64,
        e2e_sum / n as f64
    );
    Ok(())
}
