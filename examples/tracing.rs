//! Distributed tracing — follow one roaming turn across the fleet.
//!
//! Launches a four-node sharded fleet with observability enabled, roams
//! a client across all four nodes, then scrapes every node's
//! `GET /trace` ring and stitches the spans back into per-trace trees:
//! the serving node's `turn` root with its tokenize/prefill/decode/fetch
//! phase children, plus the `remote_fetch`/`serve_fetch` pair when a
//! handover forced the context to be pulled from its home replica.
//! Finishes with each node's `GET /status` one-call summary.
//!
//! ```sh
//! cargo run --release --example tracing
//! ```

use std::collections::BTreeMap;

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode};
use discedge::http::Request;
use discedge::json::{self, Value};
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::server::EdgeCluster;
use discedge::transport::PeerPool;
use discedge::workload::Scenario;

/// One span row scraped from a node's `/trace` ring.
struct Row {
    node: String,
    name: String,
    trace_id: String,
    span_id: String,
    parent: Option<String>,
    start_us: u64,
    dur_us: u64,
    detail: Option<String>,
}

fn scrape(pool: &PeerPool, addr: std::net::SocketAddr, path: &str) -> Value {
    let resp = pool
        .round_trip(addr, &Request::get(path))
        .expect("node reachable");
    json::parse(resp.body_str().expect("utf8 body")).expect("valid JSON")
}

fn main() -> discedge::Result<()> {
    let mut cfg = ClusterConfig::mock_fleet(4, Some(2));
    cfg.observability.enabled = true;
    eprintln!("[tracing] launching a 4-node fleet (rf=2, tracing on)...");
    let cluster = EdgeCluster::launch(cfg)?;

    let model = "discedge/tiny-chat";
    let mut client = Client::connect(
        cluster.endpoints(),
        MobilityPolicy::Alternate {
            nodes: vec![0, 1, 2, 3],
            every: 1,
        },
    )
    .with_mode(ContextMode::Tokenized)
    .with_model(model)
    .with_max_tokens(16);

    let scenario = Scenario::robotics_9turn();
    for turn in scenario.turns().iter().take(6) {
        let r = client.chat(&turn.prompt)?;
        println!("turn {} served by {}", turn.number, r.node);
        cluster.quiesce();
    }

    // Stitch: every node's ring, grouped by trace id.
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let mut rows: Vec<Row> = Vec::new();
    for node in &cluster.nodes {
        let v = scrape(&pool, node.api_addr(), "/trace");
        for s in v.get("spans").and_then(Value::as_array).unwrap() {
            rows.push(Row {
                node: s.req_str("node").unwrap(),
                name: s.req_str("name").unwrap(),
                trace_id: s.req_str("trace_id").unwrap(),
                span_id: s.req_str("span_id").unwrap(),
                parent: s.get("parent").and_then(Value::as_str).map(str::to_string),
                start_us: s.req_u64("start_us").unwrap(),
                dur_us: s.req_u64("dur_us").unwrap(),
                detail: s.get("detail").and_then(Value::as_str).map(str::to_string),
            });
        }
    }
    rows.sort_by_key(|r| r.start_us);

    let mut traces: BTreeMap<&str, Vec<&Row>> = BTreeMap::new();
    for row in &rows {
        traces.entry(&row.trace_id).or_default().push(row);
    }
    println!("\n{} spans across {} traces:", rows.len(), traces.len());
    for (trace_id, spans) in &traces {
        let mut nodes: Vec<&str> = spans.iter().map(|s| s.node.as_str()).collect();
        nodes.sort_unstable();
        nodes.dedup();
        let plural = if nodes.len() == 1 { "" } else { "s" };
        println!("\ntrace {}… ({} node{plural})", &trace_id[..8], nodes.len());
        // Indent each span one level under its parent (two when the
        // parent itself has a parent — this repo's traces are ≤3 deep).
        let parents: BTreeMap<&str, Option<&str>> = spans
            .iter()
            .map(|s| (s.span_id.as_str(), s.parent.as_deref()))
            .collect();
        for s in spans {
            let mut depth = 0;
            let mut cur = s.parent.as_deref();
            while let Some(p) = cur {
                depth += 1;
                cur = parents.get(p).copied().flatten();
                if depth > 8 {
                    break;
                }
            }
            println!(
                "  {:indent$}{:<14} {:>8} us  [{}]{}",
                "",
                s.name,
                s.dur_us,
                s.node,
                s.detail.as_deref().map(|d| format!("  {d}")).unwrap_or_default(),
                indent = depth * 2,
            );
        }
    }

    println!("\nper-node status:");
    for node in &cluster.nodes {
        let v = scrape(&pool, node.api_addr(), "/status");
        let obs = v.get("obs").unwrap();
        let net = v.get("net").unwrap();
        println!(
            "  {:<9} spans started={} exported={} dropped={}  conns opened={} reused={}",
            v.req_str("node").unwrap(),
            obs.req_u64("spans_started").unwrap(),
            obs.req_u64("spans_exported").unwrap(),
            obs.req_u64("spans_dropped").unwrap(),
            net.req_u64("opened").unwrap(),
            net.req_u64("reused").unwrap(),
        );
    }
    Ok(())
}
