//! Persistence end to end: run a conversation with the storage engine
//! journaling every turn, hard-crash a home replica, restart it, and
//! watch it recover the committed turns from its own snapshot+WAL before
//! hint replay tops up the outage window.
//!
//! ```sh
//! cargo run --release --example persistence
//! ```
//!
//! Uses the zero-cost mock engine: the interesting part here is the
//! storage engine and the rejoin path, not the model.

use std::time::Duration;

use discedge::client::{Client, MobilityPolicy};
use discedge::cluster::NodeState;
use discedge::config::{ClusterConfig, ContextMode};
use discedge::server::EdgeCluster;

const MODEL: &str = "discedge/tiny-chat";

fn main() -> discedge::Result<()> {
    let data_dir = std::env::temp_dir().join(format!(
        "discedge-persistence-example-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&data_dir);

    let mut cfg = ClusterConfig::mock_fleet(3, Some(2));
    cfg.enable_fast_membership();
    cfg.replication.max_attempts = 2;
    cfg.replication.retry_backoff = Duration::from_millis(1);
    cfg.storage.enabled = true;
    cfg.storage.dir = data_dir.clone();

    eprintln!(
        "[persistence] launching a 3-node fleet (rf=2, WAL under {})...",
        data_dir.display()
    );
    let mut cluster = EdgeCluster::launch(cfg)?;
    let view = cluster.membership().expect("membership enabled").clone();

    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(16);

    for t in 1..=3 {
        let r = client.chat(&format!("turn {t}: what do edge robots need?"))?;
        println!("turn {t} served by {}", r.node);
        cluster.quiesce();
    }

    let (user, session) = client.session();
    let key = format!("{}/{}", user.unwrap(), session.unwrap());
    let placement = cluster.current_placement().unwrap();
    let victim = placement
        .replicas(MODEL, &key)
        .into_iter()
        .map(|(name, _)| name)
        .find(|name| name != "edge-0")
        .expect("some home replica is not the serving node");
    let journaled = cluster.node(&victim).unwrap().kv.wal_appends();
    println!("\n*** hard-crashing home replica {victim} ({journaled} WAL records on disk) ***");
    let victim_cfg = cluster.kill_node(&victim).unwrap();

    // The conversation continues; outage-window writes park as hints.
    for t in 4..=5 {
        let r = client.chat(&format!("turn {t}: and during failures?"))?;
        println!("turn {t} served by {} (outage in progress)", r.node);
        cluster.quiesce();
    }
    assert!(view.wait_for_state(&victim, NodeState::Down, Duration::from_secs(10)));

    println!("\n*** restarting {victim} from its local snapshot+WAL ***");
    cluster.add_node(victim_cfg)?;
    let restarted = cluster.node(&victim).unwrap();
    println!(
        "{} recovered {} committed entr(ies) from disk before touching the network",
        victim,
        restarted.kv.recovered_entries()
    );
    let pre_replay = restarted.kv.get(MODEL, &key).expect("recovered session");
    println!("session readable at v{} straight from recovery", pre_replay.version);

    // Hint replay closes the outage-window gap on top of the recovery.
    view.wait_for_state(&victim, NodeState::Alive, Duration::from_secs(10));
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !restarted.kv.get(MODEL, &key).is_some_and(|e| e.version >= 5) {
        if std::time::Instant::now() > deadline {
            panic!("hint replay did not converge");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let entry = restarted.kv.get(MODEL, &key).unwrap();
    println!(
        "hint replay topped the session up to v{} — disk carried the past, peers the gap",
        entry.version
    );

    let r = client.chat("turn 6: summarize what survived the crash")?;
    cluster.quiesce();
    println!("turn 6 served by {} — conversation never lost a turn", r.node);
    drop(cluster);
    let _ = std::fs::remove_dir_all(&data_dir);
    Ok(())
}
