//! Anti-entropy integration: the two acceptance pins for Merkle-tree
//! replica repair.
//!
//! (a) After a partition long enough to overflow the hint queues (the
//!     oldest hints evict — data the push pipeline can never deliver
//!     again), a fleet with anti-entropy converges byte-for-byte with an
//!     unpartitioned control run, while an otherwise-identical fleet
//!     without it stays diverged forever.
//!
//! (b) With anti-entropy enabled and zero divergence, the replication
//!     port's data traffic is byte-for-byte identical to a fleet with it
//!     disabled: digest rounds ride a dedicated listener and meters
//!     (`kv_ae_digest_bytes`), at O(1) bytes per converged round.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use discedge::cluster::NodeState;
use discedge::config::{ClusterConfig, ContextMode};
use discedge::context::{CompletionRequest, CompletionResponse};
use discedge::http::Request as HttpRequest;
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::server::EdgeCluster;
use discedge::transport::PeerPool;

const MODEL: &str = "discedge/tiny-chat";

/// Distinct sessions driven through the partition scenario. Must exceed
/// `hints.max_per_peer` below so the oldest hints evict.
const SESSIONS: usize = 5;
const HINT_CAP: usize = 2;

fn fleet(antientropy: bool, membership: bool) -> EdgeCluster {
    let mut cfg = ClusterConfig::mock_fleet(2, None);
    if membership {
        cfg.enable_fast_membership();
        // Keep the detection window behind the outage turns (CI hosts).
        cfg.membership.down_after = Duration::from_millis(400);
        // Fail fast during the outage so hinting carries the test.
        cfg.replication.max_attempts = 2;
        cfg.replication.retry_backoff = Duration::from_millis(1);
        // Tiny bound: the 5-session outage overflows it by 3.
        cfg.hints.max_per_peer = HINT_CAP;
    }
    if antientropy {
        cfg.antientropy.enabled = true;
        // Background rounds dormant: the test drives rounds explicitly
        // (plus the automatic post-rejoin kick) so every assertion is
        // deterministic.
        cfg.antientropy.interval = Duration::from_secs(3600);
    }
    EdgeCluster::launch(cfg).unwrap()
}

fn post(addr: SocketAddr, req: &CompletionRequest) -> CompletionResponse {
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let resp = pool
        .round_trip(addr, &HttpRequest::post_json("/completion", &req.to_json()))
        .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str().unwrap_or("?"));
    CompletionResponse::from_json(resp.body_str().unwrap()).unwrap()
}

/// One turn of session `i` on edge-0, with explicit ids so both fleets
/// of a comparison produce identical keys and documents.
fn turn(cluster: &EdgeCluster, i: usize, t: u64) {
    let mut req = CompletionRequest::new(
        MODEL,
        &format!("turn {t} of session {i}: tell me about robots"),
        t,
        ContextMode::Tokenized,
    );
    req.user_id = Some(format!("u{i}"));
    req.session_id = Some(format!("s{i}"));
    post(cluster.nodes[0].api_addr(), &req);
    cluster.quiesce();
}

fn session_keys() -> Vec<String> {
    (1..=SESSIONS).map(|i| format!("u{i}/s{i}")).collect()
}

fn wait_for<T>(mut f: impl FnMut() -> Option<T>, timeout: Duration) -> Option<T> {
    let start = Instant::now();
    while start.elapsed() < timeout {
        if let Some(v) = f() {
            return Some(v);
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    None
}

/// Drive the partition scenario: converge 5 sessions, kill edge-1, write
/// a second turn per session during the outage (5 hints into a 2-slot
/// queue — 3 evict), then restart edge-1 and let hints replay. Returns
/// the cluster positioned right after the rejoin.
fn partition_past_hint_capacity(antientropy: bool) -> EdgeCluster {
    let mut cluster = fleet(antientropy, true);
    let view = cluster.membership().unwrap().clone();
    for i in 1..=SESSIONS {
        turn(&cluster, i, 1);
    }
    // Every session's v1 must be on the replica before the partition.
    let keys = session_keys();
    for key in &keys {
        wait_for(
            || cluster.node("edge-1").unwrap().kv.get(MODEL, key),
            Duration::from_secs(5),
        )
        .unwrap_or_else(|| panic!("{key} must replicate before the kill"));
    }
    let victim_cfg = cluster.kill_node("edge-1").expect("edge-1 exists");
    std::thread::sleep(Duration::from_millis(30));
    for i in 1..=SESSIONS {
        turn(&cluster, i, 2);
    }
    let edge0 = cluster.node("edge-0").unwrap();
    assert_eq!(
        edge0.kv.hints_dropped(),
        (SESSIONS - HINT_CAP) as u64,
        "the outage must overflow the hint queue"
    );
    assert_eq!(edge0.kv.repl_dropped_total(), 0, "outage writes hint, not drop");
    if antientropy {
        assert!(
            edge0.kv.ae_lost_updates() >= (SESSIONS - HINT_CAP) as u64,
            "every evicted hint must be handed to repair"
        );
    }
    assert!(view.wait_for_state("edge-1", NodeState::Down, Duration::from_secs(10)));
    cluster.add_node(victim_cfg).unwrap();
    assert!(view.wait_for_state("edge-1", NodeState::Alive, Duration::from_secs(10)));
    // Drain the hint replay (the surviving HINT_CAP newest sessions).
    cluster.quiesce();
    let restarted = cluster.node("edge-1").unwrap();
    wait_for(
        || {
            restarted
                .kv
                .get(MODEL, keys.last().unwrap())
                .filter(|e| e.version == 2)
        },
        Duration::from_secs(10),
    )
    .expect("replay must restore the newest surviving hint");
    cluster
}

#[test]
fn partition_past_hint_capacity_stays_diverged_without_antientropy() {
    // The hole this PR closes, pinned: evicted hints are gone for good —
    // the restarted replica never sees those sessions again.
    let cluster = partition_past_hint_capacity(false);
    let restarted = cluster.node("edge-1").unwrap();
    std::thread::sleep(Duration::from_millis(100)); // nothing in flight
    let keys = session_keys();
    let missing = keys
        .iter()
        .filter(|key| restarted.kv.get(MODEL, key).is_none())
        .count();
    assert_eq!(
        missing,
        SESSIONS - HINT_CAP,
        "evicted sessions must still be missing on the restarted replica"
    );
}

#[test]
fn partition_past_hint_capacity_heals_with_antientropy() {
    let cluster = partition_past_hint_capacity(true);
    // The rejoin kick already scheduled a round; run explicit rounds too
    // so the assertion does not race the background thread.
    for node in &cluster.nodes {
        node.kv.run_antientropy_round();
    }
    // Control: an identical fleet that never saw a failure. Same node
    // names, explicit session ids, deterministic mock engine => the
    // stored documents must match byte-for-byte.
    let control = fleet(false, true);
    for i in 1..=SESSIONS {
        turn(&control, i, 1);
        turn(&control, i, 2);
    }
    control.quiesce();
    let keys = session_keys();
    for key in &keys {
        let expected = control
            .node("edge-0")
            .unwrap()
            .kv
            .get(MODEL, key)
            .unwrap_or_else(|| panic!("control must hold {key}"));
        assert_eq!(expected.version, 2);
        for name in ["edge-0", "edge-1"] {
            let entry = wait_for(
                || {
                    cluster
                        .node(name)
                        .unwrap()
                        .kv
                        .get(MODEL, key)
                        .filter(|e| e.version == expected.version)
                },
                Duration::from_secs(10),
            )
            .unwrap_or_else(|| panic!("{name} must heal {key} to v2"));
            assert_eq!(
                entry.value, expected.value,
                "{name} diverged from the unpartitioned run on {key}"
            );
        }
    }
    let repaired: u64 = cluster
        .nodes
        .iter()
        .map(|n| n.kv.ae_keys_repaired())
        .sum();
    assert!(
        repaired >= (SESSIONS - HINT_CAP) as u64,
        "the evicted sessions must have healed through repair (got {repaired})"
    );
}

#[test]
fn zero_divergence_wire_traffic_is_byte_identical() {
    // Same fleet, same conversation, anti-entropy off vs. on with a
    // digest round after every turn: the replication-port byte counters
    // must be identical on every node — digest rounds ride dedicated
    // listeners and meters.
    fn run(antientropy: bool) -> Vec<(String, u64, u64)> {
        let cluster = fleet(antientropy, false);
        let mut digest_deltas: Vec<u64> = Vec::new();
        for t in 1..=4 {
            turn(&cluster, 1, t);
            if antientropy {
                let before: u64 = cluster.nodes.iter().map(|n| n.kv.ae_digest_bytes()).sum();
                for node in &cluster.nodes {
                    assert_eq!(
                        node.kv.run_antientropy_round(),
                        0,
                        "a converged fleet has nothing to repair"
                    );
                }
                let after: u64 = cluster.nodes.iter().map(|n| n.kv.ae_digest_bytes()).sum();
                assert!(after > before, "digest rounds must be metered");
                digest_deltas.push(after - before);
            }
        }
        if antientropy {
            // O(1) bytes per converged round: every round costs the same
            // root exchange, independent of the growing history.
            assert!(
                digest_deltas.windows(2).all(|w| w[0] == w[1]),
                "converged rounds must cost constant digest bytes: {digest_deltas:?}"
            );
            for node in &cluster.nodes {
                assert!(node.kv.ae_rounds() > 0);
                assert_eq!(node.kv.ae_keys_repaired(), 0);
                assert_eq!(node.kv.ae_conflicts(), 0);
            }
        }
        cluster
            .nodes
            .iter()
            .map(|n| (n.name.clone(), n.kv.sync_rx_bytes(), n.kv.sync_tx_bytes()))
            .collect()
    }
    let base = run(false);
    let with_ae = run(true);
    assert_eq!(
        base, with_ae,
        "anti-entropy with zero divergence must not change replication traffic"
    );
}
