//! Distributed-tracing integration: the two pins of the observability
//! layer.
//!
//! (a) **Stitched roaming trace**: with tracing enabled, a roaming turn
//!     served by a node outside the session's preference list produces
//!     ONE trace id whose spans appear on at least two nodes — the
//!     serving node's `turn`/`remote_fetch` spans and the home replica's
//!     serve-side span — all linked by the `x-pallas-trace` header the
//!     transport injects and the HTTP server extracts.
//!
//! (b) **Wire neutrality when off**: with the default (disabled)
//!     config, replication traffic is byte-for-byte what an
//!     observability-less build sends — no trace header, no extra
//!     bytes. Pinned by capturing a real replication push on a stub
//!     peer and asserting the exact header set and framing.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode};
use discedge::http::{Request as HttpRequest, Response, Server, ServerLimits};
use discedge::kvstore::{KvConfig, KvNode};
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::obs;
use discedge::server::EdgeCluster;
use discedge::transport::PeerPool;

const MODEL: &str = "discedge/tiny-chat";

/// Scrape `GET /trace` and return `(node, name, trace_id, parent)` rows.
fn scrape_trace(
    pool: &PeerPool,
    addr: std::net::SocketAddr,
) -> Vec<(String, String, String, Option<String>)> {
    let r = pool.round_trip(addr, &HttpRequest::get("/trace")).unwrap();
    assert_eq!(r.status, 200);
    let v = discedge::json::parse(r.body_str().unwrap()).unwrap();
    let node = v.req_str("node").unwrap();
    v.get("spans")
        .and_then(|s| s.as_array())
        .unwrap()
        .iter()
        .map(|s| {
            (
                node.clone(),
                s.req_str("name").unwrap(),
                s.req_str("trace_id").unwrap(),
                s.get("parent").and_then(|p| p.as_str()).map(str::to_string),
            )
        })
        .collect()
}

#[test]
fn roaming_turn_yields_one_trace_spanning_two_nodes() {
    // Sharded fleet (rf=2 of 4) so an alternate-roaming client is
    // guaranteed to serve some turn from a node outside the session's
    // preference list — the remote-fetch path the paper's mobility
    // penalty measures.
    let mut cfg = ClusterConfig::mock_fleet(4, Some(2));
    cfg.observability.enabled = true;
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let mut client = Client::connect(
        cluster.endpoints(),
        MobilityPolicy::Alternate {
            nodes: vec![0, 1, 2, 3],
            every: 1,
        },
    )
    .with_mode(ContextMode::Tokenized)
    .with_model(MODEL)
    .with_max_tokens(8);
    for t in 0..6 {
        client.chat(&format!("turn {t}: tell me about rovers")).unwrap();
        cluster.quiesce();
    }

    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let mut spans = Vec::new();
    for n in &cluster.nodes {
        spans.extend(scrape_trace(&pool, n.api_addr()));
    }
    // Index trace id -> (nodes it appears on, span names).
    let mut by_trace: BTreeMap<&str, (Vec<&str>, Vec<&str>)> = BTreeMap::new();
    for (node, name, trace_id, _) in &spans {
        let e = by_trace.entry(trace_id).or_default();
        if !e.0.contains(&node.as_str()) {
            e.0.push(node);
        }
        e.1.push(name);
    }
    let stitched = by_trace
        .iter()
        .find(|(_, (nodes, names))| {
            nodes.len() >= 2 && names.contains(&"remote_fetch")
        })
        .unwrap_or_else(|| {
            panic!("no trace spans two nodes with a remote_fetch child: {by_trace:#?}")
        });
    let (trace_id, (nodes, names)) = stitched;
    assert!(names.contains(&"turn"), "root span missing for {trace_id}: {names:?}");
    // The remote fetch's serve side landed on a *different* node under
    // the same trace id — the header crossed the node boundary.
    assert!(
        names.contains(&"serve_fetch"),
        "home replica must record the serve side of {trace_id} ({nodes:?}): {names:?}"
    );
    // And the remote_fetch span is parented, i.e. a child of the turn —
    // not an orphan that happened to share the id.
    assert!(
        spans
            .iter()
            .any(|(_, name, tid, _)| name == "turn" && tid == trace_id),
        "turn root present somewhere in the fleet for {trace_id}"
    );
    let fetch_parent = spans
        .iter()
        .find(|(_, name, tid, _)| name == "remote_fetch" && tid == trace_id)
        .and_then(|(_, _, _, parent)| parent.clone());
    assert!(fetch_parent.is_some(), "remote_fetch must have a parent span");
}

#[test]
fn async_update_replication_stitches_under_the_turn_trace() {
    // Replicate-to-all pair: the turn's async context write pushes to
    // the peer, which must record the apply under the originating
    // turn's trace id (the context carried across the replication
    // queue, then the wire).
    let mut cfg = ClusterConfig::mock_fleet(2, None);
    cfg.observability.enabled = true;
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    client.chat("hello").unwrap();
    cluster.quiesce();

    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let origin = scrape_trace(&pool, cluster.nodes[0].api_addr());
    let peer = scrape_trace(&pool, cluster.nodes[1].api_addr());
    let turn_trace = origin
        .iter()
        .find(|(_, name, _, _)| name == "turn")
        .map(|(_, _, tid, _)| tid.clone())
        .expect("origin records the turn root");
    assert!(
        peer.iter()
            .any(|(_, name, tid, _)| name == "repl_apply" && *tid == turn_trace),
        "peer must record the replication apply under the turn's trace: {peer:?}"
    );
}

/// Stub replication peer that records every request it receives.
#[allow(clippy::type_complexity)]
fn capture_server() -> (Server, Arc<Mutex<Vec<(String, BTreeMap<String, String>, Vec<u8>)>>>) {
    let seen: Arc<Mutex<Vec<(String, BTreeMap<String, String>, Vec<u8>)>>> =
        Arc::new(Mutex::new(Vec::new()));
    let sink = seen.clone();
    let server = Server::serve_with(
        0,
        LinkModel::ideal(),
        ServerLimits::default(),
        Arc::new(move |req: &HttpRequest| {
            sink.lock().unwrap().push((
                req.path.clone(),
                req.headers.clone(),
                req.body.clone(),
            ));
            Response::json("{\"ok\":true}")
        }),
    )
    .unwrap();
    (server, seen)
}

#[test]
fn observability_off_replication_is_byte_identical_to_seed() {
    // A default-config node (observability off — the shipped default)
    // pushing to a captured peer must emit EXACTLY the seed's request:
    // the deterministic `post_json` framing with content-type and
    // content-length and nothing else. A trace header here would change
    // every byte count Fig 5 plots.
    let (server, seen) = capture_server();
    let node = KvNode::start(
        "origin",
        KvConfig {
            peer_link: LinkModel::ideal(),
            ..KvConfig::default()
        },
    )
    .unwrap();
    node.create_keygroup(MODEL);
    node.add_peer(MODEL, server.addr);
    node.put(MODEL, "u1/s1", "doc-v1".to_string(), 1).unwrap();
    node.quiesce();

    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while seen.lock().unwrap().is_empty() {
        assert!(std::time::Instant::now() < deadline, "push must arrive");
        std::thread::sleep(Duration::from_millis(5));
    }
    let captured = seen.lock().unwrap();
    for (path, headers, body) in captured.iter() {
        assert_eq!(path, "/replicate");
        let keys: Vec<&str> = headers.keys().map(String::as_str).collect();
        assert_eq!(
            keys,
            ["content-length", "content-type"],
            "observability-off push must carry the seed's exact header set"
        );
        assert_eq!(
            headers.get("content-length").unwrap(),
            &body.len().to_string()
        );
        // Reconstructing the request from what arrived reproduces the
        // seed serializer's bytes — nothing rode the wire beyond them.
        let reconstructed =
            HttpRequest::post_json(path, std::str::from_utf8(body).unwrap()).to_bytes();
        let resent = discedge::http::Request {
            method: "POST".into(),
            path: path.clone(),
            headers: headers.clone(),
            body: body.clone(),
        }
        .to_bytes();
        assert_eq!(resent, reconstructed, "wire framing must match the seed");
    }
}

#[test]
fn traced_push_carries_the_header_and_untraced_does_not() {
    // Same node, observability ENABLED: a push replicated outside any
    // turn still carries no header (nothing to stitch to), while a push
    // made under an active trace carries exactly one `x-pallas-trace`.
    let (server, seen) = capture_server();
    let obs_cfg = discedge::obs::ObservabilityConfig {
        enabled: true,
        ..Default::default()
    };
    let node = KvNode::start(
        "origin",
        KvConfig {
            peer_link: LinkModel::ideal(),
            obs: obs::Obs::new("origin", &obs_cfg),
            ..KvConfig::default()
        },
    )
    .unwrap();
    node.create_keygroup(MODEL);
    node.add_peer(MODEL, server.addr);

    node.put(MODEL, "u1/s1", "v1".to_string(), 1).unwrap();
    node.quiesce();
    let ctx = node.obs().begin_trace().expect("enabled node originates");
    {
        let _g = obs::set_current(Some(ctx));
        node.put(MODEL, "u1/s1", "v2".to_string(), 2).unwrap();
    }
    node.quiesce();

    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while seen.lock().unwrap().len() < 2 {
        assert!(std::time::Instant::now() < deadline, "both pushes must arrive");
        std::thread::sleep(Duration::from_millis(5));
    }
    let captured = seen.lock().unwrap();
    let untraced = &captured[0].1;
    assert!(
        !untraced.contains_key(obs::TRACE_HEADER),
        "no active trace -> no header, even when enabled"
    );
    let traced = &captured[1].1;
    let header = traced
        .get(obs::TRACE_HEADER)
        .expect("traced push must carry the trace header");
    let decoded = obs::TraceCtx::decode(header).expect("header must round-trip");
    assert_eq!(decoded.trace_id, ctx.trace_id, "same trace across the wire");
}
