//! Integration tests for the PJRT runtime against real AOT artifacts.
//!
//! These need `make artifacts` to have produced `artifacts/` (production
//! model) — they are skipped with a notice when artifacts are absent, so
//! `cargo test` stays green on a fresh checkout. The tiny-model round-trip
//! regenerates its own artifacts if a python interpreter is available.

use std::path::{Path, PathBuf};

use discedge::llm::Engine;
use discedge::runtime::ModelRuntime;

fn artifacts_dir() -> PathBuf {
    std::env::var_os("DISCEDGE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Artifacts present AND the PJRT runtime compiled in (`--features pjrt`).
fn have_artifacts(dir: &Path) -> bool {
    if !discedge::runtime::pjrt_available() {
        eprintln!("skipping: built without the `pjrt` feature");
        return false;
    }
    dir.join("model_meta.json").exists() && dir.join("init.hlo.txt").exists()
}

#[test]
fn runtime_generates_deterministically() {
    let dir = artifacts_dir();
    if !have_artifacts(&dir) {
        eprintln!("skipping: no artifacts in {} (run `make artifacts`)", dir.display());
        return;
    }
    let rt = ModelRuntime::load(&dir).expect("artifacts must load");
    assert!(rt.weight_count() > 0);
    let meta = rt.meta().clone();

    let n_in = meta.buckets[0] - 4;
    let max_new = meta.max_new.min(16);
    let input: Vec<u32> = (1..=n_in as u32)
        .map(|i| (i * 7) % meta.vocab_size as u32)
        .collect();
    let a = rt.generate(&input, max_new, u32::MAX).unwrap();
    let b = rt.generate(&input, max_new, u32::MAX).unwrap();
    assert_eq!(a.ids, b.ids, "same input, same output (temp 0)");
    assert_eq!(a.ids.len(), max_new, "no stop id -> exactly max_new tokens");
    assert!(a.ids.iter().all(|&t| (t as usize) < meta.vocab_size));

    // Different context -> (almost surely) different continuation.
    let mut other = input.clone();
    other[0] = (other[0] + 1) % meta.vocab_size as u32;
    let c = rt.generate(&other, 16, u32::MAX).unwrap();
    assert_eq!(a.bucket, c.bucket);

    // Bucket selection: longer input uses a larger bucket.
    let long: Vec<u32> = (0..(meta.buckets[0] + 1))
        .map(|i| (i % meta.vocab_size) as u32)
        .collect();
    let d = rt.generate(&long, 4.min(meta.max_new), u32::MAX).unwrap();
    assert_eq!(d.bucket, meta.buckets[1]);
    assert_eq!(d.ids.len(), 4.min(meta.max_new));
}

#[test]
fn generation_extends_prefix_consistently() {
    // Greedy decoding from context C, then re-running with C + first
    // generated token must reproduce the remaining tokens: the cache
    // update path and the prefill path agree.
    let dir = artifacts_dir();
    if !have_artifacts(&dir) {
        eprintln!("skipping: no artifacts in {}", dir.display());
        return;
    }
    let rt = ModelRuntime::load(&dir).unwrap();
    let meta = rt.meta().clone();
    let n_in = meta.buckets[0] - 4;
    let n_gen = meta.max_new.min(8);
    let input: Vec<u32> = (5..(5 + n_in as u32))
        .map(|i| (i * 13) % meta.vocab_size as u32)
        .collect();
    let full = rt.generate(&input, n_gen, u32::MAX).unwrap();
    assert_eq!(full.ids.len(), n_gen);

    let mut extended = input.clone();
    extended.push(full.ids[0]);
    let rest = rt.generate(&extended, n_gen - 1, u32::MAX).unwrap();
    assert_eq!(&full.ids[1..], &rest.ids[..], "prefill/decode disagree");
}

#[test]
fn pjrt_engine_thread_handle() {
    let dir = artifacts_dir();
    if !have_artifacts(&dir) {
        eprintln!("skipping: no artifacts in {}", dir.display());
        return;
    }
    let engine = discedge::llm::PjrtEngine::load(
        "discedge/tiny-chat",
        &dir,
        discedge::config::GenerationConfig::default(),
    )
    .unwrap();
    // Callable from multiple threads (requests serialize on the engine
    // thread).
    let engine = std::sync::Arc::new(engine);
    let mut handles = Vec::new();
    for t in 0..3u32 {
        let e = engine.clone();
        handles.push(std::thread::spawn(move || {
            let input = vec![t + 1, t + 2, t + 3, t + 4];
            e.generate(&input, 4, u32::MAX).unwrap().ids
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap().len(), 4);
    }
}
