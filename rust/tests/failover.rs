//! Failover integration: kill a replica mid-conversation and verify that
//! (a) no committed turn is lost, (b) writes during the outage are parked
//! as hints instead of dropped, (c) the detector prunes the dead node
//! from placement (epoch bump) so later writes skip it, (d) hints replay
//! on restart and the fleet converges byte-for-byte with an identical
//! no-failure run, and (e) membership with zero failures produces
//! exactly the same replication wire traffic as a membership-less fleet.

use std::time::{Duration, Instant};

use discedge::client::{Client, MobilityPolicy};
use discedge::cluster::NodeState;
use discedge::config::{ClusterConfig, ContextMode};
use discedge::server::EdgeCluster;

const MODEL: &str = "discedge/tiny-chat";

fn fleet(n: usize, rf: Option<usize>, membership: bool) -> EdgeCluster {
    let mut cfg = ClusterConfig::mock_fleet(n, rf);
    if membership {
        cfg.enable_fast_membership();
        // A wider down-after keeps the detection window comfortably
        // behind the outage-window turns even on a loaded CI host, so
        // the "writes during the outage are hinted" assertions observe
        // the pre-detection path deterministically.
        cfg.membership.down_after = Duration::from_millis(400);
        // Fail fast during the outage window so hinting (not retrying)
        // carries the test.
        cfg.replication.max_attempts = 2;
        cfg.replication.retry_backoff = Duration::from_millis(1);
    }
    EdgeCluster::launch(cfg).unwrap()
}

fn sticky_client(cluster: &EdgeCluster) -> Client {
    Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8)
}

/// Drive turns `[from, to)` with deterministic prompts; every turn must
/// succeed (no committed turn lost / no failed request).
fn run_turns(cluster: &EdgeCluster, client: &mut Client, from: usize, to: usize) {
    for t in from..to {
        client
            .chat(&format!("turn {t}: tell me about robots"))
            .unwrap_or_else(|e| panic!("turn {t} failed: {e}"));
        cluster.quiesce();
    }
}

fn wait_for<T>(mut f: impl FnMut() -> Option<T>, timeout: Duration) -> Option<T> {
    let start = Instant::now();
    while start.elapsed() < timeout {
        if let Some(v) = f() {
            return Some(v);
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    None
}

#[test]
fn killed_replica_loses_no_turn_and_hints_replay_on_restart() {
    let mut cluster = fleet(3, Some(2), true);
    let view = cluster.membership().unwrap().clone();
    let mut client = sticky_client(&cluster);

    // Turns 1-3 with the full fleet.
    run_turns(&cluster, &mut client, 1, 4);
    let (user, session) = client.session();
    let key = format!("{}/{}", user.unwrap(), session.unwrap());

    // Kill a home replica of the session that is not the serving node.
    let placement = cluster.current_placement().unwrap();
    let replicas = placement.replicas(MODEL, &key);
    assert_eq!(replicas.len(), 2);
    let victim = replicas
        .iter()
        .map(|(name, _)| name.clone())
        .find(|name| name != "edge-0")
        .expect("rf=2 over 3 nodes: some home replica is not edge-0");
    let victim_cfg = cluster.kill_node(&victim).expect("victim config");
    // Give the severed listener a beat to finish tearing down.
    std::thread::sleep(Duration::from_millis(30));

    // Turns 4-5 during the outage: the serving node has the context
    // locally, so the conversation continues; its pushes to the dead
    // replica park as hints (never as drops).
    run_turns(&cluster, &mut client, 4, 6);
    let edge0 = cluster.node("edge-0").unwrap();
    assert!(
        edge0.kv.hints_queued() >= 1,
        "outage-window writes must be parked as hints"
    );
    assert_eq!(
        edge0.kv.repl_dropped_total(),
        0,
        "hinted writes must not count as drops"
    );

    // The detector declares the victim down and swaps an epoch-stamped
    // placement that excludes it.
    assert!(
        view.wait_for_state(&victim, NodeState::Down, Duration::from_secs(10)),
        "victim must be detected down"
    );
    let down_epoch = view.epoch();
    let pruned = wait_for(
        || {
            cluster
                .current_placement()
                .filter(|p| p.epoch() >= down_epoch)
        },
        Duration::from_secs(5),
    )
    .expect("placement swap must follow the epoch bump");
    assert!(
        !pruned.replicas(MODEL, &key).iter().any(|(n, _)| n == &victim),
        "down node must leave the preference list"
    );

    // Turns 6-7 while down: writes go to surviving replicas only.
    run_turns(&cluster, &mut client, 6, 8);

    // Restart the victim (same name, fresh ports): rejoin bumps the
    // epoch, restores it to placement, and replays the parked hints.
    cluster.add_node(victim_cfg).unwrap();
    assert!(view.wait_for_state(&victim, NodeState::Alive, Duration::from_secs(10)));
    let restarted = cluster.node(&victim).unwrap();
    let replayed = wait_for(
        || restarted.kv.get(MODEL, &key).filter(|e| e.version >= 5),
        Duration::from_secs(10),
    )
    .expect("hint replay must restore the outage-window turns");
    assert!(replayed.version >= 5);
    let edge0 = cluster.node("edge-0").unwrap();
    assert!(edge0.kv.hints_replayed() >= 1, "hints must replay on rejoin");
    assert_eq!(edge0.kv.hints_dropped(), 0);

    // One more turn after recovery: the write lands on the original
    // preference list again and closes any gap from the down window.
    run_turns(&cluster, &mut client, 8, 9);

    // Byte-for-byte convergence with an identical run that never saw a
    // failure (same node names => same ids; deterministic mock engine).
    let control = fleet(3, Some(2), true);
    let mut control_client = sticky_client(&control);
    run_turns(&control, &mut control_client, 1, 9);
    let (cu, cs) = control_client.session();
    assert_eq!(key, format!("{}/{}", cu.unwrap(), cs.unwrap()));
    let expected = control
        .node("edge-0")
        .unwrap()
        .kv
        .get(MODEL, &key)
        .expect("control holds the session");
    assert_eq!(expected.version, 8);

    let final_placement = cluster.current_placement().unwrap();
    for (name, _) in final_placement.replicas(MODEL, &key) {
        let entry = wait_for(
            || {
                cluster
                    .node(&name)
                    .unwrap()
                    .kv
                    .get(MODEL, &key)
                    .filter(|e| e.version == expected.version)
            },
            Duration::from_secs(5),
        )
        .unwrap_or_else(|| panic!("replica {name} must reach v{}", expected.version));
        assert_eq!(
            entry.value, expected.value,
            "replica {name} diverged from the no-failure run"
        );
    }
    let served = cluster.node("edge-0").unwrap().kv.get(MODEL, &key).unwrap();
    assert_eq!(served.value, expected.value, "serving node diverged");
}

#[test]
fn membership_with_zero_failures_matches_default_wire_traffic() {
    // Same fleet, same conversation, with and without membership: the
    // replication byte counters must be identical on every node —
    // heartbeats ride dedicated listeners and meters, and a no-failure
    // placement rebuild sequence ends at the same ring.
    fn run(membership: bool) -> Vec<(String, u64, u64)> {
        let cluster = fleet(3, Some(2), membership);
        let mut client = sticky_client(&cluster);
        run_turns(&cluster, &mut client, 1, 6);
        cluster.quiesce();
        cluster
            .nodes
            .iter()
            .map(|n| (n.name.clone(), n.kv.sync_rx_bytes(), n.kv.sync_tx_bytes()))
            .collect()
    }
    let base = run(false);
    let with_membership = run(true);
    assert_eq!(
        base, with_membership,
        "membership with zero failures must not change replication traffic"
    );
}

#[test]
fn membership_fleet_reports_cluster_gauges() {
    let cluster = fleet(2, Some(2), true);
    let view = cluster.membership().unwrap();
    assert_eq!(view.epoch(), 2, "one bump per launch join");
    assert_eq!(view.alive_count(), 2);
    // Zero failures: nothing hinted, nothing dropped.
    let mut client = sticky_client(&cluster);
    run_turns(&cluster, &mut client, 1, 3);
    for node in &cluster.nodes {
        assert_eq!(node.kv.hints_queued(), 0);
        assert_eq!(node.kv.repl_dropped_total(), 0);
    }
}

#[test]
fn replicate_to_all_fleet_hints_and_replays_without_a_ring() {
    // Membership also protects the seed's replicate-to-all wiring: the
    // peers list is fixed, so an outage parks every push and a rejoin
    // replays them to the restarted listener.
    let mut cluster = fleet(2, None, true);
    let view = cluster.membership().unwrap().clone();
    let mut client = sticky_client(&cluster);
    run_turns(&cluster, &mut client, 1, 3);
    let (user, session) = client.session();
    let key = format!("{}/{}", user.unwrap(), session.unwrap());

    let victim_cfg = cluster.kill_node("edge-1").expect("edge-1 exists");
    std::thread::sleep(Duration::from_millis(30));
    run_turns(&cluster, &mut client, 3, 5);
    let edge0 = cluster.node("edge-0").unwrap();
    assert!(edge0.kv.hints_queued() >= 1);
    assert_eq!(edge0.kv.repl_dropped_total(), 0);
    assert!(view.wait_for_state("edge-1", NodeState::Down, Duration::from_secs(10)));

    cluster.add_node(victim_cfg).unwrap();
    let restarted = cluster.node("edge-1").unwrap();
    let entry = wait_for(
        || restarted.kv.get(MODEL, &key).filter(|e| e.version >= 4),
        Duration::from_secs(10),
    )
    .expect("replayed hints must reach the restarted replicate-to-all peer");
    assert!(entry.version >= 4);
    // Post-restart writes flow over the re-addressed subscription.
    run_turns(&cluster, &mut client, 5, 6);
    wait_for(
        || restarted.kv.get(MODEL, &key).filter(|e| e.version == 5),
        Duration::from_secs(5),
    )
    .expect("re-addressed peer must receive live writes");
}
