//! Integration tests for delta-append replication: a fleet running
//! `delta_sync` must converge to byte-for-byte the same stored state as a
//! full-state fleet, including across ring sharding and roaming (where
//! version gaps force the full-state `/fetch` fallback).

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode};
use discedge::http::Request as HttpRequest;
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::server::EdgeCluster;
use discedge::transport::PeerPool;

const MODEL: &str = "discedge/tiny-chat";

fn fleet(n: usize, replication_factor: Option<usize>, delta_sync: bool) -> EdgeCluster {
    let mut cfg = ClusterConfig::mock_fleet(n, replication_factor);
    cfg.replication.delta_sync = delta_sync;
    EdgeCluster::launch(cfg).unwrap()
}

/// Drive one 5-turn session (fixed ids, sticky to node 0) and quiesce
/// between turns. Returns the session key.
fn drive_session(cluster: &EdgeCluster) -> String {
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(12);
    for t in 0..5 {
        client
            .chat(&format!("turn {t}: tell me about mapping"))
            .unwrap();
        cluster.quiesce();
    }
    let (user, sess) = client.session();
    format!("{}/{}", user.unwrap(), sess.unwrap())
}

#[test]
fn sharded_delta_fleet_converges_to_full_state_result() {
    // Same fleet shape, same conversation, both sync modes. Placement and
    // the mock engine are deterministic, so every replica must end up with
    // byte-for-byte identical documents — except the session ids differ
    // per cluster, so compare via each cluster's own key.
    let full = fleet(4, Some(2), false);
    let delta = fleet(4, Some(2), true);
    let full_key = drive_session(&full);
    let delta_key = drive_session(&delta);

    let doc_of = |cluster: &EdgeCluster, key: &str| -> Vec<(String, String, u64)> {
        let mut held: Vec<(String, String, u64)> = cluster
            .nodes
            .iter()
            .filter_map(|n| {
                n.kv
                    .get(MODEL, key)
                    .map(|e| (n.name.clone(), e.value, e.version))
            })
            .collect();
        held.sort();
        held
    };
    let full_docs = doc_of(&full, &full_key);
    let delta_docs = doc_of(&delta, &delta_key);

    // Every replica inside one cluster agrees with its writer.
    for docs in [&full_docs, &delta_docs] {
        assert!(!docs.is_empty());
        for (name, doc, ver) in docs.iter() {
            assert_eq!(*ver, 5, "{name} must be at the final turn");
            assert_eq!(doc, &docs[0].1, "{name} diverged");
        }
    }
    // And the two sync modes agree with each other, apart from the session
    // ids embedded nowhere in the doc (documents hold only tokens+turns).
    assert_eq!(
        full_docs[0].1, delta_docs[0].1,
        "delta sync must reproduce the full-state document"
    );
    // The delta cluster actually exercised the delta path.
    let applies: u64 = delta.nodes.iter().map(|n| n.kv.delta_applies()).sum();
    assert!(applies >= 4, "turns 2..=5 should apply as deltas ({applies})");
    let full_applies: u64 = full.nodes.iter().map(|n| n.kv.delta_applies()).sum();
    assert_eq!(full_applies, 0, "full-state cluster must not see deltas");
}

#[test]
fn roaming_with_delta_sync_satisfies_the_turn_protocol() {
    // Roaming across a sharded delta fleet: non-contiguous replicas
    // recover through the /fetch fallback and the turn-counter protocol
    // holds on every turn.
    let cluster = fleet(4, Some(2), true);
    let mut client = Client::connect(
        cluster.endpoints(),
        MobilityPolicy::Alternate {
            nodes: vec![0, 1, 2, 3],
            every: 2,
        },
    )
    .with_mode(ContextMode::Tokenized)
    .with_model(MODEL)
    .with_max_tokens(8);
    let mut prev = 0usize;
    let scenario = discedge::workload::Scenario::robotics_9turn();
    for turn in scenario.turns() {
        let r = client.chat(&turn.prompt).unwrap();
        assert!(
            r.response.prefill_tokens > prev,
            "context must grow on turn {}",
            turn.number
        );
        prev = r.response.prefill_tokens;
        cluster.quiesce();
    }
}

#[test]
fn raw_mode_sessions_replicate_as_text_deltas() {
    // The raw-text baseline is append-only too; delta sync must keep its
    // cross-node handover working.
    let cluster = fleet(2, None, true);
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Raw)
        .with_model(MODEL)
        .with_max_tokens(8);
    let mut prev = 0usize;
    for t in 0..3 {
        let r = client.chat(&format!("raw turn {t}")).unwrap();
        assert!(r.response.prefill_tokens > prev);
        prev = r.response.prefill_tokens;
        cluster.quiesce();
    }
    let (user, sess) = client.session();
    let key = format!("{}/{}", user.unwrap(), sess.unwrap());
    let a = cluster.nodes[0].kv.get(MODEL, &key).unwrap();
    let b = cluster.nodes[1].kv.get(MODEL, &key).unwrap();
    assert_eq!(a.version, 3);
    assert_eq!(a.value, b.value, "raw docs must converge over deltas");
    assert!(cluster.nodes[1].kv.delta_applies() >= 2);
}

#[test]
fn metrics_expose_delta_counters() {
    let cluster = fleet(2, None, true);
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    client.chat("one").unwrap();
    client.chat("two").unwrap();
    cluster.quiesce();
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let m = pool
        .round_trip(cluster.nodes[1].api_addr(), &HttpRequest::get("/metrics"))
        .unwrap();
    let body = m.body_str().unwrap();
    assert!(body.contains("kv_delta_applies 1"), "{body}");
    assert!(body.contains("kv_delta_fallbacks"), "{body}");
}
