//! Transport-layer integration: the three pins of the pooled-connection
//! refactor.
//!
//! (a) **Wire-format neutrality**: a steady-state fleet's metered
//!     replication bytes are identical whether connections are pooled or
//!     opened per request (the seed's behaviour) — pooling changes the
//!     connect count, never the bytes the figures plot.
//!
//! (b) **Bounded server**: with more concurrent keep-alive clients than
//!     `transport.max_server_conns`, every client is either served or
//!     answered a clean `503`; nothing hangs and the live-connection
//!     count never exceeds the budget.
//!
//! (c) **Client recovery** (the `client.rs` wedge regression): a cached
//!     client connection killed under the client — here by the server's
//!     idle reaper — used to wedge that endpoint forever; the pool
//!     transparently reconnects on the next turn.

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Duration;

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode};
use discedge::http::{read_response, Request, Response, Server, ServerLimits};
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::server::EdgeCluster;
use discedge::transport::PeerPool;

const MODEL: &str = "discedge/tiny-chat";

fn sticky_client(cluster: &EdgeCluster) -> Client {
    Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8)
}

#[test]
fn pooled_fleet_wire_bytes_match_connect_per_request_fleet() {
    // Same fleet, same conversation, with pooling on (default) and off
    // (`max_idle_per_peer = 0`, a fresh connect per request — the
    // seed's behaviour on every path): the replication byte counters
    // must be identical on every node, because pooling is not allowed
    // to change a single byte on the wire.
    fn run(pooled: bool) -> (Vec<(String, u64, u64)>, u64) {
        let mut cfg = ClusterConfig::mock_fleet(3, Some(2));
        if !pooled {
            cfg.transport.max_idle_per_peer = 0;
        }
        let cluster = EdgeCluster::launch(cfg).unwrap();
        let mut client = sticky_client(&cluster);
        for t in 1..6 {
            client
                .chat(&format!("turn {t}: tell me about robots"))
                .unwrap_or_else(|e| panic!("turn {t} failed: {e}"));
            cluster.quiesce();
        }
        let bytes = cluster
            .nodes
            .iter()
            .map(|n| (n.name.clone(), n.kv.sync_rx_bytes(), n.kv.sync_tx_bytes()))
            .collect();
        let opened = cluster
            .nodes
            .iter()
            .map(|n| n.kv.net_stats().opened.get())
            .sum();
        (bytes, opened)
    }
    let (pooled_bytes, pooled_opened) = run(true);
    let (fresh_bytes, fresh_opened) = run(false);
    assert_eq!(
        pooled_bytes, fresh_bytes,
        "pooling must not change replication wire traffic"
    );
    assert!(
        pooled_opened < fresh_opened,
        "pooling must reduce connects ({pooled_opened} vs {fresh_opened})"
    );
}

#[test]
fn server_saturation_serves_or_503s_within_budget() {
    let limits = ServerLimits {
        max_conns: 2,
        ..ServerLimits::default()
    };
    let server = Server::serve_with(
        0,
        LinkModel::ideal(),
        limits,
        std::sync::Arc::new(|_req: &Request| Response::json("{\"ok\":true}")),
    )
    .unwrap();
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());

    // Fill the budget with live keep-alive clients...
    let mut held = Vec::new();
    for _ in 0..2 {
        let mut conn = pool.checkout(server.addr).unwrap();
        assert_eq!(conn.round_trip(&Request::get("/x")).unwrap().status, 200);
        held.push(conn);
    }
    assert_eq!(server.live_conns(), 2);

    // ...then pile more clients on top: each is answered an immediate,
    // clean 503 (sent on accept, before any request — a read-first
    // client observes it deterministically), and the budget holds.
    for _ in 0..3 {
        let raw = TcpStream::connect(server.addr).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(raw);
        let resp = read_response(&mut reader).expect("refused client must get a response");
        assert_eq!(resp.status, 503);
        assert!(server.live_conns() <= 2, "budget must never be exceeded");
    }

    // Releasing the held clients — and their pool, so the sockets
    // actually close instead of idling client-side — frees the slots:
    // a brand-new client is served again (the server reaps finished
    // threads on its next accept, so poll briefly).
    drop(held);
    drop(pool);
    let fresh = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        match fresh.round_trip(server.addr, &Request::get("/x")) {
            Ok(resp) if resp.status == 200 => break,
            _ if std::time::Instant::now() > deadline => {
                panic!("freed budget slots must re-admit clients")
            }
            _ => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    assert!(server.live_conns() <= 2);
}

#[test]
fn client_recovers_after_cached_connection_dies() {
    // Regression for the client.rs wedge: the cached per-endpoint
    // connection was inserted once and never reopened after an error,
    // so one broken socket cut the client off from that node forever.
    // Kill the cached socket (the server's idle reaper severs it), then
    // retry. `/completion` is not replay-safe, so the client pool does
    // NOT transparently re-send — the dead socket surfaces as one
    // failed turn (the seed's retry-with-same-counter contract) and is
    // discarded, and the retry reconnects instead of wedging.
    let mut cfg = ClusterConfig::mock_fleet(1, None);
    cfg.transport.idle_timeout = Duration::from_millis(50);
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let mut client = sticky_client(&cluster);

    client.chat("turn 1: hello").expect("first turn");
    assert_eq!(client.net_stats().opened.get(), 1);

    // Idle well past the reap bound: the server closes the socket the
    // client still holds pooled.
    std::thread::sleep(Duration::from_millis(300));

    // The dead keep-alive costs exactly one failed attempt (the turn
    // counter does not advance)...
    assert!(
        client.chat("turn 2: still there?").is_err(),
        "dead socket surfaces as one failed turn, never silently re-sent"
    );
    assert_eq!(client.turns_done(), 1);
    // ...and the caller's retry reconnects. Pre-fix, this retry — and
    // every later one — failed on the same cached dead socket forever.
    let r2 = client.chat("turn 2: still there?").expect("retry must reconnect");
    assert_eq!(r2.response.turn, 2);
    let r3 = client.chat("turn 3: good").expect("endpoint must not wedge");
    assert_eq!(r3.response.turn, 3);
    assert!(
        client.net_stats().opened.get() >= 2,
        "recovery must have opened a fresh connection"
    );
}
