//! Integration tests for consistent-hash session sharding: bounded
//! replication pushes each write to exactly the session's preference list,
//! the default config reproduces the seed's replicate-to-all behaviour,
//! and a node outside the preference list serves a roaming session via
//! remote fetch + read-repair (the mobility path).

use std::net::SocketAddr;

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode};
use discedge::context::{CompletionRequest, CompletionResponse};
use discedge::http::Request as HttpRequest;
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::server::EdgeCluster;
use discedge::transport::PeerPool;

const MODEL: &str = "discedge/tiny-chat";

fn fleet(n: usize, replication_factor: Option<usize>) -> EdgeCluster {
    // mock_fleet already selects the zero-cost mock engine + ideal links.
    EdgeCluster::launch(ClusterConfig::mock_fleet(n, replication_factor)).unwrap()
}

fn post(addr: SocketAddr, req: &CompletionRequest) -> CompletionResponse {
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let resp = pool
        .round_trip(addr, &HttpRequest::post_json("/completion", &req.to_json()))
        .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str().unwrap_or("?"));
    CompletionResponse::from_json(resp.body_str().unwrap()).unwrap()
}

#[test]
fn bounded_replication_pushes_to_exactly_n_replicas() {
    let cluster = fleet(4, Some(2));
    let placement = cluster.placement.clone().expect("sharded cluster has placement");
    let mut expected_targets = 0u64;
    let mut sessions = Vec::new();
    for s in 0..10 {
        let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
            .with_mode(ContextMode::Tokenized)
            .with_model(MODEL)
            .with_max_tokens(8);
        client.chat(&format!("question one of session {s}")).unwrap();
        client.chat("question two").unwrap();
        cluster.quiesce();
        let (user, sess) = client.session();
        let key = format!("{}/{}", user.unwrap(), sess.unwrap());
        let replicas = placement.replicas(MODEL, &key);
        assert_eq!(replicas.len(), 2, "preference list must have exactly N nodes");
        // Two writes per session; each targets the preference list minus
        // the writer (edge-0) when the writer is itself a replica.
        expected_targets += 2 * replicas.iter().filter(|(n, _)| n != "edge-0").count() as u64;
        sessions.push((key, replicas));
    }
    assert_eq!(
        cluster.nodes[0].kv.push_targets(),
        expected_targets,
        "every write must be pushed to exactly its home replicas"
    );
    // Entries live exactly on the preference list (plus the writer's own
    // local replica, which doubles as a cache).
    for (key, replicas) in &sessions {
        assert!(cluster.nodes[0].kv.get(MODEL, key).is_some());
        for node in cluster.nodes.iter().skip(1) {
            let is_replica = replicas.iter().any(|(n, _)| n == &node.name);
            assert_eq!(
                node.kv.get(MODEL, key).is_some(),
                is_replica,
                "{} holding {key} (replica: {is_replica})",
                node.name
            );
        }
    }
}

#[test]
fn default_config_replicates_to_all() {
    let cluster = fleet(4, None);
    assert!(cluster.placement.is_none(), "default wiring must not build a ring");
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    client.chat("hello").unwrap();
    client.chat("more").unwrap();
    cluster.quiesce();
    let (user, sess) = client.session();
    let key = format!("{}/{}", user.unwrap(), sess.unwrap());
    for node in &cluster.nodes {
        assert!(node.kv.get(MODEL, &key).is_some(), "{} must hold the session", node.name);
        assert_eq!(node.kv.remote_fetches(), 0);
    }
    // Two writes, each broadcast to the 3 subscribed peers.
    assert_eq!(cluster.nodes[0].kv.push_targets(), 6);
}

#[test]
fn replication_factor_equal_to_fleet_matches_broadcast() {
    let cluster = fleet(4, Some(4));
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    client.chat("hello").unwrap();
    cluster.quiesce();
    let (user, sess) = client.session();
    let key = format!("{}/{}", user.unwrap(), sess.unwrap());
    for node in &cluster.nodes {
        assert!(node.kv.get(MODEL, &key).is_some());
    }
    // N = fleet size: the writer is always on the list, so one write
    // pushes to the other 3 nodes — identical to replicate-to-all.
    assert_eq!(cluster.nodes[0].kv.push_targets(), 3);
}

#[test]
fn roaming_session_is_served_by_non_replica_via_read_repair() {
    let cluster = fleet(4, Some(1));
    let placement = cluster.placement.clone().unwrap();
    // Choose a session homed on edge-1, then serve it from edge-0 and
    // edge-2 — both outside the preference list.
    let (user, sess) = (0..)
        .map(|i| (format!("u-roam-{i}"), format!("s-roam-{i}")))
        .find(|(u, s)| placement.replicas(MODEL, &format!("{u}/{s}"))[0].0 == "edge-1")
        .unwrap();
    let key = format!("{user}/{sess}");

    let mut req = CompletionRequest::new(MODEL, "What is SLAM?", 1, ContextMode::Tokenized);
    req.user_id = Some(user.clone());
    req.session_id = Some(sess.clone());
    let r1 = post(cluster.nodes[0].api_addr(), &req);
    cluster.quiesce();
    // The write-through half: the non-replica writer pushed to the home.
    assert!(cluster.nodes[1].kv.get(MODEL, &key).is_some(), "home replica must receive the write");
    assert!(cluster.nodes[2].kv.get(MODEL, &key).is_none());
    assert!(cluster.nodes[3].kv.get(MODEL, &key).is_none());

    // The read half: edge-2 has nothing local, fetches from the home
    // replica, read-repairs, and continues the session.
    req.turn = 2;
    req.prompt = "Tell me more".into();
    let r2 = post(cluster.nodes[2].api_addr(), &req);
    assert_eq!(r2.turn, 2);
    assert!(
        r2.prefill_tokens > r1.prefill_tokens,
        "turn 2 must see the turn-1 context ({} vs {})",
        r2.prefill_tokens,
        r1.prefill_tokens
    );
    assert!(cluster.nodes[2].kv.remote_fetches() >= 1);
    assert!(cluster.nodes[2].kv.read_repairs() >= 1);
    assert!(cluster.nodes[2].kv.get(MODEL, &key).is_some(), "read-repair must cache locally");
}

#[test]
fn placement_is_identical_across_launches() {
    // Placement must be a pure function of the membership and the knobs —
    // that is what lets every node compute preference lists independently.
    let a = fleet(4, Some(2));
    let b = fleet(4, Some(2));
    let (pa, pb) = (a.placement.clone().unwrap(), b.placement.clone().unwrap());
    for i in 0..100 {
        let key = format!("user-{i}/session-{i}");
        let ra: Vec<String> = pa.replicas(MODEL, &key).into_iter().map(|(n, _)| n).collect();
        let rb: Vec<String> = pb.replicas(MODEL, &key).into_iter().map(|(n, _)| n).collect();
        assert_eq!(ra, rb, "placement diverged for {key}");
    }
}

#[test]
fn sharded_fleet_runs_the_paper_scenario() {
    // End-to-end smoke: the 9-turn scenario with roaming across a sharded
    // fleet still satisfies the turn-counter protocol on every turn.
    let cluster = fleet(4, Some(2));
    let mut client = Client::connect(
        cluster.endpoints(),
        MobilityPolicy::Alternate { nodes: vec![0, 1, 2, 3], every: 2 },
    )
    .with_mode(ContextMode::Tokenized)
    .with_model(MODEL)
    .with_max_tokens(8);
    let mut prev = 0usize;
    let scenario = discedge::workload::Scenario::robotics_9turn();
    for turn in scenario.turns() {
        let r = client.chat(&turn.prompt).unwrap();
        assert!(r.response.prefill_tokens > prev, "context must grow on turn {}", turn.number);
        prev = r.response.prefill_tokens;
        cluster.quiesce();
    }
}
