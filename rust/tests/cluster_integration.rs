//! End-to-end integration tests over a real in-process cluster: HTTP API,
//! context modes, mobility, replication, and metric accounting. Uses the
//! mock engine (deterministic, fast); the PJRT path is covered by
//! `pjrt_integration.rs` and the examples.

use std::sync::Arc;

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode, EngineKind};
use discedge::netsim::LinkModel;
use discedge::profile::NodeProfile;
use discedge::server::EdgeCluster;
use discedge::workload::Scenario;

const MODEL: &str = "discedge/tiny-chat";

fn mock_cfg() -> ClusterConfig {
    let mut cfg = ClusterConfig::two_node_testbed();
    cfg.engine = EngineKind::Mock {
        prefill_ns_per_token: 500,
        decode_ns_per_token: 2_000,
    };
    cfg.peer_link = LinkModel::ideal();
    cfg.client_link = LinkModel::ideal();
    for n in &mut cfg.nodes {
        n.profile = NodeProfile::m2_native();
    }
    cfg
}

#[test]
fn full_scenario_tokenized_sticky() {
    let cluster = EdgeCluster::launch(mock_cfg()).unwrap();
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(32);
    let scenario = Scenario::robotics_9turn();
    let mut prev_prefill = 0;
    for turn in scenario.turns() {
        let r = client.chat(&turn.prompt).unwrap();
        assert_eq!(r.response.turn, turn.number as u64);
        assert!(!r.response.text.is_empty());
        assert!(
            r.response.prefill_tokens > prev_prefill,
            "context must grow every turn"
        );
        prev_prefill = r.response.prefill_tokens;
        cluster.quiesce(); // turn barrier, like the paper's sequential client
    }
    assert_eq!(client.turns_done(), 9);
}

#[test]
fn all_modes_agree_on_prefill_lengths() {
    // The three context modes must present identical inputs to the LLM —
    // over the real HTTP path this time.
    let run = |mode: ContextMode| -> Vec<usize> {
        let cluster = EdgeCluster::launch(mock_cfg()).unwrap();
        let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
            .with_mode(mode)
            .with_model(MODEL)
            .with_max_tokens(16);
        Scenario::robotics_9turn()
            .turns()
            .take(5)
            .map(|t| {
                let r = client.chat(&t.prompt).unwrap();
                cluster.quiesce();
                r.response.prefill_tokens
            })
            .collect()
    };
    let tokenized = run(ContextMode::Tokenized);
    let raw = run(ContextMode::Raw);
    let client_side = run(ContextMode::ClientSide);
    assert_eq!(tokenized, raw, "tokenized vs raw");
    assert_eq!(tokenized, client_side, "tokenized vs client-side");
}

#[test]
fn mobile_client_roams_with_consistent_context() {
    let cluster = EdgeCluster::launch(mock_cfg()).unwrap();
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::paper_alternate())
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(16);
    let scenario = Scenario::robotics_9turn();
    let mut nodes_seen = Vec::new();
    let mut prefills = Vec::new();
    for turn in scenario.turns() {
        let r = client.chat(&turn.prompt).unwrap();
        nodes_seen.push(r.node.clone());
        prefills.push(r.response.prefill_tokens);
        cluster.quiesce();
    }
    // Both nodes served, in the paper's schedule.
    assert_eq!(nodes_seen[0], "edge-m2");
    assert_eq!(nodes_seen[2], "edge-tx2");
    assert_eq!(nodes_seen[4], "edge-m2");
    assert_eq!(nodes_seen[6], "edge-tx2");
    // Context kept growing across handovers — nothing was lost.
    assert!(prefills.windows(2).all(|w| w[1] > w[0]), "{prefills:?}");
}

#[test]
fn client_side_requests_grow_edge_side_stay_flat() {
    // Fig 7's mechanism, end-to-end.
    let cluster = EdgeCluster::launch(mock_cfg()).unwrap();
    let run = |mode: ContextMode| -> Vec<u64> {
        let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
            .with_mode(mode)
            .with_model(MODEL)
            .with_max_tokens(64);
        Scenario::robotics_9turn()
            .turns()
            .map(|t| {
                let r = client.chat(&t.prompt).unwrap();
                cluster.quiesce();
                r.request_bytes
            })
            .collect()
    };
    let edge = run(ContextMode::Tokenized);
    let client_side = run(ContextMode::ClientSide);
    // Client-side grows monotonically and ends much larger.
    assert!(client_side.last().unwrap() > &(client_side[0] * 5));
    // Edge-side stays within a narrow band set by prompt length.
    let max = *edge.iter().max().unwrap() as f64;
    let min = *edge.iter().min().unwrap() as f64;
    assert!(max / min < 3.0, "edge-side request sizes vary too much: {edge:?}");
    assert!(client_side[8] > edge[8] * 4, "{client_side:?} vs {edge:?}");
}

#[test]
fn sync_traffic_only_between_keygroup_peers() {
    // Third node serving a *different* model must see no session traffic.
    let mut cfg = mock_cfg();
    cfg.nodes.push(discedge::config::NodeConfig {
        name: "edge-other".into(),
        profile: NodeProfile::m2_native(),
        api_port: 0,
        kv_port: 0,
        models: vec!["other/model".into()],
    });
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(16);
    for t in Scenario::robotics_9turn().turns().take(3) {
        client.chat(&t.prompt).unwrap();
        cluster.quiesce();
    }
    assert!(cluster.node("edge-m2").unwrap().sync_bytes() > 0);
    assert_eq!(
        cluster.node("edge-other").unwrap().sync_bytes(),
        0,
        "other-model node must not receive session replication"
    );
    assert!(cluster.node("edge-other").unwrap().kv.is_empty());
}

#[test]
fn concurrent_sessions_are_isolated() {
    let cluster = Arc::new(EdgeCluster::launch(mock_cfg()).unwrap());
    let mut handles = Vec::new();
    for c in 0..4usize {
        let endpoints = cluster.endpoints();
        let cl = cluster.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(endpoints, MobilityPolicy::Sticky(c % 2))
                .with_mode(ContextMode::Tokenized)
                .with_model(MODEL)
                .with_max_tokens(8);
            let mut texts = Vec::new();
            for t in Scenario::synthetic(c as u64, 4, 6).turns() {
                let r = client.chat(&t.prompt).unwrap();
                texts.push(r.response.text);
                cl.quiesce();
            }
            (client.session().1.map(String::from), texts)
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // All sessions distinct.
    let mut session_ids: Vec<_> = results.iter().map(|(s, _)| s.clone().unwrap()).collect();
    session_ids.sort();
    session_ids.dedup();
    assert_eq!(session_ids.len(), 4);
}

#[test]
fn metrics_endpoint_reflects_requests() {
    let cluster = EdgeCluster::launch(mock_cfg()).unwrap();
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    for t in Scenario::robotics_9turn().turns().take(2) {
        client.chat(&t.prompt).unwrap();
        cluster.quiesce();
    }
    let node = &cluster.nodes[0];
    assert_eq!(node.cm.registry.counter("cm_requests_total"), 2);
    assert!(node.cm.registry.series("cm_request_s").len() == 2);
    assert!(node.kv.len() >= 1, "session stored");
}
