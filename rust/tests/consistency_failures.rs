//! Failure-injection tests for the turn-counter consistency protocol:
//! delayed replication (forcing the retry path), strict-vs-available
//! policies, dropped replication pushes, and TTL expiry of sessions.

use std::time::Duration;

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ConsistencyPolicy, ContextMode, EngineKind};
use discedge::netsim::LinkModel;
use discedge::profile::NodeProfile;
use discedge::server::EdgeCluster;

const MODEL: &str = "discedge/tiny-chat";

fn cfg_with_repl_delay(delay_ms: u64) -> ClusterConfig {
    let mut cfg = ClusterConfig::two_node_testbed();
    cfg.engine = EngineKind::Mock {
        prefill_ns_per_token: 0,
        decode_ns_per_token: 0,
    };
    cfg.peer_link = LinkModel::ideal();
    cfg.client_link = LinkModel::ideal();
    cfg.replication.delay = Duration::from_millis(delay_ms);
    for n in &mut cfg.nodes {
        n.profile = NodeProfile::m2_native();
    }
    cfg
}

/// Run two turns: turn 1 on node 0, turn 2 on node 1 (handover).
fn handover(cfg: ClusterConfig) -> discedge::Result<(u64, u64)> {
    let cluster = EdgeCluster::launch(cfg)?;
    let mut client = Client::connect(
        cluster.endpoints(),
        MobilityPolicy::Schedule(vec![0, 1]),
    )
    .with_mode(ContextMode::Tokenized)
    .with_model(MODEL)
    .with_max_tokens(8);
    let r1 = client.chat("first question")?;
    // No quiesce: replication races the handover on purpose.
    let r2 = client.chat("second question")?;
    Ok((r1.response.timings.retries, r2.response.timings.retries))
}

#[test]
fn handover_with_fast_replication_rarely_retries() {
    let (_, retries2) = handover(cfg_with_repl_delay(0)).unwrap();
    // With instant replication the CM may still race once, but within the
    // paper's bound ("never more than two retries").
    assert!(retries2 <= 3, "retries {retries2}");
}

#[test]
fn handover_with_delayed_replication_uses_retries() {
    // 15 ms delay vs 3 x 10 ms retry budget: the retry path must absorb it.
    let (_, retries2) = handover(cfg_with_repl_delay(15)).unwrap();
    assert!(
        (1..=3).contains(&retries2),
        "expected 1-3 retries, got {retries2}"
    );
}

#[test]
fn handover_beyond_retry_budget_fails_strict() {
    // 200 ms delay cannot be absorbed by 3 x 10 ms: strict -> error.
    let err = handover(cfg_with_repl_delay(200)).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("409") || msg.contains("stale"), "{msg}");
}

#[test]
fn handover_beyond_retry_budget_available_serves_stale() {
    let mut cfg = cfg_with_repl_delay(200);
    cfg.consistency.policy = ConsistencyPolicy::Available;
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let mut client = Client::connect(
        cluster.endpoints(),
        MobilityPolicy::Schedule(vec![0, 1]),
    )
    .with_mode(ContextMode::Tokenized)
    .with_model(MODEL)
    .with_max_tokens(8);
    let r1 = client.chat("first question").unwrap();
    let r2 = client.chat("second question").unwrap();
    // Served despite staleness; the stale context is a fresh/preamble one,
    // so prefill shrinks instead of growing.
    assert_eq!(r2.response.turn, 2);
    assert!(r2.response.timings.retries >= 3);
    assert!(r2.response.prefill_tokens <= r1.response.prefill_tokens + 8);
    assert_eq!(
        cluster.nodes[1].cm.registry.counter("cm_stale_served_total"),
        1
    );
}

#[test]
fn dropped_replication_is_counted_and_strict_fails() {
    let mut cfg = cfg_with_repl_delay(0);
    cfg.replication.drop_probability = 1.0;
    cfg.replication.max_attempts = 1;
    let err = handover(cfg).unwrap_err();
    assert!(err.to_string().contains("409") || err.to_string().contains("stale"));
}

#[test]
fn session_ttl_expires_context() {
    let mut cfg = cfg_with_repl_delay(0);
    cfg.session_ttl = Duration::from_millis(50);
    cfg.nodes.truncate(1);
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    client.chat("hello").unwrap();
    cluster.quiesce();
    assert!(cluster.nodes[0].kv.len() >= 1);
    std::thread::sleep(Duration::from_millis(700)); // janitor sweep interval + ttl
    assert_eq!(
        cluster.nodes[0].kv.len(),
        0,
        "expired session must be swept"
    );
    // Turn 2 now finds no context: strict policy -> consistency error.
    let err = client.chat("still there?").unwrap_err();
    assert!(err.to_string().contains("409") || err.to_string().contains("stale"));
}

#[test]
fn client_side_mode_is_immune_to_replication_failures() {
    // The baseline's one advantage: no server state, no staleness.
    let mut cfg = cfg_with_repl_delay(500);
    cfg.replication.drop_probability = 1.0;
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let mut client = Client::connect(
        cluster.endpoints(),
        MobilityPolicy::Schedule(vec![0, 1, 0, 1]),
    )
    .with_mode(ContextMode::ClientSide)
    .with_model(MODEL)
    .with_max_tokens(8);
    for p in ["q1", "q2", "q3", "q4"] {
        let r = client.chat(p).unwrap();
        assert!(!r.response.text.is_empty());
    }
}

#[test]
fn interleaved_sessions_never_cross_contexts() {
    // Two clients on the same node: turn counters and contexts are
    // per-session, so interleaving must not trip the protocol.
    let cfg = cfg_with_repl_delay(0);
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let mut a = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    let mut b = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    for i in 0..3 {
        let ra = a.chat(&format!("a question {i}")).unwrap();
        let rb = b.chat(&format!("b question {i}")).unwrap();
        assert_eq!(ra.response.turn, i + 1);
        assert_eq!(rb.response.turn, i + 1);
        cluster.quiesce();
    }
    assert_ne!(a.session().1, b.session().1);
    assert_eq!(cluster.nodes[0].kv.len(), 2, "two separate session entries");
}
