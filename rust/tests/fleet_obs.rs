//! Fleet-observability integration: the four pins of the health plane.
//!
//! (a) **Windowed stats track shifts**: after a workload shift, the
//!     windowed rate/percentile lines reflect only the recent phase,
//!     while the cumulative reservoir still smears the old one — the
//!     reason `/metrics` grows `_rate1s`/`_p50_w` lines at all.
//!
//! (b) **Lag probes see an outage**: parking pushes for a down peer
//!     drives `replication.max_lag_versions` in `/status` above zero,
//!     and hint replay on recovery brings it back to exactly zero.
//!
//! (c) **Aggregator writes rows**: a cluster launched with the fleet
//!     aggregator enabled produces a non-empty health CSV — header plus
//!     one row per node per poll.
//!
//! (d) **Wire neutrality when off**: with the shipped default config
//!     (no windows, no lag tracking, no aggregator) a replication push
//!     is byte-for-byte the seed's framing — the observability plane
//!     must be free when unused.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode};
use discedge::http::{Request as HttpRequest, Response, Server, ServerLimits};
use discedge::json::Value;
use discedge::kvstore::{KvConfig, KvNode};
use discedge::metrics::Registry;
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::obs::fleet::CSV_HEADER;
use discedge::server::EdgeCluster;
use discedge::transport::PeerPool;

const MODEL: &str = "discedge/tiny-chat";

/// Fetch and parse `GET /status` from a node's API listener.
fn status_json(pool: &PeerPool, addr: std::net::SocketAddr) -> Value {
    let r = pool.round_trip(addr, &HttpRequest::get("/status")).unwrap();
    assert_eq!(r.status, 200);
    discedge::json::parse(r.body_str().unwrap()).unwrap()
}

fn max_lag(status: &Value) -> Option<u64> {
    status
        .get("replication")
        .and_then(|r| r.get("max_lag_versions"))
        .and_then(|v| v.as_u64())
}

#[test]
fn windowed_stats_track_a_workload_shift_the_reservoir_smears() {
    // Injected clock: shift time instead of sleeping, so the assertion
    // on "the old phase aged out" is deterministic.
    let now = Arc::new(AtomicU64::new(0));
    let clock = now.clone();
    let r = Registry::new();
    r.enable_windows_with_clock(250, Arc::new(move || clock.load(Ordering::SeqCst)));

    // Fast phase: many quick requests dominate the cumulative series.
    for _ in 0..2000 {
        r.observe("cm_request_s", 0.01);
        r.incr("cm_requests_total", 1);
    }
    // The workload shifts; far enough ahead that every fast-phase
    // window has aged out of the ring.
    now.store(60_000, Ordering::SeqCst);
    for i in 0..40 {
        r.observe("cm_request_s", 1.0);
        r.incr("cm_requests_total", 1);
        // Spread the slow phase over ~1 s of windows so the 1 s rate
        // sees complete windows behind `now`.
        now.store(60_000 + i * 25, Ordering::SeqCst);
    }
    now.store(61_100, Ordering::SeqCst);

    let cumulative_p50 = r.series("cm_request_s").percentile(50.0);
    let windowed_p50 = r.window_percentile("cm_request_s", 50.0);
    assert!(cumulative_p50 < 0.05, "cumulative p50 smears: {cumulative_p50}");
    assert_eq!(windowed_p50, 1.0, "window sees only the current phase");

    // The 1 s rate reflects the slow phase (~40 events/s), not the
    // lifetime average the cumulative counter implies.
    let rate = r.window_rate1s("cm_requests_total");
    assert!((10.0..80.0).contains(&rate), "windowed rate ~40/s, got {rate}");
    let dump = r.dump();
    assert!(dump.contains("cm_request_s_p50_w 1.000000"), "{dump}");
    assert!(dump.contains("cm_requests_total_rate1s"), "{dump}");
}

#[test]
fn replication_outage_surfaces_lag_in_status_and_heals_to_zero() {
    // Two-node mock fleet, observability on (lag probes), membership on
    // (hinted handoff) with the default conservative failure-detector
    // timings so no spurious Down/Up event races the assertions.
    let mut cfg = ClusterConfig::mock_fleet(2, None);
    cfg.membership.enabled = true;
    cfg.observability.enabled = true;
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let kv0 = &cluster.nodes[0].kv;
    let peer = cluster.nodes[1].kv.replication_addr();

    // Baseline: a replicated write acks and leaves no lag.
    kv0.put(MODEL, "u1/s-lag", "v1".to_string(), 1).unwrap();
    kv0.quiesce();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let lag = max_lag(&status_json(&pool, cluster.nodes[0].api_addr()));
        if lag == Some(0) {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "baseline lag must drain: {lag:?}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Outage: the failure-detector downcall parks pushes as hints.
    // Heads advance with no acks, so the probe sees versions 2..3
    // outstanding.
    kv0.mark_peer_down(peer);
    kv0.put(MODEL, "u1/s-lag", "v2".to_string(), 2).unwrap();
    kv0.put(MODEL, "u1/s-lag", "v3".to_string(), 3).unwrap();
    kv0.quiesce();
    let status = status_json(&pool, cluster.nodes[0].api_addr());
    let lag = max_lag(&status).expect("replication section present when obs on");
    assert!(lag >= 2, "two unacked versions must show as lag, got {lag} ({status:?})");
    let keys = status
        .get("replication")
        .and_then(|r| r.get("lag_keys"))
        .and_then(|v| v.as_u64())
        .unwrap();
    assert!(keys >= 1, "the lagging key is counted");

    // Recovery: replaying the parked hints acks the outstanding
    // versions and the probe returns to exactly zero.
    kv0.mark_peer_alive(peer, peer);
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let lag = max_lag(&status_json(&pool, cluster.nodes[0].api_addr()));
        if lag == Some(0) {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "lag must heal to zero: {lag:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn fleet_aggregator_writes_health_csv_rows() {
    let name = format!("discedge-fleet-obs-{}.csv", std::process::id());
    let out = std::env::temp_dir().join(name);
    let _ = std::fs::remove_file(&out);

    let mut cfg = ClusterConfig::mock_fleet(2, None);
    cfg.observability.window_ms = 250;
    cfg.fleet.enabled = true;
    // Long period: the background poller stays quiet and the test
    // drives polls explicitly (plus the final drop-time poll).
    cfg.fleet.poll_ms = 60_000;
    cfg.fleet.out = out.clone();
    let cluster = EdgeCluster::launch(cfg).unwrap();

    // One real turn so the nodes have traffic to report.
    let mut client = Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    client.chat("hello fleet").unwrap();
    cluster.quiesce();

    let fleet = cluster.fleet().expect("fleet handle when enabled");
    let snap = fleet.aggregator().poll_once().unwrap();
    assert_eq!(snap.nodes.len(), 2, "one health row per node");
    assert_eq!(snap.unreachable, 0, "both nodes answer their status plane");
    assert!(
        snap.nodes.iter().any(|n| n.wire_bytes > 0),
        "a replicated turn leaves sync bytes: {snap:?}"
    );

    let text = std::fs::read_to_string(&out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines[0], CSV_HEADER, "header written once, first");
    assert!(lines.len() >= 3, "header + one row per node: {text}");
    let header_cols = CSV_HEADER.split(',').count();
    for row in &lines[1..] {
        assert_eq!(row.split(',').count(), header_cols, "ragged row: {row}");
    }
    assert!(lines[1..].iter().any(|l| l.contains("edge-0")));
    assert!(lines[1..].iter().any(|l| l.contains("edge-1")));

    drop(cluster);
    let _ = std::fs::remove_file(&out);
}

#[test]
fn fleet_plumbing_off_keeps_replication_byte_identical_to_seed() {
    // Same pin as the tracing suite, re-asserted against this PR's
    // plumbing: a default-config node (no windows, no lag tracker, no
    // aggregator) pushing to a captured peer emits EXACTLY the seed's
    // `post_json` framing — the probes must cost zero wire bytes when
    // off.
    type Seen = Arc<Mutex<Vec<(String, BTreeMap<String, String>, Vec<u8>)>>>;
    let seen: Seen = Arc::new(Mutex::new(Vec::new()));
    let sink = seen.clone();
    let server = Server::serve_with(
        0,
        LinkModel::ideal(),
        ServerLimits::default(),
        Arc::new(move |req: &HttpRequest| {
            sink.lock().unwrap().push((
                req.path.clone(),
                req.headers.clone(),
                req.body.clone(),
            ));
            Response::json("{\"ok\":true}")
        }),
    )
    .unwrap();

    let node = KvNode::start(
        "origin",
        KvConfig {
            peer_link: LinkModel::ideal(),
            ..KvConfig::default()
        },
    )
    .unwrap();
    assert!(!node.lag_tracking_enabled(), "default config keeps the probes off");
    node.create_keygroup(MODEL);
    node.add_peer(MODEL, server.addr);
    node.put(MODEL, "u1/s1", "doc-v1".to_string(), 1).unwrap();
    node.quiesce();

    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while seen.lock().unwrap().is_empty() {
        assert!(std::time::Instant::now() < deadline, "push must arrive");
        std::thread::sleep(Duration::from_millis(5));
    }
    let captured = seen.lock().unwrap();
    for (path, headers, body) in captured.iter() {
        assert_eq!(path, "/replicate");
        let keys: Vec<&str> = headers.keys().map(String::as_str).collect();
        assert_eq!(
            keys,
            ["content-length", "content-type"],
            "probes-off push must carry the seed's exact header set"
        );
        let reconstructed =
            HttpRequest::post_json(path, std::str::from_utf8(body).unwrap()).to_bytes();
        let resent = discedge::http::Request {
            method: "POST".into(),
            path: path.clone(),
            headers: headers.clone(),
            body: body.clone(),
        }
        .to_bytes();
        assert_eq!(resent, reconstructed, "wire framing must match the seed");
    }
}
