//! Continuous-batching integration: the scheduler must change *when*
//! tokens are computed, never *what* they are — and the streamed wire
//! format must reassemble to exactly the buffered body.
//!
//! Four pins:
//!
//! (a) **Transcript neutrality**: N concurrent clients see identical
//!     per-turn texts whether the batch scheduler is off (seed path) or
//!     on — coalescing at decode-step granularity is invisible in
//!     content, and per-session turn ordering survives concurrency.
//! (b) **Stream reassembly**: with `inference.stream`, `/completion`
//!     arrives chunked and the concatenated chunks are byte-for-byte
//!     the buffered serialization of the same response.
//! (c) **Wire neutrality when off**: the default config's response
//!     carries the seed's exact header set (no `transfer-encoding`)
//!     and the deterministic serializer's bytes.
//! (d) **Admission control**: a full queue rejects with 503 and the
//!     reject is counted on `/metrics`.

use std::time::Duration;

use discedge::client::{Client, MobilityPolicy, TurnResult};
use discedge::config::{ClusterConfig, ContextMode, EngineKind};
use discedge::context::CompletionRequest;
use discedge::http::Request as HttpRequest;
use discedge::netsim::{LinkModel, TrafficMeter};
use discedge::server::EdgeCluster;
use discedge::transport::PeerPool;

const MODEL: &str = "discedge/tiny-chat";
const CLIENTS: usize = 4;
const TURNS: u64 = 3;

/// Single mock node; `batch` turns the scheduler on, `stream` chunks
/// the responses.
fn cluster(batch: bool, stream: bool) -> EdgeCluster {
    let mut cfg = ClusterConfig::single_node_mock();
    cfg.inference.enabled = batch;
    cfg.inference.max_batch = 4;
    cfg.inference.queue_depth = 16;
    cfg.inference.stream = stream;
    EdgeCluster::launch(cfg).unwrap()
}

/// Run `CLIENTS` concurrent sessions of `TURNS` turns each; returns
/// per-client transcripts (the ordered response texts). Panics if any
/// turn breaks session ordering — the concurrency pin rides along.
fn concurrent_transcripts(cluster: &EdgeCluster) -> Vec<Vec<String>> {
    let endpoints = cluster.endpoints();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let endpoints = endpoints.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(endpoints, MobilityPolicy::Sticky(0))
                    .with_mode(ContextMode::Tokenized)
                    .with_model(MODEL)
                    .with_max_tokens(16);
                let mut texts = Vec::new();
                let mut last_prefill = 0usize;
                for t in 1..=TURNS {
                    let r: TurnResult = client
                        .chat(&format!("client {c} turn {t}: tell me about rovers"))
                        .unwrap();
                    assert_eq!(r.response.turn, t, "client {c} turn counter");
                    assert!(
                        r.response.prefill_tokens > last_prefill,
                        "client {c} turn {t}: context must accrete under concurrency \
                         ({} <= {last_prefill})",
                        r.response.prefill_tokens
                    );
                    last_prefill = r.response.prefill_tokens;
                    texts.push(r.response.text);
                }
                texts
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn batched_transcripts_match_the_sequential_seed_path() {
    let off = concurrent_transcripts(&cluster(false, false));
    let on = concurrent_transcripts(&cluster(true, false));
    assert_eq!(off, on, "batching must not change a single generated token");
    // And streaming on top changes the framing, not the text.
    let streamed = concurrent_transcripts(&cluster(true, true));
    assert_eq!(off, streamed, "streaming must not change a single generated token");
}

#[test]
fn streamed_response_reassembles_to_the_buffered_bytes() {
    let cluster = cluster(true, true);
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let addr = cluster.nodes[0].api_addr();

    let req = CompletionRequest::new(MODEL, "stream me a story", 1, ContextMode::Tokenized);
    let resp = pool
        .round_trip(addr, &HttpRequest::post_json("/completion", &req.to_json()))
        .unwrap();
    assert_eq!(resp.status, 200, "{:?}", resp.body_str());
    assert_eq!(
        resp.headers.get("transfer-encoding").map(String::as_str),
        Some("chunked"),
        "streaming on -> chunked transfer: {:?}",
        resp.headers
    );
    // The de-chunked body is exactly the buffered serializer's output:
    // parsing and re-serializing it reproduces the wire bytes.
    let body = resp.body_str().unwrap();
    let parsed = discedge::context::CompletionResponse::from_json(body).unwrap();
    assert_eq!(parsed.to_json(), body, "chunks must reassemble to the buffered body");
    assert!(!parsed.text.is_empty());
    assert_eq!(parsed.turn, 1);
}

#[test]
fn scheduler_off_completion_is_byte_identical_to_seed() {
    // Default config: no scheduler, no streaming. The response must be
    // the seed's exact wire shape — buffered, content-length framed,
    // nothing riding along that a batching-aware build would add.
    let cluster = cluster(false, false);
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let addr = cluster.nodes[0].api_addr();

    let req = CompletionRequest::new(MODEL, "plain old turn", 1, ContextMode::Tokenized);
    let resp = pool
        .round_trip(addr, &HttpRequest::post_json("/completion", &req.to_json()))
        .unwrap();
    assert_eq!(resp.status, 200, "{:?}", resp.body_str());
    let mut keys: Vec<&str> = resp.headers.keys().map(String::as_str).collect();
    keys.sort_unstable();
    assert_eq!(
        keys,
        ["content-length", "content-type"],
        "scheduler-off response must carry the seed's exact header set"
    );
    assert_eq!(
        resp.headers.get("content-length").unwrap(),
        &resp.body.len().to_string()
    );
    // Deterministic serializer: the body is exactly what re-serializing
    // the parsed response produces — the seed's bytes.
    let body = resp.body_str().unwrap();
    let parsed = discedge::context::CompletionResponse::from_json(body).unwrap();
    assert_eq!(parsed.to_json(), body, "wire body must match the seed serializer");
}

#[test]
fn full_admission_queue_rejects_with_503_and_counts_it() {
    // One-deep queue, no coalescing, a deliberately slow mock decode:
    // eight simultaneous turns cannot all fit, so some must bounce off
    // admission with 503 while the node keeps serving the rest.
    let mut cfg = ClusterConfig::single_node_mock();
    cfg.engine = EngineKind::Mock {
        prefill_ns_per_token: 0,
        decode_ns_per_token: 2_000_000,
    };
    cfg.inference.enabled = true;
    cfg.inference.max_batch = 1;
    cfg.inference.queue_depth = 1;
    let cluster = EdgeCluster::launch(cfg).unwrap();
    let endpoints = cluster.endpoints();

    let handles: Vec<_> = (0..8)
        .map(|c| {
            let endpoints = endpoints.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(endpoints, MobilityPolicy::Sticky(0))
                    .with_mode(ContextMode::Tokenized)
                    .with_model(MODEL)
                    .with_max_tokens(8);
                client.chat(&format!("burst {c}")).map(|r| r.response.text)
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let ok = results.iter().filter(|r| r.is_ok()).count();
    let rejected = results
        .iter()
        .filter(|r| matches!(r, Err(e) if e.to_string().contains("503")))
        .count();
    assert!(ok >= 1, "the node must keep serving under overload: {results:?}");
    assert!(
        rejected >= 1,
        "an 8-wide burst into a 1-deep queue must trip admission: {results:?}"
    );

    // The reject is first-class on the scrape surface.
    let pool = PeerPool::new(TrafficMeter::new(), LinkModel::ideal());
    let scrape = pool
        .round_trip(cluster.nodes[0].api_addr(), &HttpRequest::get("/metrics"))
        .unwrap();
    assert_eq!(scrape.status, 200);
    let text = scrape.body_str().unwrap();
    let counted = text
        .lines()
        .find_map(|l| l.strip_prefix("llm_admission_rejects "))
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or_else(|| panic!("llm_admission_rejects missing from scrape:\n{text}"));
    assert!(
        counted as usize >= rejected,
        "metrics must count every reject ({counted} < {rejected})"
    );

    // Rejected clients retrying after the burst drains succeed — 503 is
    // backpressure, not a wedged node.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut client = Client::connect(endpoints, MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8);
    loop {
        match client.chat("after the burst") {
            Ok(_) => break,
            Err(_) if std::time::Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => panic!("node must recover after the burst: {e}"),
        }
    }
}
