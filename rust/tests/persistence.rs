//! Persistence integration: the crash-recovery contract of the striped
//! storage engine. Pins that (a) a node hard-crashed mid-conversation
//! recovers every committed turn from its local snapshot+WAL on restart
//! and the fleet converges byte-for-byte with an uncrashed control run,
//! (b) a torn or corrupt WAL tail is detected by the per-record checksum
//! and truncated — never misapplied, (c) with `storage.enabled=false`
//! the replication wire traffic and store behaviour are byte-identical
//! to the seed (and nothing touches the disk), and (d) recovering from
//! local disk beats hint-replay-from-peers on wall clock.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use discedge::client::{Client, MobilityPolicy};
use discedge::cluster::{HintConfig, NodeState};
use discedge::config::{ClusterConfig, ContextMode};
use discedge::kvstore::{KvConfig, KvNode, ReplicationConfig, StorageConfig};
use discedge::netsim::LinkModel;
use discedge::server::EdgeCluster;
use discedge::testkit::{corrupt_file_tail, truncate_file_tail};

const MODEL: &str = "discedge/tiny-chat";

/// Fresh per-test scratch directory under the system tmp root.
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "discedge-persistence-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn kv_config(storage: Option<StorageConfig>) -> KvConfig {
    KvConfig {
        peer_link: LinkModel::ideal(),
        storage: storage.unwrap_or_default(),
        ..KvConfig::default()
    }
}

fn wait_for<T>(mut f: impl FnMut() -> Option<T>, timeout: Duration) -> Option<T> {
    let start = Instant::now();
    while start.elapsed() < timeout {
        if let Some(v) = f() {
            return Some(v);
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    None
}

fn fleet(storage_dir: Option<PathBuf>) -> EdgeCluster {
    let mut cfg = ClusterConfig::mock_fleet(3, Some(2));
    cfg.enable_fast_membership();
    // Same failover tuning as tests/failover.rs: a wide-enough detection
    // window for deterministic hinting, fail-fast pushes during it.
    cfg.membership.down_after = Duration::from_millis(400);
    cfg.replication.max_attempts = 2;
    cfg.replication.retry_backoff = Duration::from_millis(1);
    if let Some(dir) = storage_dir {
        cfg.storage.enabled = true;
        cfg.storage.dir = dir;
    }
    EdgeCluster::launch(cfg).unwrap()
}

fn sticky_client(cluster: &EdgeCluster) -> Client {
    Client::connect(cluster.endpoints(), MobilityPolicy::Sticky(0))
        .with_mode(ContextMode::Tokenized)
        .with_model(MODEL)
        .with_max_tokens(8)
}

fn run_turns(cluster: &EdgeCluster, client: &mut Client, from: usize, to: usize) {
    for t in from..to {
        client
            .chat(&format!("turn {t}: tell me about robots"))
            .unwrap_or_else(|e| panic!("turn {t} failed: {e}"));
        cluster.quiesce();
    }
}

/// (a) Crash mid-conversation, restart, recover from local disk, converge
/// byte-for-byte with an uncrashed (and storage-less) control fleet.
#[test]
fn crashed_node_recovers_from_disk_and_converges_with_control() {
    let root = tmp_dir("crash-recovery");
    let mut cluster = fleet(Some(root.clone()));
    let view = cluster.membership().unwrap().clone();
    let mut client = sticky_client(&cluster);

    // Turns 1-3 with the full fleet: every home replica persists them.
    run_turns(&cluster, &mut client, 1, 4);
    let (user, session) = client.session();
    let key = format!("{}/{}", user.unwrap(), session.unwrap());

    // Hard-crash a home replica that is not the serving node (the PR-3
    // kill path: severed listeners, discarded queues — no flush, no
    // goodbye; the WAL tail is whatever had been appended).
    let placement = cluster.current_placement().unwrap();
    let victim = placement
        .replicas(MODEL, &key)
        .iter()
        .map(|(name, _)| name.clone())
        .find(|name| name != "edge-0")
        .expect("rf=2 over 3 nodes: some home replica is not edge-0");
    let committed_at_crash = cluster
        .node(&victim)
        .unwrap()
        .kv
        .get(MODEL, &key)
        .expect("victim replicated the pre-crash turns")
        .version;
    assert!(committed_at_crash >= 3);
    let victim_cfg = cluster.kill_node(&victim).expect("victim config");
    std::thread::sleep(Duration::from_millis(30));

    // Outage-window turns park as hints on the serving node.
    run_turns(&cluster, &mut client, 4, 6);
    assert!(
        view.wait_for_state(&victim, NodeState::Down, Duration::from_secs(10)),
        "victim must be detected down"
    );
    run_turns(&cluster, &mut client, 6, 8);

    // Restart on fresh ports, same name => same storage directory. The
    // recovery counter is the proof the committed turns came back from
    // the local snapshot+WAL, not from a peer.
    cluster.add_node(victim_cfg).unwrap();
    let restarted = cluster.node(&victim).unwrap();
    assert!(
        restarted.kv.storage_enabled(),
        "restarted node must reopen its storage"
    );
    assert!(
        restarted.kv.recovered_entries() >= 1,
        "restart must replay the local WAL"
    );
    assert!(
        restarted
            .kv
            .get(MODEL, &key)
            .map_or(false, |e| e.version >= committed_at_crash),
        "every turn committed before the crash must be readable right \
         after start, before any hint replay is required"
    );
    assert!(view.wait_for_state(&victim, NodeState::Alive, Duration::from_secs(10)));

    // Hint replay + AE close the outage-window gap on top.
    wait_for(
        || {
            cluster
                .node(&victim)
                .unwrap()
                .kv
                .get(MODEL, &key)
                .filter(|e| e.version >= 5)
        },
        Duration::from_secs(10),
    )
    .expect("hint replay must deliver the outage-window turns");
    run_turns(&cluster, &mut client, 8, 9);

    // Byte-for-byte convergence with an uncrashed, storage-less control
    // fleet (same node names => same ids; deterministic mock engine).
    let control = fleet(None);
    let mut control_client = sticky_client(&control);
    run_turns(&control, &mut control_client, 1, 9);
    let expected = control
        .node("edge-0")
        .unwrap()
        .kv
        .get(MODEL, &key)
        .expect("control holds the session");
    assert_eq!(expected.version, 8);
    let final_placement = cluster.current_placement().unwrap();
    for (name, _) in final_placement.replicas(MODEL, &key) {
        let entry = wait_for(
            || {
                cluster
                    .node(&name)
                    .unwrap()
                    .kv
                    .get(MODEL, &key)
                    .filter(|e| e.version == expected.version)
            },
            Duration::from_secs(5),
        )
        .unwrap_or_else(|| panic!("replica {name} must reach v{}", expected.version));
        assert_eq!(
            entry.value, expected.value,
            "replica {name} diverged from the no-crash control run"
        );
    }
    drop(cluster);
    let _ = std::fs::remove_dir_all(&root);
}

/// (b) Torn and corrupt WAL tails: detected by the per-record checksum,
/// truncated at the last intact record, never misapplied.
#[test]
fn torn_wal_tail_is_truncated_never_misapplied() {
    let dir = tmp_dir("torn-tail").join("node");
    let storage = StorageConfig {
        enabled: true,
        dir: dir.clone(),
        ..StorageConfig::default()
    };
    {
        let node = KvNode::start("p", kv_config(Some(storage.clone()))).unwrap();
        node.create_keygroup("m");
        node.put("m", "u/a", "alpha".into(), 1).unwrap();
        node.put("m", "u/b", "beta".into(), 1).unwrap();
        node.put("m", "u/torn", "tail-casualty".into(), 1).unwrap();
        assert_eq!(node.wal_appends(), 3);
        node.kill(); // hard-crash: no snapshot, no orderly flush
    }
    // A torn write: the last record lost its final bytes.
    truncate_file_tail(&dir.join("wal.log"), 7);
    let node = KvNode::start("p", kv_config(Some(storage.clone()))).unwrap();
    assert_eq!(node.wal_truncations(), 1, "torn tail must be detected");
    assert_eq!(node.recovered_entries(), 2);
    assert!(node.get("m", "u/a").is_some());
    assert!(node.get("m", "u/b").is_some());
    assert!(
        node.get("m", "u/torn").is_none(),
        "a half-written record must never be applied"
    );

    // Bit rot: same length, flipped bits — only the checksum can tell.
    node.create_keygroup("m");
    node.put("m", "u/c", "gamma".into(), 1).unwrap();
    drop(node);
    corrupt_file_tail(&dir.join("wal.log"), 3);
    let node = KvNode::start("p", kv_config(Some(storage))).unwrap();
    assert_eq!(node.wal_truncations(), 1, "corrupt tail must be detected");
    assert!(node.get("m", "u/a").is_some());
    assert!(
        node.get("m", "u/c").is_none(),
        "a checksum-failed record must never be applied"
    );
    drop(node);
    let _ = std::fs::remove_dir_all(dir.parent().unwrap());
}

/// (c) `storage.enabled=false` is the seed, byte-for-byte: no files, no
/// counters — and flipping it on changes nothing on the wire or in the
/// stored bytes (persistence is strictly node-local).
#[test]
fn storage_off_is_seed_identical_and_on_never_touches_the_wire() {
    fn run(storage_dir: Option<PathBuf>) -> (Vec<(String, u64, u64)>, String, u64) {
        let enabled = storage_dir.is_some();
        let cluster = fleet(storage_dir);
        let mut client = sticky_client(&cluster);
        run_turns(&cluster, &mut client, 1, 6);
        cluster.quiesce();
        let (user, session) = client.session();
        let key = format!("{}/{}", user.unwrap(), session.unwrap());
        let doc = cluster
            .nodes
            .iter()
            .find_map(|n| n.kv.get(MODEL, &key))
            .expect("some node holds the session")
            .value;
        let wire = cluster
            .nodes
            .iter()
            .map(|n| (n.name.clone(), n.kv.sync_rx_bytes(), n.kv.sync_tx_bytes()))
            .collect();
        let wal: u64 = cluster.nodes.iter().map(|n| n.kv.wal_appends()).sum();
        for node in &cluster.nodes {
            assert_eq!(node.kv.storage_enabled(), enabled);
            assert_eq!(node.kv.wal_truncations(), 0);
            if !enabled {
                assert_eq!(node.kv.wal_appends(), 0);
                assert_eq!(node.kv.wal_bytes(), 0);
                assert_eq!(node.kv.snapshots_taken(), 0);
                assert_eq!(node.kv.recovered_entries(), 0);
            }
        }
        (wire, doc, wal)
    }
    let off = run(None);
    assert_eq!(off.2, 0, "storage off must write no WAL records");

    let root = tmp_dir("wire-identical");
    let on = run(Some(root.clone()));
    assert!(on.2 > 0, "storage on must journal the session writes");
    assert!(root.join("edge-0").join("wal.log").exists());
    assert_eq!(
        off.0, on.0,
        "persistence must never change replication wire traffic"
    );
    assert_eq!(off.1, on.1, "stored session bytes must be identical");
    let _ = std::fs::remove_dir_all(&root);
}

/// (d) Recovering N committed entries from the local snapshot+WAL is
/// faster than pulling the same N entries back from a peer via hint
/// replay — the reason recovery runs first in the rejoin path.
#[test]
fn recovery_from_disk_beats_hint_replay_on_wall_clock() {
    const N: usize = 400;
    let value = |i: usize| format!("{i:-<200}"); // ~200 B per entry
    let root = tmp_dir("recovery-race");
    let storage = StorageConfig {
        enabled: true,
        dir: root.join("node"),
        ..StorageConfig::default()
    };

    // Path A: persist N entries, hard-crash, time the restart (recovery
    // runs inside KvNode::start).
    {
        let node = KvNode::start("p", kv_config(Some(storage.clone()))).unwrap();
        node.create_keygroup("m");
        for i in 0..N {
            node.put("m", &format!("u/s{i}"), value(i), 1).unwrap();
        }
        node.kill();
    }
    let t = Instant::now();
    let recovered = KvNode::start("p", kv_config(Some(storage))).unwrap();
    let recovery = t.elapsed();
    assert_eq!(recovered.len(), N, "recovery must restore every entry");
    assert_eq!(recovered.recovered_entries(), N as u64);

    // Path B: the same N updates parked as hints for a down peer, then
    // replayed to its replacement over the replication protocol.
    let a = KvNode::start(
        "a",
        KvConfig {
            peer_link: LinkModel::ideal(),
            hints: Some(HintConfig { max_per_peer: 2 * N }),
            replication: ReplicationConfig {
                max_attempts: 1,
                retry_backoff: Duration::from_millis(1),
                ..ReplicationConfig::default()
            },
            ..KvConfig::default()
        },
    )
    .unwrap();
    a.create_keygroup("m");
    let dead: SocketAddr = "127.0.0.1:9".parse().unwrap();
    a.add_peer("m", dead);
    a.mark_peer_down(dead);
    for i in 0..N {
        a.put("m", &format!("u/s{i}"), value(i), 1).unwrap();
    }
    a.quiesce();
    assert!(a.hints_queued() >= N as u64, "pushes must park while down");
    let b = KvNode::start("b", kv_config(None)).unwrap();
    b.create_keygroup("m");
    let t = Instant::now();
    a.replace_peer(dead, b.replication_addr());
    a.mark_peer_alive(dead, b.replication_addr());
    wait_for(|| (b.len() == N).then_some(()), Duration::from_secs(30))
        .expect("hint replay must restore the peer");
    let replay = t.elapsed();

    assert!(
        recovery < replay,
        "local recovery ({recovery:?}) must beat hint replay over the \
         network ({replay:?}) for {N} entries"
    );
    drop(recovered);
    let _ = std::fs::remove_dir_all(&root);
}
