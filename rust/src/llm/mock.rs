//! Deterministic mock engine: emulates inference cost without XLA.
//!
//! Used by protocol tests and by coordination-layer benchmarks that
//! isolate the context-management cost from model compute. Generation is
//! a pure function of the input ids, so repeated runs (and runs on
//! different "nodes") agree — mirroring the paper's fixed seed /
//! temperature-0 configuration where both edge nodes produce identical
//! outputs for identical context.
//!
//! Cost fidelity matters here: the TTFT benchmarks read this emulation.
//! Decode cost is charged **per step** (one sleep per generated token,
//! not one bulk sleep at the end), a single `device` lock serializes
//! emulated device work exactly like the PJRT engine's single executor
//! thread, and the step API charges `base_step_ns + per_seq_step_ns *
//! batch` per decode step — the fixed-cost-dominated step model that
//! makes continuous batching pay off on real accelerators.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::{Engine, GenOutput, StepInner, StepState};
use crate::testkit::Rng;
use crate::Result;

/// Configurable deterministic engine.
pub struct MockEngine {
    model: String,
    vocab_size: u32,
    max_context: usize,
    /// Emulated prefill cost per context token.
    pub prefill_ns_per_token: u64,
    /// Emulated decode cost per generated token (solo: a batch-of-one
    /// decode step costs exactly this).
    pub decode_ns_per_token: u64,
    /// Fixed number of tokens to generate (None = input-dependent).
    pub fixed_len: Option<usize>,
    /// Explicit step cost model (`with_step_costs`); derived from
    /// `decode_ns_per_token` when unset.
    step_costs: Option<(u64, u64)>,
    /// Single emulated device: the PJRT engine executes one request at a
    /// time on its engine thread, so the mock holds this lock for every
    /// emulated device sleep. Without it, concurrent `generate` calls
    /// would overlap their sleeps and emulate N free accelerators —
    /// hiding exactly the queueing the batching scheduler removes.
    device: Mutex<()>,
}

impl MockEngine {
    /// New mock for `model` with the given vocab size.
    pub fn new(model: &str, vocab_size: u32) -> MockEngine {
        MockEngine {
            model: model.into(),
            vocab_size,
            max_context: 1024,
            prefill_ns_per_token: 0,
            decode_ns_per_token: 0,
            fixed_len: None,
            step_costs: None,
            device: Mutex::new(()),
        }
    }

    /// Builder: emulated costs.
    pub fn with_costs(mut self, prefill_ns: u64, decode_ns: u64) -> MockEngine {
        self.prefill_ns_per_token = prefill_ns;
        self.decode_ns_per_token = decode_ns;
        self
    }

    /// Builder: fixed generation length.
    pub fn with_fixed_len(mut self, len: usize) -> MockEngine {
        self.fixed_len = Some(len);
        self
    }

    /// Builder: max context.
    pub fn with_max_context(mut self, n: usize) -> MockEngine {
        self.max_context = n;
        self
    }

    /// Builder: explicit per-step batch cost model — a decode step over
    /// `batch` sequences sleeps `base_ns + per_seq_ns * batch`.
    pub fn with_step_costs(mut self, base_ns: u64, per_seq_ns: u64) -> MockEngine {
        self.step_costs = Some((base_ns, per_seq_ns));
        self
    }

    /// The step cost model `(base_ns, per_seq_ns)`. The default derives
    /// both from `decode_ns_per_token` with a 31:1 fixed-to-marginal
    /// split (weight streaming and launch overhead dominate a step on
    /// small-batch edge accelerators), keeping a batch of one at exactly
    /// the solo per-token decode cost.
    fn step_cost_model(&self) -> (u64, u64) {
        self.step_costs.unwrap_or_else(|| {
            let per_seq = self.decode_ns_per_token / 32;
            (self.decode_ns_per_token - per_seq, per_seq)
        })
    }

    /// Sleep `ns` while holding the device lock (one emulated device).
    fn device_sleep(&self, ns: u64) {
        let _device = self.device.lock().unwrap();
        if ns > 0 {
            std::thread::sleep(Duration::from_nanos(ns));
        }
    }
}

/// FNV-1a over token ids: the deterministic "model state".
fn hash_ids(ids: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &id in ids {
        for b in id.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Incremental sampler state behind a mock [`StepState`].
pub(crate) struct MockStep {
    rng: Rng,
    target_len: usize,
    stop_id: u32,
}

impl MockStep {
    /// Draw the next id with exactly the candidate loop `generate` has
    /// always used, so stepped and solo outputs stay bit-identical.
    fn next_id(&mut self, vocab_size: u32) -> u32 {
        loop {
            let candidate = if self.rng.chance(0.15) {
                b' ' as u32
            } else {
                // Printable ASCII byte tokens -> valid UTF-8 output.
                (32 + self.rng.below(95) as u32).min(vocab_size - 1)
            };
            if candidate != self.stop_id {
                return candidate;
            }
        }
    }
}

impl Engine for MockEngine {
    fn model_name(&self) -> &str {
        &self.model
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    /// One full turn through the step API: prefill, then one decode
    /// step per token — per-token cost timing, so time-to-first-token
    /// against this engine means what it means against a real one.
    fn generate(&self, input_ids: &[u32], max_tokens: usize, stop_id: u32) -> Result<GenOutput> {
        let mut state = self.prefill(input_ids, max_tokens, stop_id)?;
        while !state.done() {
            self.decode_step(std::slice::from_mut(&mut state))?;
        }
        Ok(state.into_output())
    }

    fn prefill(&self, input_ids: &[u32], max_tokens: usize, stop_id: u32) -> Result<StepState> {
        let t0 = Instant::now();
        self.device_sleep(self.prefill_ns_per_token * input_ids.len() as u64);
        let mut rng = Rng::new(hash_ids(input_ids));
        let target_len = self
            .fixed_len
            .unwrap_or_else(|| 40 + (rng.below(89)) as usize)
            .min(max_tokens);
        Ok(StepState {
            prefill_tokens: input_ids.len(),
            prefill_s: t0.elapsed().as_secs_f64(),
            decode_s: 0.0,
            ids: Vec::with_capacity(target_len),
            done: target_len == 0,
            inner: StepInner::Mock(MockStep {
                rng,
                target_len,
                stop_id,
            }),
        })
    }

    fn decode_step(&self, states: &mut [StepState]) -> Result<Vec<Option<u32>>> {
        let active = states.iter().filter(|s| !s.done).count();
        if active == 0 {
            return Ok(vec![None; states.len()]);
        }
        let t0 = Instant::now();
        let (base_ns, per_seq_ns) = self.step_cost_model();
        self.device_sleep(base_ns + per_seq_ns * active as u64);
        // Wall-clock attribution: every active sequence waited this
        // whole step, same as a solo caller waiting out its sleep.
        let elapsed = t0.elapsed().as_secs_f64();
        let mut out = Vec::with_capacity(states.len());
        for s in states.iter_mut() {
            if s.done {
                out.push(None);
                continue;
            }
            s.decode_s += elapsed;
            match &mut s.inner {
                StepInner::Mock(m) => {
                    let id = m.next_id(self.vocab_size);
                    s.ids.push(id);
                    if s.ids.len() >= m.target_len {
                        s.done = true;
                    }
                    out.push(Some(id));
                }
                StepInner::Buffered(_) => out.push(s.pop_buffered()),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_input() {
        let e = MockEngine::new("m", 512);
        let a = e.generate(&[1, 2, 3], 128, 509).unwrap();
        let b = e.generate(&[1, 2, 3], 128, 509).unwrap();
        assert_eq!(a.ids, b.ids);
        let c = e.generate(&[1, 2, 4], 128, 509).unwrap();
        assert_ne!(a.ids, c.ids, "different context, different output");
    }

    #[test]
    fn respects_max_tokens_and_stop() {
        let e = MockEngine::new("m", 512);
        let out = e.generate(&[5, 6], 10, 509).unwrap();
        assert!(out.ids.len() <= 10);
        assert!(!out.ids.contains(&509));
    }

    #[test]
    fn fixed_len() {
        let e = MockEngine::new("m", 512).with_fixed_len(17);
        assert_eq!(e.generate(&[1], 128, 509).unwrap().ids.len(), 17);
    }

    #[test]
    fn emulated_costs_scale_with_tokens() {
        let e = MockEngine::new("m", 512)
            .with_costs(10_000, 0)
            .with_fixed_len(5);
        let short = e.generate(&[0; 10], 128, 509).unwrap();
        let long = e.generate(&[0; 1000], 128, 509).unwrap();
        assert!(long.prefill_s > short.prefill_s);
        assert_eq!(short.prefill_tokens, 10);
        assert_eq!(long.prefill_tokens, 1000);
    }

    #[test]
    fn decoded_output_is_text() {
        let e = MockEngine::new("m", 512).with_fixed_len(64);
        let out = e.generate(&[9, 9, 9], 128, 509).unwrap();
        for &id in &out.ids {
            assert!((32..127).contains(&id), "id {id} not a printable byte token");
        }
    }

    #[test]
    fn decode_cost_is_charged_per_step_not_in_bulk() {
        // The satellite fix: one sleep per generated token. After a
        // single decode step exactly one id exists and roughly one
        // token's cost has elapsed — under the old bulk-sleep model the
        // first id only became visible after the entire decode cost.
        let per_token_s = 0.002;
        let e = MockEngine::new("m", 512)
            .with_costs(0, 2_000_000)
            .with_fixed_len(5);
        let mut state = e.prefill(&[1, 2], 128, 509).unwrap();
        let toks = e.decode_step(std::slice::from_mut(&mut state)).unwrap();
        assert_eq!(state.ids.len(), 1, "first token after one step");
        assert_eq!(toks[0], Some(state.ids[0]));
        assert!(state.decode_s >= per_token_s * 0.9, "{}", state.decode_s);
        while !state.done() {
            e.decode_step(std::slice::from_mut(&mut state)).unwrap();
        }
        let out = state.into_output();
        assert_eq!(out.ids.len(), 5);
        assert!(
            out.decode_s >= 5.0 * per_token_s * 0.9,
            "accumulated decode_s {} below 5 per-token sleeps",
            out.decode_s
        );
    }

    #[test]
    fn step_api_matches_generate_under_batching() {
        // Two sequences decoded jointly must reproduce their solo
        // transcripts bit for bit — the invariant that makes batched
        // and unbatched serving interchangeable.
        let e = MockEngine::new("m", 512);
        let solo_a = e.generate(&[1, 2, 3], 64, 509).unwrap();
        let solo_b = e.generate(&[7, 8], 64, 509).unwrap();
        let mut states = vec![
            e.prefill(&[1, 2, 3], 64, 509).unwrap(),
            e.prefill(&[7, 8], 64, 509).unwrap(),
        ];
        while states.iter().any(|s| !s.done()) {
            e.decode_step(&mut states).unwrap();
        }
        let b = states.pop().unwrap().into_output();
        let a = states.pop().unwrap().into_output();
        assert_eq!(a.ids, solo_a.ids);
        assert_eq!(b.ids, solo_b.ids);
        assert_eq!(a.prefill_tokens, 3);
        assert_eq!(b.prefill_tokens, 2);
    }

    #[test]
    fn batched_step_cost_is_base_plus_per_seq() {
        let e = MockEngine::new("m", 512)
            .with_step_costs(1_000_000, 250_000)
            .with_fixed_len(4);
        let mut states = vec![
            e.prefill(&[1], 16, 509).unwrap(),
            e.prefill(&[2], 16, 509).unwrap(),
            e.prefill(&[3], 16, 509).unwrap(),
        ];
        let t0 = Instant::now();
        let toks = e.decode_step(&mut states).unwrap();
        // base 1ms + 3 * 0.25ms = 1.75ms for the whole batch.
        assert!(t0.elapsed() >= Duration::from_micros(1575), "{:?}", t0.elapsed());
        assert!(toks.iter().all(Option::is_some));
        assert!(states.iter().all(|s| s.ids.len() == 1));
    }

    #[test]
    fn concurrent_generates_serialize_on_the_device() {
        // Like the PJRT engine thread, the mock owns one device: two
        // concurrent generates queue, they do not overlap their sleeps.
        let e = std::sync::Arc::new(
            MockEngine::new("m", 512)
                .with_costs(0, 2_000_000)
                .with_fixed_len(5),
        );
        let t0 = Instant::now();
        let handles: Vec<_> = (0..2)
            .map(|i| {
                let e = e.clone();
                std::thread::spawn(move || e.generate(&[i], 16, 509).unwrap())
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 2 requests x 5 tokens x 2ms, serialized: >= ~20ms wall.
        assert!(t0.elapsed() >= Duration::from_millis(18), "{:?}", t0.elapsed());
    }
}
