//! Deterministic mock engine: emulates inference cost without XLA.
//!
//! Used by protocol tests and by coordination-layer benchmarks that
//! isolate the context-management cost from model compute. Generation is
//! a pure function of the input ids, so repeated runs (and runs on
//! different "nodes") agree — mirroring the paper's fixed seed /
//! temperature-0 configuration where both edge nodes produce identical
//! outputs for identical context.

use std::time::Duration;

use super::{Engine, GenOutput};
use crate::testkit::Rng;
use crate::Result;

/// Configurable deterministic engine.
pub struct MockEngine {
    model: String,
    vocab_size: u32,
    max_context: usize,
    /// Emulated prefill cost per context token.
    pub prefill_ns_per_token: u64,
    /// Emulated decode cost per generated token.
    pub decode_ns_per_token: u64,
    /// Fixed number of tokens to generate (None = input-dependent).
    pub fixed_len: Option<usize>,
}

impl MockEngine {
    /// New mock for `model` with the given vocab size.
    pub fn new(model: &str, vocab_size: u32) -> MockEngine {
        MockEngine {
            model: model.into(),
            vocab_size,
            max_context: 1024,
            prefill_ns_per_token: 0,
            decode_ns_per_token: 0,
            fixed_len: None,
        }
    }

    /// Builder: emulated costs.
    pub fn with_costs(mut self, prefill_ns: u64, decode_ns: u64) -> MockEngine {
        self.prefill_ns_per_token = prefill_ns;
        self.decode_ns_per_token = decode_ns;
        self
    }

    /// Builder: fixed generation length.
    pub fn with_fixed_len(mut self, len: usize) -> MockEngine {
        self.fixed_len = Some(len);
        self
    }

    /// Builder: max context.
    pub fn with_max_context(mut self, n: usize) -> MockEngine {
        self.max_context = n;
        self
    }
}

/// FNV-1a over token ids: the deterministic "model state".
fn hash_ids(ids: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &id in ids {
        for b in id.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

impl Engine for MockEngine {
    fn model_name(&self) -> &str {
        &self.model
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn generate(&self, input_ids: &[u32], max_tokens: usize, stop_id: u32) -> Result<GenOutput> {
        let t0 = std::time::Instant::now();
        if self.prefill_ns_per_token > 0 {
            std::thread::sleep(Duration::from_nanos(
                self.prefill_ns_per_token * input_ids.len() as u64,
            ));
        }
        let prefill_s = t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        let mut rng = Rng::new(hash_ids(input_ids));
        let len = self
            .fixed_len
            .unwrap_or_else(|| 40 + (rng.below(89)) as usize)
            .min(max_tokens);
        let mut ids = Vec::with_capacity(len);
        // Generate "text-like" ids: byte tokens for printable ASCII so the
        // decoded response is harmless text; avoid the stop id.
        for _ in 0..len {
            let id = loop {
                let candidate = if rng.chance(0.15) {
                    b' ' as u32
                } else {
                    // Printable ASCII byte tokens -> valid UTF-8 output.
                    (32 + rng.below(95) as u32).min(self.vocab_size - 1)
                };
                if candidate != stop_id {
                    break candidate;
                }
            };
            ids.push(id);
        }
        if self.decode_ns_per_token > 0 {
            std::thread::sleep(Duration::from_nanos(
                self.decode_ns_per_token * ids.len() as u64,
            ));
        }
        Ok(GenOutput {
            prefill_tokens: input_ids.len(),
            prefill_s,
            decode_s: t1.elapsed().as_secs_f64(),
            ids,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_input() {
        let e = MockEngine::new("m", 512);
        let a = e.generate(&[1, 2, 3], 128, 509).unwrap();
        let b = e.generate(&[1, 2, 3], 128, 509).unwrap();
        assert_eq!(a.ids, b.ids);
        let c = e.generate(&[1, 2, 4], 128, 509).unwrap();
        assert_ne!(a.ids, c.ids, "different context, different output");
    }

    #[test]
    fn respects_max_tokens_and_stop() {
        let e = MockEngine::new("m", 512);
        let out = e.generate(&[5, 6], 10, 509).unwrap();
        assert!(out.ids.len() <= 10);
        assert!(!out.ids.contains(&509));
    }

    #[test]
    fn fixed_len() {
        let e = MockEngine::new("m", 512).with_fixed_len(17);
        assert_eq!(e.generate(&[1], 128, 509).unwrap().ids.len(), 17);
    }

    #[test]
    fn emulated_costs_scale_with_tokens() {
        let e = MockEngine::new("m", 512)
            .with_costs(10_000, 0)
            .with_fixed_len(5);
        let short = e.generate(&[0; 10], 128, 509).unwrap();
        let long = e.generate(&[0; 1000], 128, 509).unwrap();
        assert!(long.prefill_s > short.prefill_s);
        assert_eq!(short.prefill_tokens, 10);
        assert_eq!(long.prefill_tokens, 1000);
    }

    #[test]
    fn decoded_output_is_text() {
        let e = MockEngine::new("m", 512).with_fixed_len(64);
        let out = e.generate(&[9, 9, 9], 128, 509).unwrap();
        for &id in &out.ids {
            assert!((32..127).contains(&id), "id {id} not a printable byte token");
        }
    }
}
