//! LLM Service (paper §3.2): the inference framework behind each edge node.
//!
//! Mirrors the paper's modified llama.cpp: the `/completion` path accepts a
//! **pre-tokenized context** plus the raw prompt, tokenizes only the new
//! prompt, concatenates, and generates. The engine is runtime-agnostic
//! behind the [`Engine`] trait:
//!
//! - [`PjrtEngine`] (in [`crate::llm::pjrt`]) runs the AOT-compiled JAX/
//!   Pallas transformer through PJRT — the production path;
//! - [`MockEngine`] emulates inference cost deterministically for protocol
//!   tests and coordination-only benchmarks.
//!
//! The ChatML prompt template (Qwen-style, matching the paper's
//! Qwen1.5-0.5B-Chat) lives here too, in both its token-level and raw-text
//! forms — the three context modes must produce *identical* inference
//! inputs, which the tests pin down.

mod mock;
pub mod pjrt;

pub use mock::MockEngine;
pub use pjrt::PjrtEngine;

use std::sync::Arc;

use crate::tokenizer::Tokenizer;
use crate::Result;

/// Default system prompt for chat sessions.
pub const SYSTEM_PROMPT: &str = "You are a helpful assistant.";

/// A chat message (client-side context mode ships these verbatim).
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    /// `system`, `user`, or `assistant`.
    pub role: String,
    /// Message content.
    pub content: String,
}

impl Message {
    /// Convenience constructor.
    pub fn new(role: &str, content: &str) -> Message {
        Message {
            role: role.into(),
            content: content.into(),
        }
    }
}

/// Output of one generation call.
#[derive(Debug, Clone)]
pub struct GenOutput {
    /// Generated token ids (without the trailing end marker).
    pub ids: Vec<u32>,
    /// Number of context tokens processed (prefill length).
    pub prefill_tokens: usize,
    /// Seconds spent in prefill.
    pub prefill_s: f64,
    /// Seconds spent decoding.
    pub decode_s: f64,
}

/// An inference engine serving one model.
pub trait Engine: Send + Sync {
    /// Model identifier (the KV keygroup name).
    fn model_name(&self) -> &str;
    /// Generate up to `max_tokens` continuation tokens for `input_ids`,
    /// stopping early on `stop_id`.
    fn generate(&self, input_ids: &[u32], max_tokens: usize, stop_id: u32) -> Result<GenOutput>;
    /// Longest context (in tokens) the engine accepts.
    fn max_context(&self) -> usize;
}

/// ChatML template in token and text forms.
///
/// Token layout per session:
/// ```text
/// <|im_start|>system\n{SYSTEM_PROMPT}<|im_end|>\n        <- preamble
/// <|im_start|>user\n{prompt}<|im_end|>\n<|im_start|>assistant\n   <- per turn
/// {response}<|im_end|>\n                                  <- per turn close
/// ```
#[derive(Clone)]
pub struct ChatTemplate {
    tokenizer: Arc<Tokenizer>,
    im_start: u32,
    im_end: u32,
}

impl ChatTemplate {
    /// Build for a tokenizer.
    pub fn new(tokenizer: Arc<Tokenizer>) -> Result<ChatTemplate> {
        let im_start = tokenizer.special("<|im_start|>")?;
        let im_end = tokenizer.special("<|im_end|>")?;
        Ok(ChatTemplate {
            tokenizer,
            im_start,
            im_end,
        })
    }

    /// The tokenizer behind this template.
    pub fn tokenizer(&self) -> &Arc<Tokenizer> {
        &self.tokenizer
    }

    /// End-of-message id (generation stop token).
    pub fn stop_id(&self) -> u32 {
        self.im_end
    }

    // ---- token-level assembly (tokenized mode: only new text encoded) ----

    /// Session preamble ids (system message).
    pub fn preamble_ids(&self) -> Vec<u32> {
        let mut ids = vec![self.im_start];
        ids.extend(self.tokenizer.encode(&format!("system\n{SYSTEM_PROMPT}")));
        ids.push(self.im_end);
        ids.extend(self.tokenizer.encode("\n"));
        ids
    }

    /// Ids for a new user turn, ending with the assistant header so the
    /// model continues as the assistant.
    pub fn user_turn_ids(&self, prompt: &str) -> Vec<u32> {
        let mut ids = vec![self.im_start];
        ids.extend(self.tokenizer.encode(&format!("user\n{prompt}")));
        ids.push(self.im_end);
        ids.extend(self.tokenizer.encode("\n"));
        ids.push(self.im_start);
        ids.extend(self.tokenizer.encode("assistant\n"));
        ids
    }

    /// Ids closing an assistant turn (append after the generated ids).
    pub fn close_ids(&self) -> Vec<u32> {
        let mut ids = vec![self.im_end];
        ids.extend(self.tokenizer.encode("\n"));
        ids
    }

    // ---- text assembly (raw + client-side modes) ----

    /// Text preamble.
    pub fn preamble_text(&self) -> String {
        format!("<|im_start|>system\n{SYSTEM_PROMPT}<|im_end|>\n")
    }

    /// Text for a new user turn (ends with the assistant header).
    pub fn user_turn_text(&self, prompt: &str) -> String {
        format!("<|im_start|>user\n{prompt}<|im_end|>\n<|im_start|>assistant\n")
    }

    /// Text closing an assistant turn.
    pub fn close_text(&self, response: &str) -> String {
        format!("{response}<|im_end|>\n")
    }

    /// Render a full message history (client-side mode) into transcript
    /// text ending with the assistant header.
    pub fn render_messages(&self, messages: &[Message], new_prompt: &str) -> String {
        let mut text = self.preamble_text();
        for m in messages {
            text.push_str(&format!(
                "<|im_start|>{}\n{}<|im_end|>\n",
                m.role, m.content
            ));
        }
        text.push_str(&self.user_turn_text(new_prompt));
        text
    }

    /// Tokenize transcript text with special-literal mapping (raw and
    /// client-side modes re-tokenize everything through this).
    pub fn encode_transcript(&self, text: &str) -> Vec<u32> {
        self.tokenizer.encode_with_specials(text)
    }

    /// Decode generated ids to response text.
    pub fn decode(&self, ids: &[u32]) -> String {
        self.tokenizer.decode(ids)
    }
}

/// Greedy/temperature sampling over a logits slice. Temperature 0 = argmax
/// (the paper's setting); otherwise softmax sampling with the given rng.
pub fn sample(logits: &[f32], temperature: f64, rng: &mut crate::testkit::Rng) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    // Softmax with temperature, numerically stabilized.
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - max) as f64) / temperature).exp())
        .collect();
    let sum: f64 = exps.iter().sum();
    let mut target = rng.f64() * sum;
    for (i, e) in exps.iter().enumerate() {
        target -= e;
        if target <= 0.0 {
            return i as u32;
        }
    }
    (logits.len() - 1) as u32
}

/// Index of the maximum logit (first on ties — deterministic).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;
    use crate::tokenizer::{train, TrainConfig};

    fn template() -> ChatTemplate {
        let corpus = crate::workload::corpus_with_size(1, 30_000);
        let tok = Tokenizer::from_vocab(train(
            &corpus,
            &TrainConfig {
                vocab_size: 512,
                ..TrainConfig::default()
            },
        ));
        ChatTemplate::new(Arc::new(tok)).unwrap()
    }

    #[test]
    fn token_and_text_assembly_agree() {
        // The core invariant behind the paper's Fig 3: all three modes
        // must feed the model the same ids, so the only cost difference
        // is *where tokenization happens*.
        let t = template();
        let prompt = "What is SLAM?";
        // Tokenized mode: programmatic assembly.
        let mut tok_ids = t.preamble_ids();
        tok_ids.extend(t.user_turn_ids(prompt));
        // Raw mode: text transcript re-tokenized.
        let text = format!("{}{}", t.preamble_text(), t.user_turn_text(prompt));
        let raw_ids = t.encode_transcript(&text);
        assert_eq!(tok_ids, raw_ids);
    }

    #[test]
    fn multi_turn_assembly_agrees() {
        let t = template();
        let response = "A robot maps while localizing.";
        let resp_ids = t.tokenizer().encode(response);
        // Tokenized: turn 1 + close + turn 2.
        let mut tok_ids = t.preamble_ids();
        tok_ids.extend(t.user_turn_ids("What is SLAM?"));
        tok_ids.extend(resp_ids.clone());
        tok_ids.extend(t.close_ids());
        tok_ids.extend(t.user_turn_ids("Tell me more"));
        // Raw: full transcript.
        let text = format!(
            "{}{}{}{}",
            t.preamble_text(),
            t.user_turn_text("What is SLAM?"),
            t.close_text(response),
            t.user_turn_text("Tell me more"),
        );
        assert_eq!(t.encode_transcript(&text), tok_ids);
    }

    #[test]
    fn client_side_render_matches_raw() {
        let t = template();
        let messages = vec![
            Message::new("user", "What is SLAM?"),
            Message::new("assistant", "A mapping method."),
        ];
        let rendered = t.render_messages(&messages, "Tell me more");
        let expected = format!(
            "{}<|im_start|>user\nWhat is SLAM?<|im_end|>\n<|im_start|>assistant\nA mapping method.<|im_end|>\n{}",
            t.preamble_text(),
            t.user_turn_text("Tell me more"),
        );
        assert_eq!(rendered, expected);
    }

    #[test]
    fn argmax_deterministic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[0.5, 0.5]), 0, "ties break to first");
    }

    #[test]
    fn sample_temperature_zero_is_argmax() {
        let mut rng = Rng::new(1);
        assert_eq!(sample(&[0.0, 3.0, 1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn sample_temperature_respects_distribution() {
        // With a dominant logit, sampling should pick it most of the time.
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 8.0, 0.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample(&logits, 1.0, &mut rng) == 1)
            .count();
        assert!(hits > 180, "hits {hits}");
    }
}
