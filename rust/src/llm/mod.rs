//! LLM Service (paper §3.2): the inference framework behind each edge node.
//!
//! Mirrors the paper's modified llama.cpp: the `/completion` path accepts a
//! **pre-tokenized context** plus the raw prompt, tokenizes only the new
//! prompt, concatenates, and generates. The engine is runtime-agnostic
//! behind the [`Engine`] trait:
//!
//! - [`PjrtEngine`] (in [`crate::llm::pjrt`]) runs the AOT-compiled JAX/
//!   Pallas transformer through PJRT — the production path;
//! - [`MockEngine`] emulates inference cost deterministically for protocol
//!   tests and coordination-only benchmarks.
//!
//! The ChatML prompt template (Qwen-style, matching the paper's
//! Qwen1.5-0.5B-Chat) lives here too, in both its token-level and raw-text
//! forms — the three context modes must produce *identical* inference
//! inputs, which the tests pin down.

mod mock;
pub mod pjrt;

pub use mock::MockEngine;
pub use pjrt::PjrtEngine;

use std::collections::VecDeque;
use std::sync::Arc;

use crate::tokenizer::Tokenizer;
use crate::Result;

/// Default system prompt for chat sessions.
pub const SYSTEM_PROMPT: &str = "You are a helpful assistant.";

/// A chat message (client-side context mode ships these verbatim).
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    /// `system`, `user`, or `assistant`.
    pub role: String,
    /// Message content.
    pub content: String,
}

impl Message {
    /// Convenience constructor.
    pub fn new(role: &str, content: &str) -> Message {
        Message {
            role: role.into(),
            content: content.into(),
        }
    }
}

/// Output of one generation call.
#[derive(Debug, Clone)]
pub struct GenOutput {
    /// Generated token ids (without the trailing end marker).
    pub ids: Vec<u32>,
    /// Number of context tokens processed (prefill length).
    pub prefill_tokens: usize,
    /// Seconds spent in prefill.
    pub prefill_s: f64,
    /// Seconds spent decoding.
    pub decode_s: f64,
}

/// Per-sequence decode state for the step API ([`Engine::prefill`] /
/// [`Engine::decode_step`]): everything one in-flight sequence carries
/// between decode steps of the continuous-batching scheduler.
pub struct StepState {
    /// Number of context tokens processed by prefill.
    pub prefill_tokens: usize,
    /// Seconds spent in the prefill call. For engines on the buffered
    /// sequential fallback this covers the whole fused generation.
    pub prefill_s: f64,
    /// Wall seconds this sequence has spent inside decode steps.
    pub decode_s: f64,
    /// Generated ids so far.
    pub ids: Vec<u32>,
    pub(crate) done: bool,
    pub(crate) inner: StepInner,
}

/// Engine-private half of a [`StepState`].
pub(crate) enum StepInner {
    /// Pre-generated ids replayed one per step — the sequential fallback
    /// every engine inherits from [`Engine::generate`] (the PJRT
    /// executable fuses prefill and decode, so it cannot step).
    Buffered(VecDeque<u32>),
    /// The mock engine's incremental sampler state.
    Mock(mock::MockStep),
}

impl StepState {
    /// True once the sequence finished (stop condition or `max_tokens`).
    pub fn done(&self) -> bool {
        self.done
    }

    /// Collapse into the [`GenOutput`] an equivalent solo
    /// [`Engine::generate`] call would have returned.
    pub fn into_output(self) -> GenOutput {
        GenOutput {
            ids: self.ids,
            prefill_tokens: self.prefill_tokens,
            prefill_s: self.prefill_s,
            decode_s: self.decode_s,
        }
    }

    /// Advance a buffered sequence by one replayed id (the default
    /// [`Engine::decode_step`]); marks non-buffered states done so a
    /// mismatched engine/state pairing degrades instead of spinning.
    pub(crate) fn pop_buffered(&mut self) -> Option<u32> {
        if self.done {
            return None;
        }
        let StepInner::Buffered(queue) = &mut self.inner else {
            self.done = true;
            return None;
        };
        match queue.pop_front() {
            Some(id) => {
                self.ids.push(id);
                if queue.is_empty() {
                    self.done = true;
                }
                Some(id)
            }
            None => {
                self.done = true;
                None
            }
        }
    }
}

/// An inference engine serving one model.
pub trait Engine: Send + Sync {
    /// Model identifier (the KV keygroup name).
    fn model_name(&self) -> &str;
    /// Generate up to `max_tokens` continuation tokens for `input_ids`,
    /// stopping early on `stop_id`.
    fn generate(&self, input_ids: &[u32], max_tokens: usize, stop_id: u32) -> Result<GenOutput>;
    /// Longest context (in tokens) the engine accepts.
    fn max_context(&self) -> usize;

    /// Start one sequence for step-granular decoding: process
    /// `input_ids` (prefill) and return its per-sequence decode state.
    ///
    /// The default implementation is the **sequential fallback** for
    /// engines whose executable fuses prefill and decode (the PJRT
    /// engine): it runs the whole [`Engine::generate`] call eagerly and
    /// replays the generated ids one per [`Engine::decode_step`].
    /// Engines that can decode incrementally (the mock engine) override
    /// both methods.
    fn prefill(&self, input_ids: &[u32], max_tokens: usize, stop_id: u32) -> Result<StepState> {
        let out = self.generate(input_ids, max_tokens, stop_id)?;
        Ok(StepState {
            prefill_tokens: out.prefill_tokens,
            prefill_s: out.prefill_s,
            decode_s: out.decode_s,
            done: out.ids.is_empty(),
            inner: StepInner::Buffered(out.ids.into()),
            ids: Vec::new(),
        })
    }

    /// Advance every unfinished sequence in `states` by one decode
    /// step. Returns the token appended to each sequence, index-aligned
    /// with `states` (`None` for sequences that are already done).
    fn decode_step(&self, states: &mut [StepState]) -> Result<Vec<Option<u32>>> {
        Ok(states.iter_mut().map(StepState::pop_buffered).collect())
    }

    /// Generate like [`Engine::generate`], reporting each id to
    /// `on_token` as it is produced. The default delegates to
    /// `generate` and replays the ids afterwards — no early tokens,
    /// matching the buffered behaviour of engines without incremental
    /// decode. The batching scheduler overrides this to forward tokens
    /// as decode steps complete.
    fn generate_streamed(
        &self,
        input_ids: &[u32],
        max_tokens: usize,
        stop_id: u32,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<GenOutput> {
        let out = self.generate(input_ids, max_tokens, stop_id)?;
        for &id in &out.ids {
            on_token(id);
        }
        Ok(out)
    }
}

/// ChatML template in token and text forms.
///
/// Token layout per session:
/// ```text
/// <|im_start|>system\n{SYSTEM_PROMPT}<|im_end|>\n        <- preamble
/// <|im_start|>user\n{prompt}<|im_end|>\n<|im_start|>assistant\n   <- per turn
/// {response}<|im_end|>\n                                  <- per turn close
/// ```
#[derive(Clone)]
pub struct ChatTemplate {
    tokenizer: Arc<Tokenizer>,
    im_start: u32,
    im_end: u32,
}

impl ChatTemplate {
    /// Build for a tokenizer.
    pub fn new(tokenizer: Arc<Tokenizer>) -> Result<ChatTemplate> {
        let im_start = tokenizer.special("<|im_start|>")?;
        let im_end = tokenizer.special("<|im_end|>")?;
        Ok(ChatTemplate {
            tokenizer,
            im_start,
            im_end,
        })
    }

    /// The tokenizer behind this template.
    pub fn tokenizer(&self) -> &Arc<Tokenizer> {
        &self.tokenizer
    }

    /// End-of-message id (generation stop token).
    pub fn stop_id(&self) -> u32 {
        self.im_end
    }

    // ---- token-level assembly (tokenized mode: only new text encoded) ----

    /// Session preamble ids (system message).
    pub fn preamble_ids(&self) -> Vec<u32> {
        let mut ids = vec![self.im_start];
        ids.extend(self.tokenizer.encode(&format!("system\n{SYSTEM_PROMPT}")));
        ids.push(self.im_end);
        ids.extend(self.tokenizer.encode("\n"));
        ids
    }

    /// Ids for a new user turn, ending with the assistant header so the
    /// model continues as the assistant.
    pub fn user_turn_ids(&self, prompt: &str) -> Vec<u32> {
        let mut ids = vec![self.im_start];
        ids.extend(self.tokenizer.encode(&format!("user\n{prompt}")));
        ids.push(self.im_end);
        ids.extend(self.tokenizer.encode("\n"));
        ids.push(self.im_start);
        ids.extend(self.tokenizer.encode("assistant\n"));
        ids
    }

    /// Ids closing an assistant turn (append after the generated ids).
    pub fn close_ids(&self) -> Vec<u32> {
        let mut ids = vec![self.im_end];
        ids.extend(self.tokenizer.encode("\n"));
        ids
    }

    // ---- text assembly (raw + client-side modes) ----

    /// Text preamble.
    pub fn preamble_text(&self) -> String {
        format!("<|im_start|>system\n{SYSTEM_PROMPT}<|im_end|>\n")
    }

    /// Text for a new user turn (ends with the assistant header).
    pub fn user_turn_text(&self, prompt: &str) -> String {
        format!("<|im_start|>user\n{prompt}<|im_end|>\n<|im_start|>assistant\n")
    }

    /// Text closing an assistant turn.
    pub fn close_text(&self, response: &str) -> String {
        format!("{response}<|im_end|>\n")
    }

    /// Render a full message history (client-side mode) into transcript
    /// text ending with the assistant header.
    pub fn render_messages(&self, messages: &[Message], new_prompt: &str) -> String {
        let mut text = self.preamble_text();
        for m in messages {
            text.push_str(&format!(
                "<|im_start|>{}\n{}<|im_end|>\n",
                m.role, m.content
            ));
        }
        text.push_str(&self.user_turn_text(new_prompt));
        text
    }

    /// Tokenize transcript text with special-literal mapping (raw and
    /// client-side modes re-tokenize everything through this).
    pub fn encode_transcript(&self, text: &str) -> Vec<u32> {
        self.tokenizer.encode_with_specials(text)
    }

    /// Decode generated ids to response text.
    pub fn decode(&self, ids: &[u32]) -> String {
        self.tokenizer.decode(ids)
    }
}

/// Greedy/temperature sampling over a logits slice. Temperature 0 = argmax
/// (the paper's setting); otherwise softmax sampling with the given rng.
pub fn sample(logits: &[f32], temperature: f64, rng: &mut crate::testkit::Rng) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    // Softmax with temperature, numerically stabilized.
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - max) as f64) / temperature).exp())
        .collect();
    let sum: f64 = exps.iter().sum();
    let mut target = rng.f64() * sum;
    for (i, e) in exps.iter().enumerate() {
        target -= e;
        if target <= 0.0 {
            return i as u32;
        }
    }
    (logits.len() - 1) as u32
}

/// Index of the maximum logit (first on ties — deterministic).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;
    use crate::tokenizer::{train, TrainConfig};

    fn template() -> ChatTemplate {
        let corpus = crate::workload::corpus_with_size(1, 30_000);
        let tok = Tokenizer::from_vocab(train(
            &corpus,
            &TrainConfig {
                vocab_size: 512,
                ..TrainConfig::default()
            },
        ));
        ChatTemplate::new(Arc::new(tok)).unwrap()
    }

    #[test]
    fn token_and_text_assembly_agree() {
        // The core invariant behind the paper's Fig 3: all three modes
        // must feed the model the same ids, so the only cost difference
        // is *where tokenization happens*.
        let t = template();
        let prompt = "What is SLAM?";
        // Tokenized mode: programmatic assembly.
        let mut tok_ids = t.preamble_ids();
        tok_ids.extend(t.user_turn_ids(prompt));
        // Raw mode: text transcript re-tokenized.
        let text = format!("{}{}", t.preamble_text(), t.user_turn_text(prompt));
        let raw_ids = t.encode_transcript(&text);
        assert_eq!(tok_ids, raw_ids);
    }

    #[test]
    fn multi_turn_assembly_agrees() {
        let t = template();
        let response = "A robot maps while localizing.";
        let resp_ids = t.tokenizer().encode(response);
        // Tokenized: turn 1 + close + turn 2.
        let mut tok_ids = t.preamble_ids();
        tok_ids.extend(t.user_turn_ids("What is SLAM?"));
        tok_ids.extend(resp_ids.clone());
        tok_ids.extend(t.close_ids());
        tok_ids.extend(t.user_turn_ids("Tell me more"));
        // Raw: full transcript.
        let text = format!(
            "{}{}{}{}",
            t.preamble_text(),
            t.user_turn_text("What is SLAM?"),
            t.close_text(response),
            t.user_turn_text("Tell me more"),
        );
        assert_eq!(t.encode_transcript(&text), tok_ids);
    }

    #[test]
    fn client_side_render_matches_raw() {
        let t = template();
        let messages = vec![
            Message::new("user", "What is SLAM?"),
            Message::new("assistant", "A mapping method."),
        ];
        let rendered = t.render_messages(&messages, "Tell me more");
        let expected = format!(
            "{}<|im_start|>user\nWhat is SLAM?<|im_end|>\n<|im_start|>assistant\nA mapping method.<|im_end|>\n{}",
            t.preamble_text(),
            t.user_turn_text("Tell me more"),
        );
        assert_eq!(rendered, expected);
    }

    #[test]
    fn argmax_deterministic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[0.5, 0.5]), 0, "ties break to first");
    }

    #[test]
    fn sample_temperature_zero_is_argmax() {
        let mut rng = Rng::new(1);
        assert_eq!(sample(&[0.0, 3.0, 1.0], 0.0, &mut rng), 1);
    }

    /// Engine that only implements `generate` — the shape of the PJRT
    /// engine, exercising the default buffered step fallback.
    struct FixedEngine;

    impl Engine for FixedEngine {
        fn model_name(&self) -> &str {
            "fixed"
        }

        fn max_context(&self) -> usize {
            64
        }

        fn generate(
            &self,
            _input_ids: &[u32],
            max_tokens: usize,
            _stop_id: u32,
        ) -> Result<GenOutput> {
            Ok(GenOutput {
                ids: (0..max_tokens as u32).collect(),
                prefill_tokens: 3,
                prefill_s: 0.25,
                decode_s: 0.5,
            })
        }
    }

    #[test]
    fn buffered_fallback_replays_generate_step_by_step() {
        let e = FixedEngine;
        let mut state = e.prefill(&[1, 2, 3], 4, 99).unwrap();
        assert!(!state.done());
        let mut seen = Vec::new();
        while !state.done() {
            let toks = e.decode_step(std::slice::from_mut(&mut state)).unwrap();
            seen.push(toks[0].expect("one token per step until done"));
        }
        assert_eq!(seen, vec![0, 1, 2, 3]);
        let out = state.into_output();
        assert_eq!(out.ids, vec![0, 1, 2, 3]);
        assert_eq!(out.prefill_tokens, 3);
        assert_eq!(out.prefill_s, 0.25);
        assert_eq!(out.decode_s, 0.5, "buffered decode cost was paid at prefill");
    }

    #[test]
    fn finished_states_yield_none_not_tokens() {
        let e = FixedEngine;
        let mut state = e.prefill(&[1], 1, 99).unwrap();
        assert_eq!(
            e.decode_step(std::slice::from_mut(&mut state)).unwrap(),
            vec![Some(0)]
        );
        assert!(state.done());
        assert_eq!(
            e.decode_step(std::slice::from_mut(&mut state)).unwrap(),
            vec![None]
        );
    }

    #[test]
    fn streamed_default_replays_all_ids() {
        let e = FixedEngine;
        let mut got = Vec::new();
        let out = e
            .generate_streamed(&[1], 3, 99, &mut |id| got.push(id))
            .unwrap();
        assert_eq!(got, out.ids);
    }

    #[test]
    fn sample_temperature_respects_distribution() {
        // With a dominant logit, sampling should pick it most of the time.
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 8.0, 0.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample(&logits, 1.0, &mut rng) == 1)
            .count();
        assert!(hits > 180, "hits {hits}");
    }
}
