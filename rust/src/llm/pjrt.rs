//! PJRT-backed [`Engine`]: the production inference path.
//!
//! The `xla` crate's PJRT objects are not `Send`/`Sync` (internal `Rc`s),
//! so the compiled model lives on a **dedicated engine thread** — the
//! single-executor pattern real accelerators force anyway. The
//! [`PjrtEngine`] handle is `Send + Sync`; requests are serialized through
//! a channel and answered over a per-request reply channel.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use super::{Engine, GenOutput};
use crate::config::GenerationConfig;
use crate::runtime::ModelRuntime;
use crate::{Error, Result};

struct Job {
    input_ids: Vec<u32>,
    max_tokens: usize,
    stop_id: u32,
    reply: Sender<Result<GenOutput>>,
}

/// Thread-safe handle to a model running on the PJRT engine thread.
pub struct PjrtEngine {
    model: String,
    max_context: usize,
    tx: Mutex<Option<Sender<Job>>>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl PjrtEngine {
    /// Load artifacts from `dir` and start the engine thread. Fails fast
    /// (before returning) if artifacts are missing or fail to compile.
    pub fn load(model: &str, dir: &Path, _gen: GenerationConfig) -> Result<PjrtEngine> {
        let dir: PathBuf = dir.to_path_buf();
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<Result<usize>>();
        let thread = std::thread::Builder::new()
            .name(format!("pjrt-engine-{model}"))
            .spawn(move || {
                let runtime = match ModelRuntime::load(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(rt.meta().max_context()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // Per-bucket window of recent CPU costs. The reported cost
                // is the median of the window: identical inputs cost the
                // same on a real accelerator, but XLA-on-shared-CPU timing
                // jitters ±15 % — a robust estimate keeps the emulated
                // device profiles (which multiply this number) stable.
                let mut history: std::collections::HashMap<usize, Vec<f64>> =
                    std::collections::HashMap::new();
                while let Ok(job) = rx.recv() {
                    let result = runtime
                        .generate(&job.input_ids, job.max_tokens, job.stop_id)
                        .map(|raw| {
                            let window = history.entry(raw.bucket).or_default();
                            window.push(raw.execute_s);
                            if window.len() > 7 {
                                window.remove(0);
                            }
                            let mut sorted = window.clone();
                            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                            let robust = sorted[sorted.len() / 2];
                            GenOutput {
                                prefill_tokens: raw.context_len,
                                // The fused generate executable does prefill
                                // + decode in one device call; the split is
                                // not observable from the host. Report
                                // everything as decode time; TPS uses the
                                // sum anyway.
                                prefill_s: 0.0,
                                decode_s: robust,
                                ids: raw.ids,
                            }
                        });
                    let _ = job.reply.send(result);
                }
            })?;
        let max_context = ready_rx
            .recv()
            .map_err(|_| Error::Engine("engine thread died during load".into()))??;
        Ok(PjrtEngine {
            model: model.to_string(),
            max_context,
            tx: Mutex::new(Some(tx)),
            thread: Mutex::new(Some(thread)),
        })
    }

    /// Stop the engine thread.
    pub fn shutdown(&self) {
        self.tx.lock().unwrap().take();
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

impl Drop for PjrtEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Engine for PjrtEngine {
    fn model_name(&self) -> &str {
        &self.model
    }

    fn max_context(&self) -> usize {
        self.max_context
    }

    fn generate(&self, input_ids: &[u32], max_tokens: usize, stop_id: u32) -> Result<GenOutput> {
        let (reply_tx, reply_rx) = channel();
        {
            let guard = self.tx.lock().unwrap();
            let tx = guard
                .as_ref()
                .ok_or_else(|| Error::Engine("engine is shut down".into()))?;
            tx.send(Job {
                input_ids: input_ids.to_vec(),
                max_tokens,
                stop_id,
                reply: reply_tx,
            })
            .map_err(|_| Error::Engine("engine thread gone".into()))?;
        }
        reply_rx
            .recv()
            .map_err(|_| Error::Engine("engine thread dropped the request".into()))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_cleanly_without_artifacts() {
        let dir = std::env::temp_dir().join("discedge_pjrt_none");
        std::fs::create_dir_all(&dir).unwrap();
        let err = PjrtEngine::load("m", &dir, GenerationConfig::default());
        assert!(err.is_err());
    }

    // Real-artifact engine tests live in rust/tests/pjrt_integration.rs.
}
