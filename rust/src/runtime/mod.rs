//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the PJRT CPU client via the `xla` crate.
//!
//! Python is involved only at build time (`make artifacts`): it lowers the
//! JAX/Pallas model to **HLO text** (the interchange format this XLA build
//! accepts — serialized protos from jax ≥ 0.5 carry 64-bit instruction ids
//! that xla_extension 0.5.1 rejects). At node startup this module parses
//! and compiles:
//!
//! - `init.hlo.txt` — () → weights tuple (deterministic seeded init; run
//!   once, kept as host literals and passed to every generation call);
//! - `generate_{L}.hlo.txt` per prefill bucket `L` — one *full turn*:
//!   Pallas flash-attention prefill over the (padded) context, then an
//!   XLA `while`-loop greedy decode that keeps the KV cache on device —
//!   no per-token host round-trips.
//!
//! Static shapes are required for AOT, so contexts are padded to bucket
//! sizes `{128, 256, 512, 1024, 2048}` and masked by their true length.
//!
//! The `xla` crate (and its native `xla_extension` library) is an optional
//! dependency behind the **`pjrt` cargo feature**. Without the feature,
//! [`ModelRuntime::load`] reports the runtime as unavailable (after
//! surfacing missing-artifact errors first) so the mock-engine paths —
//! every protocol-level test and bench — build and run with zero external
//! dependencies. [`pjrt_available`] lets callers skip real-model work.

pub mod scheduler;

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::time::Instant;

#[cfg(feature = "pjrt")]
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::json;
use crate::{Error, Result};

/// Whether this build carries the PJRT runtime (`--features pjrt`).
pub const fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Model metadata contract shared with `python/compile/aot.py`
/// (`artifacts/model_meta.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    /// Vocabulary size (must match the tokenizer artifact).
    pub vocab_size: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// SwiGLU inner width.
    pub ffn: usize,
    /// Maximum new tokens per call (compiled into the decode loop).
    pub max_new: usize,
    /// Prefill buckets, ascending.
    pub buckets: Vec<usize>,
    /// Weight-init seed (paper config: 123).
    pub seed: u64,
}

impl ModelMeta {
    /// Load from `artifacts/model_meta.json`.
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let path = dir.join("model_meta.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        ModelMeta::from_json(&text)
    }

    /// Parse the metadata document.
    pub fn from_json(text: &str) -> Result<ModelMeta> {
        let v = json::parse(text)?;
        let buckets = v
            .get("buckets")
            .and_then(|b| b.as_int_array())
            .ok_or_else(|| Error::Runtime("meta missing buckets".into()))?
            .into_iter()
            .map(|x| x as usize)
            .collect::<Vec<usize>>();
        if buckets.is_empty() || buckets.windows(2).any(|w| w[0] >= w[1]) {
            return Err(Error::Runtime("buckets must be ascending".into()));
        }
        Ok(ModelMeta {
            vocab_size: v.req_u64("vocab_size")? as usize,
            d_model: v.req_u64("d_model")? as usize,
            n_layers: v.req_u64("n_layers")? as usize,
            n_heads: v.req_u64("n_heads")? as usize,
            head_dim: v.req_u64("head_dim")? as usize,
            ffn: v.req_u64("ffn")? as usize,
            max_new: v.req_u64("max_new")? as usize,
            seed: v.req_u64("seed")?,
            buckets,
        })
    }

    /// Largest usable context (the last bucket).
    pub fn max_context(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket holding `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= len)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "context of {len} tokens exceeds the largest bucket {}",
                    self.max_context()
                ))
            })
    }
}

/// Raw output of one on-device generation call.
#[derive(Debug, Clone)]
pub struct RawGeneration {
    /// Generated ids (`n_generated` of them, already trimmed).
    pub ids: Vec<u32>,
    /// Prefill bucket used.
    pub bucket: usize,
    /// True context length fed to prefill.
    pub context_len: usize,
    /// Device-execution CPU seconds (process CPU time, robust against
    /// scheduler preemption on shared hosts — see [`process_cpu_time`]).
    pub execute_s: f64,
    /// Wall-clock seconds of the same call (diagnostics).
    pub execute_wall_s: f64,
}

/// Process CPU time in seconds. XLA's CPU client runs work on its own
/// thread pool, so thread CPU time of the caller would miss it; process
/// CPU time captures it and is insensitive to preemption by other
/// processes — the property the [`crate::profile`] inference scaling
/// needs on this single-core testbed. Engine calls are serialized, so
/// cross-request contamination cannot occur; other in-process threads
/// sleep during inference and contribute negligible CPU.
///
/// Calls `clock_gettime(CLOCK_PROCESS_CPUTIME_ID)` through a local FFI
/// declaration — the seed referenced the `libc` crate here without
/// declaring the dependency, which could never compile. Returns 0.0 on
/// platforms without the clock (callers treat it as "no CPU accounting").
/// The hand-declared `Timespec` hardcodes 64-bit fields, so the real
/// implementation is additionally gated to 64-bit targets — on 32-bit
/// (e.g. armv7 edge boards) `time_t`/`long` are 32-bit and the layout
/// would be wrong, so those fall back to 0.0 instead of reading garbage.
#[cfg(all(
    target_pointer_width = "64",
    any(target_os = "linux", target_os = "macos")
))]
pub fn process_cpu_time() -> f64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    extern "C" {
        fn clock_gettime(clk_id: i32, tp: *mut Timespec) -> i32;
    }
    #[cfg(target_os = "linux")]
    const CLOCK_PROCESS_CPUTIME_ID: i32 = 2;
    #[cfg(target_os = "macos")]
    const CLOCK_PROCESS_CPUTIME_ID: i32 = 12;
    let mut ts = Timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    let rc = unsafe { clock_gettime(CLOCK_PROCESS_CPUTIME_ID, &mut ts) };
    if rc != 0 {
        return 0.0;
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Fallback for platforms without `CLOCK_PROCESS_CPUTIME_ID` (or whose C
/// `timespec` layout the 64-bit FFI declaration above would misread).
#[cfg(not(all(
    target_pointer_width = "64",
    any(target_os = "linux", target_os = "macos")
)))]
pub fn process_cpu_time() -> f64 {
    0.0
}

/// The compiled model: PJRT client + per-bucket executables + weights.
///
/// NOT `Send`/`Sync` (the `xla` crate wraps `Rc` internals) — own it on a
/// dedicated engine thread; see [`crate::llm::PjrtEngine`].
#[cfg(feature = "pjrt")]
pub struct ModelRuntime {
    meta: ModelMeta,
    weights: Vec<Literal>,
    generates: BTreeMap<usize, PjRtLoadedExecutable>,
    _client: PjRtClient,
}

#[cfg(feature = "pjrt")]
impl ModelRuntime {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<ModelRuntime> {
        let meta = ModelMeta::load(dir)?;
        let client = PjRtClient::cpu()?;

        let init = compile(&client, &dir.join("init.hlo.txt"))?;
        let weights = {
            let outs = init.execute::<Literal>(&[])?;
            let mut tuple = outs[0][0].to_literal_sync()?;
            tuple.decompose_tuple()?
        };

        let mut generates = BTreeMap::new();
        for &bucket in &meta.buckets {
            let path = dir.join(format!("generate_{bucket}.hlo.txt"));
            generates.insert(bucket, compile(&client, &path)?);
        }
        Ok(ModelRuntime {
            meta,
            weights,
            generates,
            _client: client,
        })
    }

    /// Model metadata.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Number of weight tensors (diagnostics).
    pub fn weight_count(&self) -> usize {
        self.weights.len()
    }

    /// Run one full turn: prefill `input_ids` (padded to the bucket) and
    /// greedily decode up to `max_new` tokens, stopping on `stop_id`.
    pub fn generate(
        &self,
        input_ids: &[u32],
        max_new: usize,
        stop_id: u32,
    ) -> Result<RawGeneration> {
        let len = input_ids.len();
        if len == 0 {
            return Err(Error::Runtime("empty input".into()));
        }
        let bucket = self.meta.bucket_for(len)?;
        let max_new = max_new.min(self.meta.max_new);
        let exe = self
            .generates
            .get(&bucket)
            .ok_or_else(|| Error::Runtime(format!("no executable for bucket {bucket}")))?;

        // Pad tokens to the bucket with zeros (masked by `length`).
        let mut tokens = vec![0i32; bucket];
        for (i, &id) in input_ids.iter().enumerate() {
            tokens[i] = id as i32;
        }
        let tokens_lit = Literal::vec1(&tokens);
        let len_lit = Literal::scalar(len as i32);
        let max_new_lit = Literal::scalar(max_new as i32);
        let stop_lit = Literal::scalar(stop_id as i32);

        let mut args: Vec<&Literal> = self.weights.iter().collect();
        args.push(&tokens_lit);
        args.push(&len_lit);
        args.push(&max_new_lit);
        args.push(&stop_lit);

        let t = Instant::now();
        let cpu0 = process_cpu_time();
        let outs = exe.execute::<&Literal>(&args)?;
        let mut tuple = outs[0][0].to_literal_sync()?;
        let execute_s = process_cpu_time() - cpu0;
        let execute_wall_s = t.elapsed().as_secs_f64();

        let parts = tuple.decompose_tuple()?;
        if parts.len() != 2 {
            return Err(Error::Runtime(format!(
                "generate returned {} outputs, expected 2",
                parts.len()
            )));
        }
        let out_ids = parts[0].to_vec::<i32>()?;
        let n_gen = (parts[1].to_vec::<i32>()?[0] as usize).min(out_ids.len());
        let ids = out_ids
            .iter()
            .take(n_gen)
            .map(|&x| x as u32)
            .collect::<Vec<u32>>();
        Ok(RawGeneration {
            ids,
            bucket,
            context_len: len,
            execute_s,
            execute_wall_s,
        })
    }
}

/// Stub runtime for builds without the `pjrt` feature: loading surfaces
/// missing-artifact errors first (same diagnostics as the real runtime),
/// then reports the feature as absent. The accessors exist so PJRT call
/// sites type-check unchanged; they are unreachable because `load` never
/// succeeds.
#[cfg(not(feature = "pjrt"))]
pub struct ModelRuntime {
    meta: ModelMeta,
}

#[cfg(not(feature = "pjrt"))]
impl ModelRuntime {
    /// Always fails: with artifacts absent, like the real runtime; with
    /// artifacts present, because the PJRT stack is not compiled in.
    pub fn load(dir: &Path) -> Result<ModelRuntime> {
        let _meta = ModelMeta::load(dir)?;
        Err(Error::Runtime(
            "PJRT runtime not compiled in (rebuild with `--features pjrt`)".into(),
        ))
    }

    /// Model metadata.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Number of weight tensors (diagnostics).
    pub fn weight_count(&self) -> usize {
        0
    }

    /// Unreachable (construction is impossible without the feature).
    pub fn generate(
        &self,
        _input_ids: &[u32],
        _max_new: usize,
        _stop_id: u32,
    ) -> Result<RawGeneration> {
        Err(Error::Runtime("PJRT runtime not compiled in".into()))
    }
}

#[cfg(feature = "pjrt")]
fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    if !path.exists() {
        return Err(Error::Runtime(format!(
            "artifact missing: {} (run `make artifacts`)",
            path.display()
        )));
    }
    let proto = HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
    )?;
    let comp = XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{
        "vocab_size": 4096, "d_model": 128, "n_layers": 2, "n_heads": 4,
        "head_dim": 32, "ffn": 352, "max_new": 128, "seed": 123,
        "buckets": [128, 256, 512, 1024, 2048]
    }"#;

    #[test]
    fn meta_parses() {
        let m = ModelMeta::from_json(META).unwrap();
        assert_eq!(m.vocab_size, 4096);
        assert_eq!(m.buckets, vec![128, 256, 512, 1024, 2048]);
        assert_eq!(m.max_context(), 2048);
    }

    #[test]
    fn bucket_selection() {
        let m = ModelMeta::from_json(META).unwrap();
        assert_eq!(m.bucket_for(1).unwrap(), 128);
        assert_eq!(m.bucket_for(128).unwrap(), 128);
        assert_eq!(m.bucket_for(129).unwrap(), 256);
        assert_eq!(m.bucket_for(2048).unwrap(), 2048);
        assert!(m.bucket_for(2049).is_err());
    }

    #[test]
    fn meta_rejects_bad_buckets() {
        let bad = META.replace("[128, 256, 512, 1024, 2048]", "[256, 128]");
        assert!(ModelMeta::from_json(&bad).is_err());
        let empty = META.replace("[128, 256, 512, 1024, 2048]", "[]");
        assert!(ModelMeta::from_json(&empty).is_err());
    }

    #[test]
    fn missing_artifacts_reported() {
        let dir = std::env::temp_dir().join("discedge_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let err = match ModelRuntime::load(&dir) {
            Ok(_) => panic!("load must fail without artifacts"),
            Err(e) => e,
        };
        let msg = err.to_string();
        assert!(msg.contains("model_meta.json") || msg.contains("read"), "{msg}");
    }

    // End-to-end runtime tests against real artifacts live in
    // rust/tests/pjrt_integration.rs (they require `make artifacts`).
}
