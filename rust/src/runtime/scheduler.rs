//! Continuous-batching inference scheduler: an admission queue in front
//! of the engine plus a step-granular batch loop.
//!
//! The seed serving path runs every `/completion` solo through
//! [`Engine::generate`] — under concurrent load the device serializes
//! whole turns and time-to-first-token (TTFT) grows with queue depth.
//! [`BatchScheduler`] wraps an engine and coalesces concurrent requests
//! at **decode-step granularity** instead:
//!
//! - **admit** — requests enter a bounded admission queue; beyond
//!   `queue_depth` they are rejected with [`Error::Unavailable`]
//!   (HTTP 503) so queue wait cannot grow without bound;
//! - **join** — the batch loop drains admitted requests whenever the
//!   running batch has room (`max_batch`), prefills each
//!   ([`Engine::prefill`]), and adds its [`StepState`] to the batch —
//!   no waiting for the current batch to finish;
//! - **step** — one [`Engine::decode_step`] advances every running
//!   sequence together; each produced token is forwarded to its waiting
//!   request immediately (this is what the streamed `/completion` path
//!   sends down the wire as a chunk);
//! - **leave** — sequences retire individually on stop-token or
//!   `max_tokens`; the rest of the batch keeps decoding.
//!
//! The scheduler itself implements [`Engine`], so the context manager's
//! request path is unchanged: `generate` submits and blocks for the
//! full output, `generate_streamed` submits and relays tokens as steps
//! complete. Engines whose executable fuses prefill and decode (the
//! PJRT path) fall back to the default buffered step API and still gain
//! admission control and streaming, just not cross-request batching.
//!
//! Metrics (written into the node registry, scraped via `/metrics`):
//! `llm_queue_wait_s` (admission latency), `llm_ttft_s` (submit to
//! first token), `llm_batch_size` (batch occupancy per step), and the
//! `llm_admission_rejects` counter.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::InferenceConfig;
use crate::llm::{Engine, GenOutput, StepState};
use crate::metrics::Registry;
use crate::sync::{classes, OrderedMutex};
use crate::{Error, Result};

/// What the batch loop reports back to a waiting request.
enum SeqEvent {
    /// One decoded token (forwarded as a step completes).
    Token(u32),
    /// The sequence finished (or failed); terminal.
    Done(Result<GenOutput>),
}

/// One queued request.
struct Job {
    input_ids: Vec<u32>,
    max_tokens: usize,
    stop_id: u32,
    events: Sender<SeqEvent>,
    submitted: Instant,
}

/// Request-side bookkeeping for a running sequence, index-aligned with
/// its [`StepState`] in the batch.
struct SeqMeta {
    events: Sender<SeqEvent>,
    submitted: Instant,
    first_token: bool,
    /// The waiting request hung up (channel closed); decode stops early
    /// and the sequence retires without a `Done`.
    dead: bool,
}

/// Admission queue state under [`classes::SCHED_ADMISSION`].
struct AdmissionQueue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    inner: Arc<dyn Engine>,
    registry: Arc<Registry>,
    max_batch: usize,
    queue_depth: usize,
    admission: OrderedMutex<AdmissionQueue>,
    cvar: Condvar,
    /// Running batch size, mirrored for `/status` without touching the
    /// queue lock.
    batch: AtomicUsize,
}

/// Admission queue + continuous-batching loop in front of an engine.
/// See the module docs for the admit → join → step → leave lifecycle.
pub struct BatchScheduler {
    shared: Arc<Shared>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl BatchScheduler {
    /// Wrap `inner`, spawning the batch loop thread. `registry` receives
    /// the `llm_*` scheduler metrics.
    pub fn new(inner: Arc<dyn Engine>, cfg: &InferenceConfig, registry: Arc<Registry>) -> Self {
        // Pre-register the reject counter so `/metrics` exports it as 0
        // before the first overload instead of omitting it.
        registry.incr("llm_admission_rejects", 0);
        let shared = Arc::new(Shared {
            inner,
            registry,
            max_batch: cfg.max_batch.max(1),
            queue_depth: cfg.queue_depth.max(1),
            admission: OrderedMutex::new(
                &classes::SCHED_ADMISSION,
                AdmissionQueue {
                    jobs: VecDeque::new(),
                    shutdown: false,
                },
            ),
            cvar: Condvar::new(),
            batch: AtomicUsize::new(0),
        });
        let loop_shared = shared.clone();
        let worker = std::thread::Builder::new()
            .name("inference-sched".into())
            .spawn(move || batch_loop(&loop_shared))
            .expect("spawn inference scheduler thread");
        BatchScheduler {
            shared,
            worker: Mutex::new(Some(worker)),
        }
    }

    /// Requests waiting for admission (for `/status`).
    pub fn queue_len(&self) -> usize {
        self.shared.admission.lock().unwrap().jobs.len()
    }

    /// Sequences in the running batch (for `/status`).
    pub fn batch_size(&self) -> usize {
        self.shared.batch.load(Ordering::Relaxed)
    }

    /// Stop the batch loop: queued-but-unadmitted requests fail, running
    /// sequences decode to completion, then the thread exits. Idempotent.
    pub fn shutdown(&self) {
        {
            let mut q = self.shared.admission.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cvar.notify_all();
        if let Some(handle) = self.worker.lock().unwrap().take() {
            let _ = handle.join();
        }
    }

    /// Enqueue one request, rejecting with [`Error::Unavailable`] when
    /// the admission queue is at `queue_depth`.
    fn submit(
        &self,
        input_ids: &[u32],
        max_tokens: usize,
        stop_id: u32,
        events: Sender<SeqEvent>,
    ) -> Result<()> {
        let full = {
            let mut q = self.shared.admission.lock().unwrap();
            if q.shutdown {
                return Err(Error::Engine("inference scheduler is shut down".into()));
            }
            if q.jobs.len() >= self.shared.queue_depth {
                true
            } else {
                q.jobs.push_back(Job {
                    input_ids: input_ids.to_vec(),
                    max_tokens,
                    stop_id,
                    events,
                    submitted: Instant::now(),
                });
                false
            }
        };
        if full {
            self.shared.registry.incr("llm_admission_rejects", 1);
            return Err(Error::Unavailable(format!(
                "admission queue full ({} waiting)",
                self.shared.queue_depth
            )));
        }
        self.shared.cvar.notify_all();
        Ok(())
    }
}

impl Drop for BatchScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Engine for BatchScheduler {
    fn model_name(&self) -> &str {
        self.shared.inner.model_name()
    }

    fn max_context(&self) -> usize {
        self.shared.inner.max_context()
    }

    fn generate(&self, input_ids: &[u32], max_tokens: usize, stop_id: u32) -> Result<GenOutput> {
        let (tx, rx) = channel();
        self.submit(input_ids, max_tokens, stop_id, tx)?;
        loop {
            match rx.recv() {
                Ok(SeqEvent::Token(_)) => {}
                Ok(SeqEvent::Done(res)) => return res,
                Err(_) => {
                    return Err(Error::Engine(
                        "inference scheduler dropped an in-flight sequence".into(),
                    ))
                }
            }
        }
    }

    fn generate_streamed(
        &self,
        input_ids: &[u32],
        max_tokens: usize,
        stop_id: u32,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<GenOutput> {
        let (tx, rx) = channel();
        self.submit(input_ids, max_tokens, stop_id, tx)?;
        loop {
            match rx.recv() {
                Ok(SeqEvent::Token(id)) => on_token(id),
                Ok(SeqEvent::Done(res)) => return res,
                Err(_) => {
                    return Err(Error::Engine(
                        "inference scheduler dropped an in-flight sequence".into(),
                    ))
                }
            }
        }
    }
}

/// The batch loop: admit up to capacity, prefill joiners, advance the
/// whole batch one decode step, retire finished sequences — repeat.
/// The admission lock is held only while draining jobs, never across
/// engine work.
fn batch_loop(shared: &Shared) {
    let mut states: Vec<StepState> = Vec::new();
    let mut meta: Vec<SeqMeta> = Vec::new();
    loop {
        let mut admitted: Vec<Job> = Vec::new();
        let shutting_down = {
            let mut q = shared.admission.lock().unwrap();
            while q.jobs.is_empty() && !q.shutdown && states.is_empty() {
                q = q.wait(&shared.cvar).unwrap();
            }
            if q.shutdown {
                for job in q.jobs.drain(..) {
                    let _ = job.events.send(SeqEvent::Done(Err(Error::Engine(
                        "inference scheduler shut down before the request was admitted".into(),
                    ))));
                }
            } else {
                while states.len() + admitted.len() < shared.max_batch {
                    match q.jobs.pop_front() {
                        Some(job) => admitted.push(job),
                        None => break,
                    }
                }
            }
            q.shutdown
        };
        if shutting_down && states.is_empty() {
            shared.batch.store(0, Ordering::Relaxed);
            return;
        }

        // Join: prefill the newly admitted sequences (outside the lock —
        // prefill is real engine work).
        for job in admitted {
            shared
                .registry
                .observe("llm_queue_wait_s", job.submitted.elapsed().as_secs_f64());
            match shared
                .inner
                .prefill(&job.input_ids, job.max_tokens, job.stop_id)
            {
                Ok(state) => {
                    states.push(state);
                    meta.push(SeqMeta {
                        events: job.events,
                        submitted: job.submitted,
                        first_token: false,
                        dead: false,
                    });
                }
                Err(e) => {
                    let _ = job.events.send(SeqEvent::Done(Err(e)));
                }
            }
        }
        // A prefill can finish a sequence outright (empty generation).
        retire_finished(&mut states, &mut meta);
        shared.batch.store(states.len(), Ordering::Relaxed);
        if states.is_empty() {
            continue;
        }

        // Step: advance every running sequence together.
        shared
            .registry
            .observe("llm_batch_size", states.len() as f64);
        match shared.inner.decode_step(&mut states) {
            Ok(tokens) => {
                for (i, tok) in tokens.iter().enumerate() {
                    let Some(id) = tok else { continue };
                    if !meta[i].first_token {
                        meta[i].first_token = true;
                        shared
                            .registry
                            .observe("llm_ttft_s", meta[i].submitted.elapsed().as_secs_f64());
                    }
                    if meta[i].events.send(SeqEvent::Token(*id)).is_err() {
                        meta[i].dead = true;
                    }
                }
            }
            Err(e) => {
                // A whole-batch failure kills every in-flight sequence.
                let msg = e.to_string();
                for (_state, m) in states.drain(..).zip(meta.drain(..)) {
                    let _ = m
                        .events
                        .send(SeqEvent::Done(Err(Error::Engine(msg.clone()))));
                }
                shared.batch.store(0, Ordering::Relaxed);
                continue;
            }
        }

        // Leave: finished sequences retire individually.
        retire_finished(&mut states, &mut meta);
        shared.batch.store(states.len(), Ordering::Relaxed);
    }
}

/// Remove finished (or abandoned) sequences, sending each its final
/// [`GenOutput`]. Both vectors are swap-removed at the same index so
/// they stay aligned.
fn retire_finished(states: &mut Vec<StepState>, meta: &mut Vec<SeqMeta>) {
    let mut i = 0;
    while i < states.len() {
        if states[i].done() || meta[i].dead {
            let state = states.swap_remove(i);
            let m = meta.swap_remove(i);
            if !m.dead {
                let _ = m.events.send(SeqEvent::Done(Ok(state.into_output())));
            }
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::MockEngine;
    use std::time::Duration;

    fn scheduler(engine: MockEngine, cfg: &InferenceConfig) -> (Arc<BatchScheduler>, Arc<Registry>) {
        let registry = Arc::new(Registry::new());
        let sched = Arc::new(BatchScheduler::new(
            Arc::new(engine),
            cfg,
            registry.clone(),
        ));
        (sched, registry)
    }

    #[test]
    fn batched_transcripts_match_solo_generate() {
        // The scheduler must be invisible to outputs: concurrent
        // requests through the batch loop produce exactly the ids a
        // solo `generate` produces for the same input.
        let solo = MockEngine::new("m", 512);
        let cfg = InferenceConfig {
            enabled: true,
            max_batch: 4,
            queue_depth: 64,
            stream: false,
        };
        let (sched, _reg) = scheduler(MockEngine::new("m", 512), &cfg);
        let inputs: Vec<Vec<u32>> = (0..8u32).map(|i| vec![i, i + 1, i + 2]).collect();
        let handles: Vec<_> = inputs
            .iter()
            .map(|ids| {
                let sched = sched.clone();
                let ids = ids.clone();
                std::thread::spawn(move || sched.generate(&ids, 16, 9999).unwrap())
            })
            .collect();
        for (ids, h) in inputs.iter().zip(handles) {
            let batched = h.join().unwrap();
            let expect = solo.generate(ids, 16, 9999).unwrap();
            assert_eq!(batched.ids, expect.ids, "input {ids:?}");
            assert_eq!(batched.prefill_tokens, expect.prefill_tokens);
        }
    }

    #[test]
    fn streamed_tokens_match_the_final_output() {
        let cfg = InferenceConfig {
            enabled: true,
            max_batch: 2,
            queue_depth: 8,
            stream: true,
        };
        let (sched, _reg) = scheduler(MockEngine::new("m", 512), &cfg);
        let mut seen = Vec::new();
        let out = sched
            .generate_streamed(&[5, 6, 7], 12, 9999, &mut |id| seen.push(id))
            .unwrap();
        assert!(!out.ids.is_empty());
        assert_eq!(seen, out.ids, "every token is forwarded exactly once");
    }

    #[test]
    fn admission_queue_bound_rejects_with_unavailable() {
        // max_batch 1 + queue_depth 1 + a slow engine: one request
        // runs, one waits, the third must bounce with 503 semantics.
        let slow = MockEngine::new("m", 512)
            .with_costs(0, 2_000_000)
            .with_fixed_len(50);
        let cfg = InferenceConfig {
            enabled: true,
            max_batch: 1,
            queue_depth: 1,
            stream: false,
        };
        let (sched, reg) = scheduler(slow, &cfg);
        let a = {
            let sched = sched.clone();
            std::thread::spawn(move || sched.generate(&[1], 50, 9999))
        };
        // Let A reach the running batch so B occupies the queue slot.
        std::thread::sleep(Duration::from_millis(30));
        let b = {
            let sched = sched.clone();
            std::thread::spawn(move || sched.generate(&[2], 50, 9999))
        };
        std::thread::sleep(Duration::from_millis(10));
        let err = sched.generate(&[3], 50, 9999).unwrap_err();
        assert!(
            matches!(err, Error::Unavailable(_)),
            "expected Unavailable, got {err:?}"
        );
        assert!(reg.counter("llm_admission_rejects") >= 1);
        assert!(a.join().unwrap().is_ok());
        assert!(b.join().unwrap().is_ok());
    }

    #[test]
    fn scheduler_records_ttft_queue_wait_and_batch_size() {
        let cfg = InferenceConfig {
            enabled: true,
            max_batch: 4,
            queue_depth: 16,
            stream: false,
        };
        let (sched, reg) = scheduler(MockEngine::new("m", 512).with_costs(1000, 10_000), &cfg);
        sched.generate(&[1, 2, 3], 8, 9999).unwrap();
        assert!(reg.series("llm_ttft_s").len() >= 1);
        assert!(reg.series("llm_queue_wait_s").len() >= 1);
        assert!(reg.series("llm_batch_size").len() >= 1);
        assert!(reg.series("llm_batch_size").samples().iter().all(|&b| b >= 1.0));
        assert_eq!(reg.counter("llm_admission_rejects"), 0);
    }

    #[test]
    fn shutdown_fails_queued_jobs_and_joins_the_loop() {
        let slow = MockEngine::new("m", 512)
            .with_costs(0, 2_000_000)
            .with_fixed_len(40);
        let cfg = InferenceConfig {
            enabled: true,
            max_batch: 1,
            queue_depth: 8,
            stream: false,
        };
        let (sched, _reg) = scheduler(slow, &cfg);
        let a = {
            let sched = sched.clone();
            std::thread::spawn(move || sched.generate(&[1], 40, 9999))
        };
        std::thread::sleep(Duration::from_millis(20));
        let b = {
            let sched = sched.clone();
            std::thread::spawn(move || sched.generate(&[2], 40, 9999))
        };
        std::thread::sleep(Duration::from_millis(10));
        sched.shutdown();
        // A was running: it decodes to completion. B was queued: it
        // fails instead of running after shutdown.
        assert!(a.join().unwrap().is_ok());
        assert!(b.join().unwrap().is_err());
        // Idempotent.
        sched.shutdown();
    }

    #[test]
    fn queue_and_batch_snapshots_settle_to_zero() {
        let cfg = InferenceConfig::default();
        let (sched, _reg) = scheduler(MockEngine::new("m", 512), &cfg);
        sched.generate(&[9], 4, 9999).unwrap();
        assert_eq!(sched.queue_len(), 0);
        // The loop parks with an empty batch once the request retires.
        for _ in 0..100 {
            if sched.batch_size() == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(sched.batch_size(), 0);
    }
}
