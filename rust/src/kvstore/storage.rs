//! Opt-in local persistence for the KV replica: write-ahead log +
//! periodic snapshot (ROADMAP item 1; Dynamo-style pluggable local
//! store, here a single engine).
//!
//! **Default off.** With `storage.enabled = false` nothing in this module
//! runs — no directory is touched, no bytes are cloned on the write path,
//! and the store behaves byte-for-byte like the seed (the same contract
//! PRs 1–5 kept for their features).
//!
//! **On-disk format.** Two files in `storage.dir`: `wal.log` (append-only)
//! and `snapshot.log` (rewritten wholesale at each compaction). Both use
//! the same record framing:
//!
//! ```text
//! [u32 LE payload_len][u64 LE fnv1a(payload)][payload]
//! ```
//!
//! The payload is one JSON object, e.g.
//! `{"exp":1765432100000,"key":"u/s","kg":"model","op":"put","val":"…","ver":7}`
//! (`exp`, an absolute unix-epoch deadline in ms, is present only for TTL
//! entries; `val` only for puts; deletes carry the removed entry's
//! version so replay stays order-safe — see below). The per-record
//! checksum is what turns a torn tail (a crash mid-append) into a
//! *detected* truncation instead of a misapplied garbage record.
//!
//! **Recovery ordering.** `Storage::recover` replays `snapshot.log` then
//! `wal.log` into a fresh [`Store`] *before* the node wires replication,
//! hint replay, or anti-entropy — so the cheap local copy is in place
//! first and the network paths only reconcile the tail. Replay is safe
//! under the crash window between snapshot-rename and WAL-truncate
//! because every record is LWW-idempotent: puts re-apply at equal version
//! and are rejected when stale, and deletes apply only when the live
//! entry's version is `<=` the version captured at delete time.
//!
//! **TTLs.** Records persist absolute expiry deadlines (unix epoch ms),
//! not remaining durations: an entry that expired while the node was down
//! is skipped on replay, never resurrected.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use super::Store;
use crate::json::{self, Value};
use crate::sync::{classes, OrderedMutex};
use crate::testkit::fnv1a;
use crate::{Error, Result};

/// Local persistence knobs (`storage.*` in the cluster config). Default
/// **off**: the seed's memory-only replica, byte-for-byte.
#[derive(Debug, Clone)]
pub struct StorageConfig {
    /// Master switch. Off = no WAL, no snapshot, no recovery.
    pub enabled: bool,
    /// Directory holding `wal.log` and `snapshot.log`. Cluster launch
    /// appends the node name so fleet members never share files.
    pub dir: PathBuf,
    /// Compact (snapshot + WAL reset) after this many WAL appends.
    pub snapshot_every: u64,
    /// fsync the WAL after every append and the snapshot before rename.
    /// Off trades durability-to-media for speed (data still survives a
    /// process crash either way; only a whole-host crash can lose the
    /// page-cache tail).
    pub fsync: bool,
}

impl Default for StorageConfig {
    fn default() -> StorageConfig {
        StorageConfig {
            enabled: false,
            dir: PathBuf::from("discedge-data"),
            snapshot_every: 4096,
            fsync: false,
        }
    }
}

/// Framing overhead per record: u32 length + u64 checksum.
const HEADER_LEN: usize = 12;
/// Upper bound on a sane payload; anything larger read back from disk is
/// treated as tail corruption.
const MAX_PAYLOAD: u32 = 64 * 1024 * 1024;

/// WAL writer state behind one mutex: appenders serialize here, and the
/// snapshotter holds it across collect+rename+truncate so no append can
/// slip between the state capture and the WAL reset (which would lose
/// the record). Lock order: callers must NEVER hold a store shard lock
/// when taking this mutex — the snapshotter takes shard read locks while
/// holding it.
struct Wal {
    file: File,
    /// Appends since the last snapshot (drives `snapshot_every`).
    appends: u64,
}

/// One node's persistence engine. Cheap to share (`Arc`); all methods
/// take `&self`.
pub struct Storage {
    dir: PathBuf,
    fsync: bool,
    snapshot_every: u64,
    wal: OrderedMutex<Wal>,
    wal_appends: AtomicU64,
    wal_bytes: AtomicU64,
    snapshots: AtomicU64,
    recovered: AtomicU64,
    truncations: AtomicU64,
    /// Completion time of the most recent snapshot (terminal leaf state:
    /// plain mutex, never held across another lock). Feeds `/status`.
    last_snapshot: Mutex<Option<Instant>>,
}

impl std::fmt::Debug for Storage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Storage").field("dir", &self.dir).finish()
    }
}

fn unix_ms_now() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_millis() as u64
}

/// Encode one record into its framed byte form.
fn frame(payload: &str) -> Vec<u8> {
    let bytes = payload.as_bytes();
    let mut out = Vec::with_capacity(HEADER_LEN + bytes.len());
    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a(bytes).to_le_bytes());
    out.extend_from_slice(bytes);
    out
}

/// One decoded log record.
struct Record {
    op: String,
    keygroup: String,
    key: String,
    version: u64,
    value: Option<String>,
    /// Absolute expiry, unix epoch ms.
    expires_unix_ms: Option<u64>,
}

impl Record {
    fn parse(payload: &str) -> Result<Record> {
        let v = json::parse(payload)?;
        Ok(Record {
            op: v.req_str("op")?,
            keygroup: v.req_str("kg")?,
            key: v.req_str("key")?,
            version: v.req_u64("ver")?,
            value: v.get("val").and_then(|x| x.as_str()).map(|s| s.to_string()),
            expires_unix_ms: v.get("exp").and_then(|x| x.as_u64()),
        })
    }
}

/// Read every intact record off `file`, calling `apply` per record.
/// Returns the byte offset just past the last intact record and whether
/// the scan stopped early on a torn/corrupt tail.
fn scan(file: &mut File, mut apply: impl FnMut(Record)) -> Result<(u64, bool)> {
    file.seek(SeekFrom::Start(0))?;
    let mut buf = Vec::new();
    file.read_to_end(&mut buf)?;
    let mut pos = 0usize;
    loop {
        let rest = buf.len() - pos;
        if rest == 0 {
            return Ok((pos as u64, false));
        }
        if rest < HEADER_LEN {
            return Ok((pos as u64, true));
        }
        // Infallible header decode: the `rest >= HEADER_LEN` check above
        // guarantees the slices exist, so no unwrap on the recovery path.
        let mut len_b = [0u8; 4];
        len_b.copy_from_slice(&buf[pos..pos + 4]);
        let len = u32::from_le_bytes(len_b);
        let mut sum_b = [0u8; 8];
        sum_b.copy_from_slice(&buf[pos + 4..pos + 12]);
        let sum = u64::from_le_bytes(sum_b);
        if len > MAX_PAYLOAD || rest - HEADER_LEN < len as usize {
            return Ok((pos as u64, true));
        }
        let payload = &buf[pos + HEADER_LEN..pos + HEADER_LEN + len as usize];
        if fnv1a(payload) != sum {
            return Ok((pos as u64, true));
        }
        let text = match std::str::from_utf8(payload) {
            Ok(t) => t,
            Err(_) => return Ok((pos as u64, true)),
        };
        match Record::parse(text) {
            Ok(r) => apply(r),
            // A checksummed-but-unparseable record means a writer bug,
            // not a torn write; still safer to stop than to guess.
            Err(_) => return Ok((pos as u64, true)),
        }
        pos += HEADER_LEN + len as usize;
    }
}

impl Storage {
    /// Open (creating if needed) the persistence directory and WAL.
    pub fn open(cfg: &StorageConfig) -> Result<Arc<Storage>> {
        if cfg.dir.as_os_str().is_empty() {
            return Err(Error::Config("storage.dir must be set".into()));
        }
        std::fs::create_dir_all(&cfg.dir)?;
        let file = OpenOptions::new()
            .create(true)
            .read(true)
            .append(true)
            .open(cfg.dir.join("wal.log"))?;
        Ok(Arc::new(Storage {
            dir: cfg.dir.clone(),
            fsync: cfg.fsync,
            snapshot_every: cfg.snapshot_every.max(1),
            wal: OrderedMutex::new(&classes::STORAGE_WAL, Wal { file, appends: 0 }),
            wal_appends: AtomicU64::new(0),
            wal_bytes: AtomicU64::new(0),
            snapshots: AtomicU64::new(0),
            recovered: AtomicU64::new(0),
            truncations: AtomicU64::new(0),
            last_snapshot: Mutex::new(None),
        }))
    }

    /// Replay snapshot + WAL into `store`. Call on a fresh store, before
    /// [`Store::install_storage`] (replay must not re-log itself) and
    /// before any forest install or network wiring — recovery-from-disk
    /// comes first, hint replay and anti-entropy reconcile the tail.
    ///
    /// A torn or corrupt WAL tail is truncated at the last intact record;
    /// snapshot corruption just stops the snapshot scan (the file is
    /// replaced wholesale at the next compaction).
    pub fn recover(&self, store: &Store) -> Result<()> {
        let now = unix_ms_now();
        let mut applied = 0u64;
        let mut groups = std::collections::HashSet::new();
        let mut replay = |r: Record| {
            groups.insert(r.keygroup.clone());
            // Convert the absolute deadline back to a remaining TTL;
            // already-expired entries are skipped, never resurrected.
            let ttl = match r.expires_unix_ms {
                Some(exp) if exp <= now => return,
                Some(exp) => Some(Duration::from_millis(exp - now)),
                None => None,
            };
            match r.op.as_str() {
                "put" => {
                    if let Some(val) = r.value {
                        if store.apply(&r.keygroup, &r.key, val, r.version, ttl) {
                            applied += 1;
                        }
                    }
                }
                "del" => {
                    if store.remove_if_not_newer(&r.keygroup, &r.key, r.version) {
                        applied += 1;
                    }
                }
                _ => {}
            }
        };
        let snap_path = self.dir.join("snapshot.log");
        if snap_path.exists() {
            let mut snap = File::open(&snap_path)?;
            let (_, torn) = scan(&mut snap, &mut replay)?;
            if torn {
                self.truncations.fetch_add(1, Ordering::SeqCst);
            }
        }
        {
            let mut wal = self.wal.lock().unwrap();
            let (good, torn) = scan(&mut wal.file, &mut replay)?;
            if torn {
                wal.file.set_len(good)?;
                self.truncations.fetch_add(1, Ordering::SeqCst);
            }
            // Leave the cursor at the end for subsequent appends (append
            // mode repositions per write, but keep the handle sane).
            wal.file.seek(SeekFrom::End(0))?;
        }
        // Re-register the keygroups the records belonged to, so the
        // recovered entries are visible to anti-entropy digests (and
        // writable) before the serving layer re-creates them.
        store.keygroups.write().unwrap().extend(groups);
        self.recovered.fetch_add(applied, Ordering::SeqCst);
        Ok(())
    }

    fn record_json(
        op: &str,
        keygroup: &str,
        key: &str,
        version: u64,
        value: Option<&str>,
        expires_unix_ms: Option<u64>,
    ) -> String {
        let mut v = Value::obj()
            .set("op", op)
            .set("kg", keygroup)
            .set("key", key)
            .set("ver", version);
        if let Some(val) = value {
            v = v.set("val", val);
        }
        if let Some(exp) = expires_unix_ms {
            v = v.set("exp", exp);
        }
        v.to_json()
    }

    fn append(&self, payload: &str) {
        let framed = frame(payload);
        let mut wal = self.wal.lock().unwrap();
        // Persistence is best-effort below the store's in-memory truth: a
        // full disk degrades durability, not availability.
        if wal.file.write_all(&framed).is_err() {
            return;
        }
        if self.fsync {
            let _ = wal.file.sync_data();
        }
        wal.appends += 1;
        drop(wal);
        self.wal_appends.fetch_add(1, Ordering::SeqCst);
        self.wal_bytes.fetch_add(framed.len() as u64, Ordering::SeqCst);
    }

    /// Log an applied write. Caller must have released all store locks.
    pub(super) fn log_put(
        &self,
        keygroup: &str,
        key: &str,
        value: &str,
        version: u64,
        ttl: Option<Duration>,
    ) {
        let exp = ttl.map(|t| unix_ms_now().saturating_add(t.as_millis() as u64));
        self.append(&Self::record_json("put", keygroup, key, version, Some(value), exp));
    }

    /// Log an applied delete; `version` is the removed entry's version,
    /// which makes WAL replay order-safe against the snapshot crash
    /// window (a delete never clobbers a newer recovered put).
    pub(super) fn log_delete(&self, keygroup: &str, key: &str, version: u64) {
        self.append(&Self::record_json("del", keygroup, key, version, None, None));
    }

    /// Compact if `snapshot_every` appends accumulated since the last
    /// snapshot. Called from the mutation path (after locks drop) and the
    /// janitor; errors are swallowed — the WAL keeps growing and the next
    /// trigger retries.
    pub fn maybe_snapshot(&self, store: &Store) {
        let due = self.wal.lock().unwrap().appends >= self.snapshot_every;
        if due {
            let _ = self.snapshot(store);
        }
    }

    /// Write a full snapshot and reset the WAL.
    ///
    /// Holds the WAL mutex across the whole operation so no append can
    /// land between the state capture and the WAL truncate. Crash-window
    /// analysis: tmp-write then atomic rename, so a crash leaves either
    /// the old snapshot + full WAL (nothing lost) or the new snapshot +
    /// not-yet-truncated WAL (replay is LWW-idempotent, nothing
    /// misapplied).
    pub fn snapshot(&self, store: &Store) -> Result<()> {
        let mut wal = self.wal.lock().unwrap();
        let now_ms = unix_ms_now();
        let mut out = Vec::new();
        for (keygroup, key, value, version, remaining) in store.dump_live() {
            let exp = remaining.map(|d| now_ms.saturating_add(d.as_millis() as u64));
            let payload =
                Self::record_json("put", &keygroup, &key, version, Some(&value), exp);
            out.extend_from_slice(&frame(&payload));
        }
        let tmp = self.dir.join("snapshot.tmp");
        let final_path = self.dir.join("snapshot.log");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&out)?;
            if self.fsync {
                f.sync_data()?;
            }
        }
        std::fs::rename(&tmp, &final_path)?;
        wal.file.set_len(0)?;
        wal.file.seek(SeekFrom::End(0))?;
        wal.appends = 0;
        drop(wal);
        self.snapshots.fetch_add(1, Ordering::SeqCst);
        *self.last_snapshot.lock().unwrap() = Some(Instant::now());
        Ok(())
    }

    /// WAL records appended since start (`kv_wal_appends`).
    pub fn wal_appends(&self) -> u64 {
        self.wal_appends.load(Ordering::SeqCst)
    }

    /// Framed WAL bytes written since start (`kv_wal_bytes`).
    pub fn wal_bytes(&self) -> u64 {
        self.wal_bytes.load(Ordering::SeqCst)
    }

    /// Snapshots taken since start (`kv_snapshots`).
    pub fn snapshots(&self) -> u64 {
        self.snapshots.load(Ordering::SeqCst)
    }

    /// Records applied to the store by [`Storage::recover`]
    /// (`kv_recovered_entries`).
    pub fn recovered_entries(&self) -> u64 {
        self.recovered.load(Ordering::SeqCst)
    }

    /// Torn/corrupt tails detected and cut off during recovery
    /// (`kv_wal_truncations`).
    pub fn wal_truncations(&self) -> u64 {
        self.truncations.load(Ordering::SeqCst)
    }

    /// Time since the last snapshot completed; `None` before the first.
    pub fn snapshot_age(&self) -> Option<Duration> {
        self.last_snapshot.lock().unwrap().map(|t| t.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{corrupt_file_tail, truncate_file_tail};

    /// Fresh per-test directory under the system tmp root.
    fn tmp_cfg(tag: &str) -> StorageConfig {
        let dir = std::env::temp_dir().join(format!(
            "discedge-storage-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        StorageConfig {
            enabled: true,
            dir,
            ..StorageConfig::default()
        }
    }

    /// `(keygroup, key, value, version)` of every live entry, sorted —
    /// the TTL-free canonical state for equality asserts.
    fn state(store: &Store) -> Vec<(String, String, String, u64)> {
        let mut v: Vec<_> = store
            .dump_live()
            .into_iter()
            .map(|(kg, k, val, ver, _)| (kg, k, val, ver))
            .collect();
        v.sort();
        v
    }

    #[test]
    fn wal_replays_puts_and_versioned_deletes() {
        let cfg = tmp_cfg("replay");
        let a = Store::new();
        let s = Storage::open(&cfg).unwrap();
        a.install_storage(s.clone());
        a.apply("m", "keep", "v1".into(), 1, None);
        a.apply("m", "keep", "v2".into(), 2, None);
        a.apply("m", "gone", "x".into(), 1, None);
        a.remove("m", "gone");
        a.apply("m", "other", "y".into(), 5, None);
        assert_eq!(s.wal_appends(), 5);
        assert!(s.wal_bytes() > 0);
        drop(s);

        let b = Store::new();
        let s2 = Storage::open(&cfg).unwrap();
        s2.recover(&b).unwrap();
        assert_eq!(state(&b), state(&a), "recovered state must match");
        assert!(s2.recovered_entries() >= 3);
        assert_eq!(s2.wal_truncations(), 0);
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn torn_tail_is_truncated_never_misapplied() {
        let cfg = tmp_cfg("torn");
        let a = Store::new();
        let s = Storage::open(&cfg).unwrap();
        a.install_storage(s.clone());
        a.apply("m", "first", "ok".into(), 1, None);
        a.apply("m", "second", "also-ok".into(), 1, None);
        a.apply("m", "torn", "half-written".into(), 1, None);
        drop(s);
        // Model a crash mid-append: the last record loses its tail.
        let wal = cfg.dir.join("wal.log");
        truncate_file_tail(&wal, 5);

        let b = Store::new();
        let s2 = Storage::open(&cfg).unwrap();
        s2.recover(&b).unwrap();
        assert_eq!(s2.wal_truncations(), 1);
        assert!(b.read("m", "first").is_some());
        assert!(b.read("m", "second").is_some());
        assert!(b.read("m", "torn").is_none(), "torn record must not apply");
        // The truncation is durable: a third open sees a clean log.
        drop(s2);
        let c = Store::new();
        let s3 = Storage::open(&cfg).unwrap();
        s3.recover(&c).unwrap();
        assert_eq!(s3.wal_truncations(), 0, "tail was cut, log is clean now");
        assert_eq!(state(&c), state(&b));
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn corrupt_tail_fails_the_checksum_and_is_cut() {
        let cfg = tmp_cfg("corrupt");
        let a = Store::new();
        let s = Storage::open(&cfg).unwrap();
        a.install_storage(s.clone());
        a.apply("m", "good", "ok".into(), 1, None);
        a.apply("m", "bad", "bit-rotted".into(), 1, None);
        drop(s);
        // Same length, flipped bits: only the per-record checksum can
        // tell — a length-only framing would misapply garbage here.
        corrupt_file_tail(&cfg.dir.join("wal.log"), 4);

        let b = Store::new();
        let s2 = Storage::open(&cfg).unwrap();
        s2.recover(&b).unwrap();
        assert_eq!(s2.wal_truncations(), 1);
        assert!(b.read("m", "good").is_some());
        assert!(b.read("m", "bad").is_none());
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn snapshot_compacts_the_wal_and_recovers() {
        let cfg = tmp_cfg("snapshot");
        let a = Store::new();
        let s = Storage::open(&cfg).unwrap();
        a.install_storage(s.clone());
        for i in 0..20u64 {
            a.apply("m", "doc", format!("v{i}"), i + 1, None);
        }
        s.snapshot(&a).unwrap();
        assert_eq!(s.snapshots(), 1);
        assert_eq!(
            std::fs::metadata(cfg.dir.join("wal.log")).unwrap().len(),
            0,
            "snapshot resets the WAL"
        );
        // Post-snapshot writes land in the fresh WAL.
        a.apply("m", "doc", "v-after".into(), 99, None);
        drop(s);

        let b = Store::new();
        let s2 = Storage::open(&cfg).unwrap();
        s2.recover(&b).unwrap();
        assert_eq!(state(&b), state(&a), "snapshot + WAL tail must recover");
        assert_eq!(b.read("m", "doc").unwrap().version, 99);
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn expired_entries_are_not_resurrected() {
        let cfg = tmp_cfg("ttl");
        let a = Store::new();
        let s = Storage::open(&cfg).unwrap();
        a.install_storage(s.clone());
        a.apply("m", "flash", "gone-soon".into(), 1, Some(Duration::from_millis(1)));
        a.apply("m", "stays", "long-lived".into(), 1, Some(Duration::from_secs(3600)));
        std::thread::sleep(Duration::from_millis(10));
        drop(s);

        let b = Store::new();
        let s2 = Storage::open(&cfg).unwrap();
        s2.recover(&b).unwrap();
        assert!(
            b.read("m", "flash").is_none(),
            "an entry that expired during downtime must stay dead"
        );
        let stays = b.read("m", "stays").expect("unexpired entry recovers");
        assert!(stays.expires_at.is_some(), "TTL survives the round trip");
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn wal_delete_does_not_clobber_newer_snapshot_entry() {
        // The snapshot-then-truncate crash window: the WAL still holds
        // [put v1, del@v1, put v2] while the snapshot already has v2.
        // Replaying both must end at v2 — the versioned delete is what
        // prevents the del from eating the snapshot's newer entry.
        let cfg = tmp_cfg("delwindow");
        let a = Store::new();
        let s = Storage::open(&cfg).unwrap();
        a.install_storage(s.clone());
        a.apply("m", "doc", "v1".into(), 1, None);
        a.remove("m", "doc");
        a.apply("m", "doc", "v2".into(), 2, None);
        // Crash window: snapshot written but WAL NOT truncated.
        {
            let wal_bytes = std::fs::read(cfg.dir.join("wal.log")).unwrap();
            s.snapshot(&a).unwrap();
            std::fs::write(cfg.dir.join("wal.log"), &wal_bytes).unwrap();
        }
        drop(s);

        let b = Store::new();
        let s2 = Storage::open(&cfg).unwrap();
        s2.recover(&b).unwrap();
        let doc = b.read("m", "doc").expect("doc survives the replay");
        assert_eq!((doc.value.as_str(), doc.version), ("v2", 2));
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }
}
