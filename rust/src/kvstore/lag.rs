//! Replication-lag / staleness bookkeeping (sender-side).
//!
//! The paper's consistency story is "replicas converge quickly"; this
//! module turns that into a measurable quantity. The [`Replicator`]'s
//! sender thread records, per `(peer, keygroup, key)`, the highest
//! version it has *addressed* to the peer (the local head) and the
//! highest version the peer has *acknowledged* (a 200 on the push). A
//! key whose head runs ahead of its ack is **lagging**: the peer would
//! serve stale context for it. `GET /status` and `/metrics` surface
//!
//! - `max_lag_versions` — the largest `head - acked` gap over all keys,
//! - `lag_keys` — how many keys are behind on at least one peer,
//! - `staleness_ms` — age of the oldest unacknowledged head,
//!
//! so "how far behind is replica B right now?" has a live answer.
//!
//! Entries are dropped the moment the ack catches the head, so the map
//! is bounded by in-flight pushes plus keys on genuinely unreachable
//! peers (parked hints / anti-entropy debt). Healing clears lag through
//! two doors: hint replay delivers and acks each parked update, and an
//! anti-entropy round that proves equal Merkle roots clears the whole
//! `(peer, keygroup)` slice (see [`LagTracker::clear_converged`]).
//!
//! Purely local bookkeeping: nothing here touches the wire, so the
//! seed's replication byte stream is unchanged whether or not a tracker
//! is attached.
//!
//! [`Replicator`]: super::replication::Replicator

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Per-key lag record: local head vs. highest peer ack.
#[derive(Debug, Clone, Copy)]
struct KeyLag {
    /// Highest version addressed to the peer for this key.
    head: u64,
    /// Highest version the peer acknowledged.
    acked: u64,
    /// When the currently-unacknowledged head was first recorded.
    since: Instant,
}

/// One peer's aggregated lag, as reported in `GET /status`.
#[derive(Debug, Clone)]
pub struct PeerLag {
    /// The peer's replication address.
    pub peer: SocketAddr,
    /// Largest `head - acked` gap over the peer's lagging keys.
    pub max_lag_versions: u64,
    /// Number of keys behind on this peer.
    pub lag_keys: u64,
    /// Age in ms of the oldest unacknowledged head (`None` when clean).
    pub staleness_ms: Option<u64>,
}

/// Sender-side replication-lag tracker shared between the
/// [`Replicator`](super::replication::Replicator) thread, the
/// anti-entropy heal hook, and the `/status`/`/metrics` accessors.
#[derive(Debug, Default)]
pub struct LagTracker {
    /// peer → (keygroup, key) → lag record. Only *lagging* keys are
    /// held; a full ack removes its entry.
    inner: Mutex<BTreeMap<SocketAddr, BTreeMap<(String, String), KeyLag>>>,
}

impl LagTracker {
    /// Fresh tracker (attached to a node when observability is on).
    pub fn new() -> Arc<LagTracker> {
        Arc::new(LagTracker::default())
    }

    /// Record that `version` of `keygroup/key` is now addressed to
    /// `peer`. A key first seen here is assumed caught up through
    /// `version - 1` (its previous entry was removed by a full ack), so
    /// the version gap is an estimate that never *under*-counts a
    /// freshly-diverging key at less than one version behind.
    pub fn record_head(&self, peer: SocketAddr, keygroup: &str, key: &str, version: u64) {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner
            .entry(peer)
            .or_default()
            .entry((keygroup.to_string(), key.to_string()))
            .or_insert(KeyLag {
                head: version,
                acked: version.saturating_sub(1),
                since: Instant::now(),
            });
        if version > entry.head {
            entry.head = version;
        }
    }

    /// Record that `peer` acknowledged `version` of `keygroup/key`.
    /// Catching the head removes the entry — the peer is current again.
    pub fn record_ack(&self, peer: SocketAddr, keygroup: &str, key: &str, version: u64) {
        let mut inner = self.inner.lock().unwrap();
        let Some(keys) = inner.get_mut(&peer) else {
            return;
        };
        let k = (keygroup.to_string(), key.to_string());
        if let Some(entry) = keys.get_mut(&k) {
            if version >= entry.head {
                keys.remove(&k);
            } else if version > entry.acked {
                entry.acked = version;
            }
        }
        if keys.is_empty() {
            inner.remove(&peer);
        }
    }

    /// Move `old`'s lag records to `new` — a peer restarted on a new
    /// address and its parked hints were re-addressed there. Existing
    /// records under `new` win on conflict (they are newer).
    pub fn forward(&self, old: SocketAddr, new: SocketAddr) {
        if old == new {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let Some(moved) = inner.remove(&old) else {
            return;
        };
        let dst = inner.entry(new).or_default();
        for (k, v) in moved {
            dst.entry(k).or_insert(v);
        }
        if dst.is_empty() {
            inner.remove(&new);
        }
    }

    /// An anti-entropy round proved `peer`'s Merkle root for `keygroup`
    /// equals ours: every key in that slice converged, drop its lag.
    pub fn clear_converged(&self, peer: SocketAddr, keygroup: &str) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(keys) = inner.get_mut(&peer) {
            keys.retain(|(kg, _), _| kg != keygroup);
            if keys.is_empty() {
                inner.remove(&peer);
            }
        }
    }

    /// Largest `head - acked` gap over every peer and key (0 = caught
    /// up everywhere).
    pub fn max_lag_versions(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .values()
            .flat_map(|keys| keys.values())
            .map(|e| e.head - e.acked)
            .max()
            .unwrap_or(0)
    }

    /// Distinct `(peer, keygroup, key)` records currently behind.
    pub fn lag_keys(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.values().map(|keys| keys.len() as u64).sum()
    }

    /// Age in ms of the oldest unacknowledged head over the whole map
    /// (`None` when every peer is caught up) — the node's estimated
    /// worst-case staleness window.
    pub fn staleness_ms(&self) -> Option<u64> {
        let inner = self.inner.lock().unwrap();
        inner
            .values()
            .flat_map(|keys| keys.values())
            .map(|e| e.since.elapsed().as_millis() as u64)
            .max()
    }

    /// Per-peer rollup for `GET /status`, sorted by peer address.
    pub fn per_peer(&self) -> Vec<PeerLag> {
        let inner = self.inner.lock().unwrap();
        inner
            .iter()
            .map(|(peer, keys)| PeerLag {
                peer: *peer,
                max_lag_versions: keys.values().map(|e| e.head - e.acked).max().unwrap_or(0),
                lag_keys: keys.len() as u64,
                staleness_ms: keys
                    .values()
                    .map(|e| e.since.elapsed().as_millis() as u64)
                    .max(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    #[test]
    fn ack_catching_head_clears_the_entry() {
        let lag = LagTracker::new();
        assert_eq!(lag.max_lag_versions(), 0);
        assert_eq!(lag.lag_keys(), 0);
        assert_eq!(lag.staleness_ms(), None);
        lag.record_head(addr(1), "kg", "k", 5);
        assert_eq!(lag.max_lag_versions(), 1, "fresh head is one behind");
        assert_eq!(lag.lag_keys(), 1);
        assert!(lag.staleness_ms().is_some());
        lag.record_ack(addr(1), "kg", "k", 5);
        assert_eq!(lag.max_lag_versions(), 0);
        assert_eq!(lag.lag_keys(), 0);
        assert_eq!(lag.staleness_ms(), None);
        assert!(lag.per_peer().is_empty(), "clean peers are not reported");
    }

    #[test]
    fn unacked_heads_accumulate_version_gap() {
        let lag = LagTracker::new();
        lag.record_head(addr(1), "kg", "k", 5);
        lag.record_head(addr(1), "kg", "k", 6);
        lag.record_head(addr(1), "kg", "k", 7);
        // Assumed caught up through 4, head now 7.
        assert_eq!(lag.max_lag_versions(), 3);
        assert_eq!(lag.lag_keys(), 1, "same key, one record");
        // A partial ack narrows but does not clear.
        lag.record_ack(addr(1), "kg", "k", 6);
        assert_eq!(lag.max_lag_versions(), 1);
        assert_eq!(lag.lag_keys(), 1);
        // Stale ack below the recorded floor is ignored.
        lag.record_ack(addr(1), "kg", "k", 2);
        assert_eq!(lag.max_lag_versions(), 1);
    }

    #[test]
    fn per_peer_rollup_separates_peers() {
        let lag = LagTracker::new();
        lag.record_head(addr(1), "kg", "a", 3);
        lag.record_head(addr(1), "kg", "b", 9);
        lag.record_head(addr(2), "kg", "a", 4);
        lag.record_ack(addr(2), "kg", "a", 4);
        let peers = lag.per_peer();
        assert_eq!(peers.len(), 1, "caught-up peer dropped from the map");
        assert_eq!(peers[0].peer, addr(1));
        assert_eq!(peers[0].lag_keys, 2);
        assert_eq!(peers[0].max_lag_versions, 1);
    }

    #[test]
    fn converged_keygroup_clears_only_its_slice() {
        let lag = LagTracker::new();
        lag.record_head(addr(1), "kg-a", "k", 2);
        lag.record_head(addr(1), "kg-b", "k", 2);
        lag.clear_converged(addr(1), "kg-a");
        assert_eq!(lag.lag_keys(), 1);
        lag.clear_converged(addr(1), "kg-b");
        assert_eq!(lag.lag_keys(), 0);
        assert!(lag.per_peer().is_empty());
    }

    #[test]
    fn forward_moves_records_to_the_new_address() {
        let lag = LagTracker::new();
        lag.record_head(addr(1), "kg", "k", 2);
        lag.forward(addr(1), addr(2));
        assert_eq!(lag.lag_keys(), 1);
        assert_eq!(lag.per_peer()[0].peer, addr(2));
        // Acks arriving at the new address now clear it.
        lag.record_ack(addr(2), "kg", "k", 2);
        assert_eq!(lag.lag_keys(), 0);
    }
}
