//! Consistent-hash session placement with virtual nodes and a bounded
//! replication factor (Dynamo-style, cf. SNIPPETS §3 and EdgeShard).
//!
//! The seed prototype replicated every session in a model's keygroup to
//! *all* peers serving that model — fine for the paper's two-node testbed,
//! a dead end for a fleet. This module maps `(keygroup, session_key)` onto
//! a **preference list** of `N` replica nodes so each write is pushed to
//! exactly those replicas:
//!
//! - every member node is hashed onto the ring at `virtual_nodes` points,
//!   smoothing the load split and bounding remapping when membership
//!   changes (adding/removing one of `k` nodes moves ~`1/k` of keys);
//! - the preference list is the first `min(N, members)` *distinct* nodes
//!   found walking clockwise from the key's hash point;
//! - placement is a pure function of `(members, virtual_nodes, key)` —
//!   every node computes the same list with no coordination, which is what
//!   lets the write path stay peer-to-peer.
//!
//! A node outside a session's preference list can still serve it: the KV
//! layer fetches the entry from a home replica on demand and read-repairs
//! it into the local store (the paper's §3.3 mobility path, generalized).

use std::collections::HashMap;
use std::net::SocketAddr;

/// A consistent-hash ring over a set of named nodes.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// Ring points, sorted by hash: `(hash, index into names)`.
    points: Vec<(u64, usize)>,
    /// Member node names, in insertion order.
    names: Vec<String>,
    /// Ring points per node.
    virtual_nodes: usize,
}

impl HashRing {
    /// Build a ring over `names` with `virtual_nodes` points per node.
    pub fn new<S: AsRef<str>>(names: &[S], virtual_nodes: usize) -> HashRing {
        let mut ring = HashRing {
            points: Vec::with_capacity(names.len() * virtual_nodes.max(1)),
            names: Vec::with_capacity(names.len()),
            virtual_nodes: virtual_nodes.max(1),
        };
        for n in names {
            ring.add_node(n.as_ref());
        }
        ring
    }

    /// Member names, in insertion order.
    pub fn nodes(&self) -> &[String] {
        &self.names
    }

    /// Number of member nodes.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when the ring has no members.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Add a node (idempotent): inserts its virtual points, leaving every
    /// other node's points untouched.
    pub fn add_node(&mut self, name: &str) {
        if self.names.iter().any(|n| n == name) {
            return;
        }
        let idx = self.names.len();
        self.names.push(name.to_string());
        for v in 0..self.virtual_nodes {
            self.points.push((point_hash(name, v), idx));
        }
        self.points.sort_unstable();
    }

    /// Remove a node and its virtual points. Keys whose preference list
    /// did not include the node keep their list unchanged.
    pub fn remove_node(&mut self, name: &str) {
        let Some(idx) = self.names.iter().position(|n| n == name) else {
            return;
        };
        self.names.remove(idx);
        self.points.retain(|&(_, i)| i != idx);
        // Re-index points above the removed slot.
        for p in &mut self.points {
            if p.1 > idx {
                p.1 -= 1;
            }
        }
    }

    /// The preference list for `key`: the first `min(n, members)` distinct
    /// nodes clockwise from the key's hash point. Deterministic; every
    /// node computes the same list.
    pub fn preference_list(&self, key: &str, n: usize) -> Vec<&str> {
        let want = n.min(self.names.len());
        let mut out: Vec<&str> = Vec::with_capacity(want);
        if want == 0 {
            return out;
        }
        let h = key_hash(key);
        // First ring point at or after the key's hash (wrapping).
        let start = match self.points.binary_search(&(h, 0)) {
            Ok(i) => i,
            Err(i) => i,
        };
        let mut seen = vec![false; self.names.len()];
        for step in 0..self.points.len() {
            let (_, node) = self.points[(start + step) % self.points.len()];
            if !seen[node] {
                seen[node] = true;
                out.push(self.names[node].as_str());
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }

    /// The first node on `key`'s preference list (its primary replica).
    pub fn primary(&self, key: &str) -> Option<&str> {
        self.preference_list(key, 1).first().copied()
    }

    /// Whether `node` is one of the first `n` replicas for `key`.
    pub fn is_replica(&self, node: &str, key: &str, n: usize) -> bool {
        self.preference_list(key, n).iter().any(|&r| r == node)
    }

    /// The next `k` members clockwise from `name` when members are laid
    /// out by their primary ring position — the peers `name` heartbeats
    /// in the failure detector. One position per member (not the virtual
    /// points): in a circular order every member is the immediate
    /// successor of exactly one other, so with `k ≥ 1` the union of all
    /// successor sets provably covers every node. Empty when `name` is
    /// not a member.
    pub fn successors(&self, name: &str, k: usize) -> Vec<&str> {
        if !self.names.iter().any(|n| n == name) {
            return Vec::new();
        }
        let mut order: Vec<&str> = self.names.iter().map(String::as_str).collect();
        order.sort_by(|a, b| (point_hash(a, 0), *a).cmp(&(point_hash(b, 0), *b)));
        let pos = order.iter().position(|n| *n == name).unwrap();
        let want = k.min(order.len() - 1);
        (1..=want).map(|i| order[(pos + i) % order.len()]).collect()
    }
}

/// Cluster-wide placement: one ring per keygroup (only the nodes serving
/// that keygroup are members), the replication factor, and the replication
/// listener address of every node. Built once at cluster assembly and
/// shared read-only by every [`super::KvNode`].
#[derive(Debug)]
pub struct Placement {
    rings: HashMap<String, HashRing>,
    addrs: HashMap<String, SocketAddr>,
    /// Anti-entropy listener per node, when repair is enabled there.
    /// Carried here so membership-driven placement swaps re-address the
    /// digest walks exactly like they re-address writes.
    ae_addrs: HashMap<String, SocketAddr>,
    replication_factor: usize,
    /// Topology version this placement was built from. 0 for a static
    /// launch-time placement; membership-driven rebuilds stamp the
    /// cluster epoch here so `/metrics` (and tests) can observe swaps.
    epoch: u64,
}

impl Placement {
    /// Create a placement with replication factor `n` (clamped to ≥ 1).
    pub fn new(replication_factor: usize) -> Placement {
        Placement {
            rings: HashMap::new(),
            addrs: HashMap::new(),
            ae_addrs: HashMap::new(),
            replication_factor: replication_factor.max(1),
            epoch: 0,
        }
    }

    /// The configured replication factor.
    pub fn replication_factor(&self) -> usize {
        self.replication_factor
    }

    /// The membership epoch this placement was built from (0 = static).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Stamp the membership epoch this placement was built from.
    pub fn set_epoch(&mut self, epoch: u64) {
        self.epoch = epoch;
    }

    /// Register a keygroup with its member nodes and their replication
    /// listener addresses.
    pub fn add_keygroup(
        &mut self,
        keygroup: &str,
        members: &[(String, SocketAddr)],
        virtual_nodes: usize,
    ) {
        let names: Vec<&String> = members.iter().map(|(n, _)| n).collect();
        self.rings
            .insert(keygroup.to_string(), HashRing::new(&names, virtual_nodes));
        for (name, addr) in members {
            self.addrs.insert(name.clone(), *addr);
        }
    }

    /// Record `name`'s anti-entropy listener address.
    pub fn set_ae_addr(&mut self, name: &str, addr: SocketAddr) {
        self.ae_addrs.insert(name.to_string(), addr);
    }

    /// `name`'s anti-entropy listener, if repair runs there.
    pub fn ae_addr(&self, name: &str) -> Option<SocketAddr> {
        self.ae_addrs.get(name).copied()
    }

    /// `name`'s replication listener, if the node is known to placement.
    pub fn node_addr(&self, name: &str) -> Option<SocketAddr> {
        self.addrs.get(name).copied()
    }

    /// Whether placement is defined for `keygroup`.
    pub fn has_keygroup(&self, keygroup: &str) -> bool {
        self.rings.contains_key(keygroup)
    }

    /// The ring for `keygroup`, if registered.
    pub fn ring(&self, keygroup: &str) -> Option<&HashRing> {
        self.rings.get(keygroup)
    }

    /// The preference list for a session: `min(N, members)` distinct
    /// `(name, replication_addr)` pairs. Empty when the keygroup has no
    /// registered ring.
    pub fn replicas(&self, keygroup: &str, key: &str) -> Vec<(String, SocketAddr)> {
        let Some(ring) = self.rings.get(keygroup) else {
            return Vec::new();
        };
        ring.preference_list(&placement_key(keygroup, key), self.replication_factor)
            .into_iter()
            .map(|name| {
                let addr = self.addrs[name];
                (name.to_string(), addr)
            })
            .collect()
    }

    /// Whether `node` is a home replica for the session.
    pub fn is_replica(&self, node: &str, keygroup: &str, key: &str) -> bool {
        self.rings.get(keygroup).map_or(false, |ring| {
            ring.is_replica(node, &placement_key(keygroup, key), self.replication_factor)
        })
    }
}

/// The string hashed for session placement: keygroup and session key
/// together, so the same session id lands independently per model.
fn placement_key(keygroup: &str, key: &str) -> String {
    format!("{keygroup}/{key}")
}

/// Hash of one virtual point of a node.
fn point_hash(name: &str, replica: usize) -> u64 {
    let mut h = crate::testkit::fnv1a(name.as_bytes());
    h ^= replica as u64;
    mix64(h)
}

/// Hash of a session key onto the ring.
fn key_hash(key: &str) -> u64 {
    mix64(crate::testkit::fnv1a(key.as_bytes()))
}

/// SplitMix64 finalizer: FNV alone clusters similar strings; this gives
/// the avalanche the ring's balance depends on. Shared with the
/// anti-entropy bucket hashing — the two must never diverge, or a
/// placement tweak would silently reshuffle Merkle buckets too.
pub(crate) fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("edge-{i}")).collect()
    }

    fn keys(k: usize) -> Vec<String> {
        let mut rng = Rng::new(0x51E55);
        (0..k)
            .map(|i| format!("u-{:08x}/s-{:08x}", rng.next_u64() as u32, i))
            .collect()
    }

    #[test]
    fn placement_is_deterministic() {
        let a = HashRing::new(&names(5), 64);
        let b = HashRing::new(&names(5), 64);
        for key in keys(200) {
            assert_eq!(a.preference_list(&key, 3), b.preference_list(&key, 3));
        }
        // Repeated queries on the same ring are stable too.
        let k = "u-1/s-1";
        assert_eq!(a.preference_list(k, 2), a.preference_list(k, 2));
    }

    #[test]
    fn preference_list_has_min_n_nodes_distinct() {
        for nodes in [1usize, 2, 3, 5, 8] {
            let ring = HashRing::new(&names(nodes), 32);
            for n in [1usize, 2, 3, 10] {
                for key in keys(100) {
                    let list = ring.preference_list(&key, n);
                    assert_eq!(list.len(), n.min(nodes), "n={n} nodes={nodes}");
                    let mut dedup = list.clone();
                    dedup.sort_unstable();
                    dedup.dedup();
                    assert_eq!(dedup.len(), list.len(), "replicas must be distinct");
                }
            }
        }
    }

    #[test]
    fn adding_a_node_remaps_about_one_kth() {
        let k = 2000usize;
        let old = HashRing::new(&names(6), 128);
        let mut new = old.clone();
        new.add_node("edge-6");
        let moved = keys(k)
            .iter()
            .filter(|key| old.primary(key) != new.primary(key))
            .count();
        // Expect ~K/7 primaries to move to the new node; allow generous
        // slack for hash variance but reject broadcast-style reshuffles.
        let expected = k / 7;
        assert!(moved > 0, "a new node must take over some keys");
        assert!(
            moved < expected * 5 / 2,
            "remapped {moved} of {k} keys; consistent hashing bounds this near {expected}"
        );
        // Every moved key must have moved *to* the new node.
        for key in keys(k) {
            if old.primary(&key) != new.primary(&key) {
                assert_eq!(new.primary(&key), Some("edge-6"));
            }
        }
    }

    #[test]
    fn removing_a_node_only_touches_its_keys() {
        let ring = HashRing::new(&names(5), 64);
        let mut smaller = ring.clone();
        smaller.remove_node("edge-3");
        for key in keys(500) {
            let before = ring.preference_list(&key, 2);
            let after = smaller.preference_list(&key, 2);
            if !before.contains(&"edge-3") {
                assert_eq!(before, after, "lists without the removed node must not change");
            } else {
                assert!(!after.contains(&"edge-3"));
                assert_eq!(after.len(), 2);
            }
        }
    }

    #[test]
    fn virtual_nodes_balance_the_primary_load() {
        let nodes = 8usize;
        let k = 4000usize;
        let ring = HashRing::new(&names(nodes), 128);
        let mut counts: HashMap<&str, usize> = HashMap::new();
        let keys = keys(k);
        for key in &keys {
            *counts.entry(ring.primary(key).unwrap()).or_default() += 1;
        }
        assert_eq!(counts.len(), nodes, "every node must own some keys");
        let fair = k / nodes;
        for (node, count) in counts {
            assert!(
                count > fair / 4 && count < fair * 3,
                "node {node} owns {count} of {k} keys (fair share {fair})"
            );
        }
    }

    #[test]
    fn placement_routes_by_keygroup_membership() {
        let mut p = Placement::new(2);
        let a: SocketAddr = "127.0.0.1:7001".parse().unwrap();
        let b: SocketAddr = "127.0.0.1:7002".parse().unwrap();
        let c: SocketAddr = "127.0.0.1:7003".parse().unwrap();
        p.add_keygroup(
            "model-x",
            &[
                ("edge-0".to_string(), a),
                ("edge-1".to_string(), b),
                ("edge-2".to_string(), c),
            ],
            32,
        );
        p.add_keygroup("model-y", &[("edge-2".to_string(), c)], 32);
        let reps = p.replicas("model-x", "u1/s1");
        assert_eq!(reps.len(), 2);
        // model-y is only served by edge-2: lists clamp to membership.
        assert_eq!(p.replicas("model-y", "u1/s1"), vec![("edge-2".to_string(), c)]);
        assert!(p.is_replica("edge-2", "model-y", "u1/s1"));
        assert!(p.replicas("model-z", "u1/s1").is_empty());
        // The same session key may place differently per keygroup.
        assert!(p.has_keygroup("model-x") && !p.has_keygroup("model-z"));
    }

    #[test]
    fn successors_cover_every_member_and_exclude_self() {
        let ring = HashRing::new(&names(6), 32);
        let mut probed: HashMap<String, usize> = HashMap::new();
        for name in ring.nodes().to_vec() {
            let succ = ring.successors(&name, 2);
            assert_eq!(succ.len(), 2);
            assert!(!succ.contains(&name.as_str()), "{name} probing itself");
            let mut dedup = succ.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), succ.len());
            for s in succ {
                *probed.entry(s.to_string()).or_default() += 1;
            }
        }
        // Everyone is somebody's successor: no member goes unprobed.
        assert_eq!(probed.len(), 6, "{probed:?}");
        // Degenerate sizes.
        let two = HashRing::new(&["a", "b"], 8);
        assert_eq!(two.successors("a", 2), vec!["b"]);
        assert!(HashRing::new(&["solo"], 8).successors("solo", 2).is_empty());
        assert!(two.successors("ghost", 2).is_empty());
    }

    #[test]
    fn placement_epoch_round_trips() {
        let mut p = Placement::new(2);
        assert_eq!(p.epoch(), 0, "static placements are epoch 0");
        p.set_epoch(7);
        assert_eq!(p.epoch(), 7);
    }

    #[test]
    fn single_node_ring_degenerates_cleanly() {
        let ring = HashRing::new(&["only"], 16);
        assert_eq!(ring.preference_list("any", 3), vec!["only"]);
        assert_eq!(ring.primary("any"), Some("only"));
        let empty = HashRing::new(&[] as &[&str], 16);
        assert!(empty.preference_list("any", 2).is_empty());
        assert!(empty.primary("any").is_none());
    }
}
