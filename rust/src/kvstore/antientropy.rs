//! Anti-entropy repair: Merkle-tree replica synchronization (Dynamo-style
//! background repair, cf. PAPERS.md on edge churn).
//!
//! Push replication (PR 1), delta sync (PR 2), and hinted handoff (PR 3)
//! each narrow the window in which replicas can diverge — but none closes
//! it: a push that exhausts its retry budget with membership disabled
//! drops forever, and a hint queue at `hints.max_per_peer` evicts its
//! oldest record. This module is the backstop that makes the paper's
//! "guaranteed data consistency" unconditional: every replica can detect
//! and heal divergence at O(digest) cost, no matter how the damage
//! happened.
//!
//! **Tree shape.** Each node keeps one incrementally-updated
//! [`MerkleForest`] entry per keygroup: `fanout²` leaf buckets (keys
//! assigned by key hash), one internal level of `fanout` nodes, one root.
//! A leaf hashes the `(key, version, content hash)` triples of its live
//! entries in key order; every put / delta apply / delete / TTL sweep
//! marks the touched bucket **dirty**, so a digest rebuild re-hashes only
//! changed buckets (content hashing is the expensive part — the internal
//! levels are a few hundred 8-byte folds).
//!
//! **Exchange.** A background [`AntiEntropy`] thread periodically picks a
//! replica peer per keygroup (ring members under placement, keygroup
//! subscribers otherwise; Down peers are skipped) and walks the peer's
//! tree over three verbs on the peer's dedicated anti-entropy listener:
//! `/ae/root` (root digest — equal roots end the round at O(1) bytes),
//! `/ae/level` (internal node hashes, then leaf hashes under mismatched
//! parents), and `/ae/keys` (per-key records for mismatched buckets).
//! Digest traffic rides its own listener and meters, exported as
//! `kv_ae_digest_bytes` — the replication-port byte accounting the
//! figures plot is untouched (PR 3's zero-failure regression style).
//!
//! **Repair (who wins).** Both sides repair themselves by **pulling**
//! over the existing `fetch_entry` read-repair path (TTL
//! preserved; an entry that expired on the source is never resurrected —
//! `/fetch` filters expired entries):
//!
//! - lower version pulls the newer entry (LWW, as everywhere else);
//! - equal version, different bytes: the side with the *lower* content
//!   hash pulls — both sides apply the same rule, so they converge
//!   deterministically; `kv_ae_conflicts` counts these;
//! - a key missing locally is pulled (explicit deletes are not
//!   tombstoned in the prototype — TTL is the deletion mechanism — so a
//!   missing key is indistinguishable from damage and is restored);
//! - under ring placement a key is only repaired between two of its home
//!   replicas (read-repair caches age out by TTL instead).
//!
//! At most `antientropy.max_keys_per_round` entries are pulled per round;
//! the rest heal on subsequent rounds. Default **off**; with zero
//! divergence an enabled fleet's replication-port traffic is
//! byte-for-byte identical to a disabled one.
//!
//! **Sharded-mode cost.** The tree covers a node's whole local key set,
//! so the O(1)-bytes converged round holds when sync partners replicate
//! the same keys (replicate-to-all, or `replication_factor >=` fleet
//! size). Under a ring with a smaller factor, two replicas legitimately
//! hold different key sets: their roots differ even when every shared
//! key agrees, and each round descends to the record exchange for the
//! buckets holding non-shared keys (repair itself stays correct — the
//! preference-list filter skips those keys, and pulls stay bounded by
//! `max_keys_per_round`). Restricting digests to the pairwise-shared
//! subset needs per-peer trees and is future work; see ARCHITECTURE.md.

use std::collections::{HashMap, HashSet};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::ring::mix64;
use super::{fetch_entry, Placement, Store};
use crate::cluster::HintedHandoff;
use crate::http::{Handler, Request, Response, Server, ServerLimits};
use crate::json::{self, Value};
use crate::netsim::LinkModel;
use crate::sync::{classes, OrderedMutex};
use crate::testkit::fnv1a;
use crate::transport::PeerPool;
use crate::Result;

/// Anti-entropy tuning (`antientropy` config section).
#[derive(Debug, Clone)]
pub struct AntiEntropyConfig {
    /// Master switch. Default **off**: no listener, no thread, no digest
    /// traffic — the wire behaviour of the seed, byte-for-byte.
    pub enabled: bool,
    /// Pause between background rounds (`interval_ms`).
    pub interval: Duration,
    /// Tree fanout: `fanout²` leaf buckets, `fanout` internal nodes.
    pub fanout: usize,
    /// Maximum entries pulled per round; the remainder heals on later
    /// rounds (bounds repair burst bandwidth after a long partition).
    pub max_keys_per_round: usize,
}

impl Default for AntiEntropyConfig {
    fn default() -> AntiEntropyConfig {
        AntiEntropyConfig {
            enabled: false,
            interval: Duration::from_millis(1000),
            fanout: 16,
            max_keys_per_round: 256,
        }
    }
}

/// Hash of one entry's bytes + version — the per-key digest exchanged in
/// `/ae/keys` records and the equal-version tiebreaker.
pub fn content_hash(value: &str, version: u64) -> u64 {
    mix64(fnv1a(value.as_bytes()) ^ version.rotate_left(32))
}

/// Deterministic fold of one `(key hash, content hash)` pair into an
/// accumulator. Order-sensitive, but both sides iterate entries in key
/// order (the store is a BTreeMap), so folds agree.
fn fold(acc: u64, key_hash: u64, entry_hash: u64) -> u64 {
    mix64(acc.wrapping_mul(0x100000001b3) ^ key_hash).wrapping_add(entry_hash)
}

/// Hash every leaf/internal child sequence folds from.
const EMPTY_HASH: u64 = 0xcbf29ce484222325;

/// One keygroup's incrementally-maintained tree state.
#[derive(Debug)]
struct Tree {
    /// Leaf bucket hashes (`fanout²` of them).
    leaves: Vec<u64>,
    /// Buckets whose contents changed since their hash was computed.
    dirty: Vec<bool>,
    /// Cheap "anything to rebuild?" flag.
    any_dirty: bool,
}

impl Tree {
    fn new(leaf_count: usize) -> Tree {
        Tree {
            leaves: vec![EMPTY_HASH; leaf_count],
            dirty: vec![true; leaf_count],
            any_dirty: true,
        }
    }
}

/// A refreshed digest snapshot of one keygroup's tree.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeDigest {
    /// Root hash over the internal level.
    pub root: u64,
    /// Internal node hashes (`fanout` of them; node `i` covers leaf
    /// buckets `i*fanout .. (i+1)*fanout`).
    pub level1: Vec<u64>,
    /// Leaf bucket hashes.
    pub leaves: Vec<u64>,
}

/// Per-node set of keygroup Merkle trees with dirty-bucket tracking.
///
/// Installed into the [`Store`] when anti-entropy is enabled so every
/// mutation (local put, replicated apply, delta apply, delete, TTL
/// sweep) marks the key's bucket dirty; [`MerkleForest::digest`] then
/// re-hashes only dirty buckets from store contents.
#[derive(Debug)]
pub struct MerkleForest {
    fanout: usize,
    trees: OrderedMutex<HashMap<String, Tree>>,
}

impl MerkleForest {
    /// Empty forest; trees materialize lazily per keygroup.
    pub fn new(fanout: usize) -> Arc<MerkleForest> {
        Arc::new(MerkleForest {
            fanout: fanout.max(2),
            trees: OrderedMutex::new(&classes::MERKLE_TREES, HashMap::new()),
        })
    }

    /// Leaf buckets per tree.
    pub fn leaf_count(&self) -> usize {
        self.fanout * self.fanout
    }

    /// The leaf bucket `key` hashes into.
    pub fn bucket_of(&self, key: &str) -> usize {
        (mix64(fnv1a(key.as_bytes())) % self.leaf_count() as u64) as usize
    }

    /// Mark `key`'s bucket dirty (cheap; called on every store mutation).
    pub fn mark(&self, keygroup: &str, key: &str) {
        let bucket = self.bucket_of(key);
        let mut trees = self.trees.lock().unwrap();
        let tree = trees
            .entry(keygroup.to_string())
            .or_insert_with(|| Tree::new(self.leaf_count()));
        tree.dirty[bucket] = true;
        tree.any_dirty = true;
    }

    /// Refresh dirty buckets from `store` and return the digest snapshot.
    /// Expired-but-unswept entries are skipped so a swept and an unswept
    /// replica hash identically.
    ///
    /// A rebuild with any dirty bucket makes one pass over the keygroup:
    /// the per-key work is a cheap key hash to find the bucket, and only
    /// entries in dirty buckets pay the content hash. Keeping a
    /// per-bucket key index would drop the scan to O(dirty keys) at the
    /// cost of mirroring the store's membership — not worth it at the
    /// prototype's key counts.
    pub fn digest(&self, keygroup: &str, store: &Store) -> TreeDigest {
        let leaf_count = self.leaf_count();
        let mut trees = self.trees.lock().unwrap();
        let tree = trees
            .entry(keygroup.to_string())
            .or_insert_with(|| Tree::new(leaf_count));
        if tree.any_dirty {
            let now = Instant::now();
            let mut fresh = vec![EMPTY_HASH; leaf_count];
            // The fold is order-sensitive, so iterate the keygroup in key
            // order — the striped store merges its shards back into the
            // single-BTreeMap order this digest was defined over.
            store.with_keygroup_sorted(keygroup, |items| {
                for (key, entry) in items {
                    if entry.is_expired(now) {
                        continue;
                    }
                    let bucket = self.bucket_of(key);
                    if tree.dirty[bucket] {
                        fresh[bucket] = fold(
                            fresh[bucket],
                            fnv1a(key.as_bytes()),
                            content_hash(&entry.value, entry.version),
                        );
                    }
                }
            });
            for (bucket, dirty) in tree.dirty.iter_mut().enumerate() {
                if *dirty {
                    tree.leaves[bucket] = fresh[bucket];
                    *dirty = false;
                }
            }
            tree.any_dirty = false;
        }
        let leaves = tree.leaves.clone();
        drop(trees);
        let level1: Vec<u64> = leaves
            .chunks(self.fanout)
            .map(|chunk| {
                chunk
                    .iter()
                    .enumerate()
                    .fold(EMPTY_HASH, |acc, (i, h)| fold(acc, i as u64, *h))
            })
            .collect();
        let root = level1
            .iter()
            .enumerate()
            .fold(EMPTY_HASH, |acc, (i, h)| fold(acc, i as u64, *h));
        TreeDigest {
            root,
            level1,
            leaves,
        }
    }
}

/// Wake-up latch for the background thread (interval OR on-demand kick).
#[derive(Debug, Default)]
pub struct Kick {
    flag: Mutex<bool>,
    cvar: Condvar,
}

impl Kick {
    /// Fresh latch.
    pub fn new() -> Arc<Kick> {
        Arc::new(Kick::default())
    }

    /// Request an immediate round (coalesces with pending kicks).
    pub fn kick(&self) {
        *self.flag.lock().unwrap() = true;
        self.cvar.notify_all();
    }

    /// Wait until kicked or `timeout` elapses; clears the kick flag.
    fn wait(&self, timeout: Duration) {
        let flag = self.flag.lock().unwrap();
        let (mut flag, _) = self
            .cvar
            .wait_timeout_while(flag, timeout, |kicked| !*kicked)
            .unwrap();
        *flag = false;
    }
}

/// Damage handle the replication pipeline reports unrecoverable losses
/// to: an exhausted drop (membership off) or a hint-queue eviction means
/// the push path can no longer deliver that update — the loss is
/// counted, logged once per peer, and an immediate round is requested so
/// anti-entropy repairs what replication lost. (The key's bucket is
/// already dirty: the local write that spawned the push marked it —
/// only [`Store`] mutations touch the forest.)
#[derive(Debug)]
pub struct AeSink {
    kick: Arc<Kick>,
    obs: Arc<crate::obs::Obs>,
    lost: AtomicU64,
    logged: Mutex<HashSet<SocketAddr>>,
}

impl AeSink {
    /// Create the sink over a node's round latch, reporting losses as
    /// structured events through `obs`.
    pub(crate) fn new(kick: Arc<Kick>, obs: Arc<crate::obs::Obs>) -> Arc<AeSink> {
        Arc::new(AeSink {
            kick,
            obs,
            lost: AtomicU64::new(0),
            logged: Mutex::new(HashSet::new()),
        })
    }

    /// Record that an update for `keygroup/key` addressed to `peer` was
    /// lost by the push pipeline and must be healed by repair.
    pub fn note_lost(&self, peer: SocketAddr, keygroup: &str, key: &str) {
        self.lost.fetch_add(1, Ordering::SeqCst);
        if self.logged.lock().unwrap().insert(peer) {
            self.obs.event(
                crate::obs::Level::Warn,
                "ae",
                &format!(
                    "replication to {peer} lost an update for {keygroup}/{key}; \
                     anti-entropy will repair (further losses to this peer \
                     not logged)"
                ),
            );
        }
        self.kick.kick();
    }

    /// Updates handed to repair after the push pipeline gave up on them.
    pub fn lost(&self) -> u64 {
        self.lost.load(Ordering::SeqCst)
    }
}

/// One sync partner's two listeners.
#[derive(Debug, Clone, Copy)]
struct AePeer {
    /// Replication listener — where repair pulls `/fetch` from.
    kv: SocketAddr,
    /// Anti-entropy listener — where the digest walk goes.
    ae: SocketAddr,
}

/// Everything one node's anti-entropy machinery shares between the
/// background thread, the manual-round test hook, and the `/ae/*`
/// endpoint (which repairs the responder side).
pub struct AeRuntime {
    /// Node name (placement identity, logs).
    name: String,
    cfg: AntiEntropyConfig,
    store: Arc<Store>,
    forest: Arc<MerkleForest>,
    placement: Arc<RwLock<Option<Arc<Placement>>>>,
    /// keygroup → subscribed peer replication addresses (replicate-to-all
    /// peer source; shared with the owning `KvNode`).
    peers: Arc<Mutex<HashMap<String, Vec<SocketAddr>>>>,
    /// Replication address → anti-entropy address of known peers.
    ae_map: Arc<Mutex<HashMap<SocketAddr, SocketAddr>>>,
    /// Down-peer set (None without membership): Down peers are skipped.
    handoff: Option<Arc<HintedHandoff>>,
    link: LinkModel,
    /// This node's replication listener (peers pull repairs from here).
    kv_addr: SocketAddr,
    /// Keep-alive pool for the `/ae/*` digest walks, carrying the
    /// dedicated digest meter (client side of the exchange).
    digest_pool: PeerPool,
    /// Repair pulls ride the node's shared fetch pool (and so its
    /// remote-read meter), like read-repair.
    fetch_pool: Arc<PeerPool>,
    rounds: AtomicU64,
    repaired: AtomicU64,
    conflicts: AtomicU64,
    /// Serializes rounds (background thread vs. manual test hook).
    round_lock: Mutex<()>,
    /// Round-robin cursor over sync partners.
    next_peer: AtomicU64,
    /// Span recording + `ae_round` trace roots (`/status` freshness).
    obs: Arc<crate::obs::Obs>,
    /// When the last round started (terminal leaf state; `/status`
    /// reports its age so an operator can spot a wedged round loop).
    last_round: Mutex<Option<Instant>>,
    /// Replication-lag tracker shared with the owning node: an
    /// equal-roots digest round proves a `(peer, keygroup)` slice
    /// converged and clears its recorded lag (None with tracking off).
    lag: Option<Arc<super::lag::LagTracker>>,
}

impl AeRuntime {
    /// Assemble the shared runtime. `kv_addr` is the owning node's
    /// replication listener; `peers`/`ae_map`/`placement` are shared live
    /// with the `KvNode` so topology changes are visible immediately.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        cfg: AntiEntropyConfig,
        store: Arc<Store>,
        forest: Arc<MerkleForest>,
        placement: Arc<RwLock<Option<Arc<Placement>>>>,
        peers: Arc<Mutex<HashMap<String, Vec<SocketAddr>>>>,
        ae_map: Arc<Mutex<HashMap<SocketAddr, SocketAddr>>>,
        handoff: Option<Arc<HintedHandoff>>,
        link: LinkModel,
        kv_addr: SocketAddr,
        fetch_pool: Arc<PeerPool>,
        digest_pool: PeerPool,
        obs: Arc<crate::obs::Obs>,
        lag: Option<Arc<super::lag::LagTracker>>,
    ) -> Arc<AeRuntime> {
        Arc::new(AeRuntime {
            name: name.to_string(),
            cfg,
            store,
            forest,
            placement,
            peers,
            ae_map,
            handoff,
            link,
            kv_addr,
            digest_pool,
            fetch_pool,
            rounds: AtomicU64::new(0),
            repaired: AtomicU64::new(0),
            conflicts: AtomicU64::new(0),
            round_lock: Mutex::new(()),
            next_peer: AtomicU64::new(0),
            obs,
            last_round: Mutex::new(None),
            lag,
        })
    }

    /// Digest exchanges initiated by this node.
    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::SeqCst)
    }

    /// Entries pulled and applied by repair (either side).
    pub fn repaired(&self) -> u64 {
        self.repaired.load(Ordering::SeqCst)
    }

    /// Equal-version byte mismatches repaired deterministically.
    pub fn conflicts(&self) -> u64 {
        self.conflicts.load(Ordering::SeqCst)
    }

    /// Outbound digest-walk bytes (the server-side share is metered on
    /// the listener and added by the owning node's accessor).
    pub fn digest_tx_bytes(&self) -> u64 {
        self.digest_pool.meter().total()
    }

    /// Time since the last round started (`None` before the first one).
    pub fn last_round_age(&self) -> Option<Duration> {
        self.last_round.lock().unwrap().map(|t| t.elapsed())
    }

    /// Run one full round now: for every keygroup, pick the next sync
    /// partner round-robin and walk its tree. Returns entries repaired
    /// on this (initiating) side. Serialized against the background
    /// thread; safe to call from tests/benches/examples.
    pub fn run_once(&self) -> u64 {
        let _guard = self.round_lock.lock().unwrap();
        let started = Instant::now();
        *self.last_round.lock().unwrap() = Some(started);
        // Each background round is its own trace root: the digest walk's
        // round trips (and any repair pulls) stitch under it on both
        // nodes. None while observability is off — no header, seed wire.
        let trace = self.obs.begin_trace();
        let _ctx = crate::obs::set_current(trace);
        let mut keygroups: Vec<String> = self
            .store
            .keygroups
            .read()
            .unwrap()
            .iter()
            .cloned()
            .collect();
        keygroups.sort_unstable();
        let mut repaired = 0;
        for kg in keygroups {
            let peers = self.peers_for(&kg);
            if peers.is_empty() {
                continue;
            }
            let idx = self.next_peer.fetch_add(1, Ordering::SeqCst) as usize % peers.len();
            let peer = peers[idx];
            if let Some(h) = &self.handoff {
                // The failure detector marked the peer down: its tree is
                // unreachable, and the rejoin path schedules a round the
                // moment it returns.
                if h.is_down(peer.kv) {
                    continue;
                }
            }
            repaired += self.sync_keygroup(&kg, &peer).unwrap_or(0);
        }
        if let Some(ctx) = trace {
            self.obs.record_span(
                ctx,
                None,
                "ae_round",
                &format!("repaired={repaired}"),
                started,
                started.elapsed(),
            );
        }
        repaired
    }

    /// Sync partners for `kg`: ring members under placement (minus this
    /// node), keygroup subscribers otherwise. Peers without a known
    /// anti-entropy listener (e.g. admitted over HTTP from outside the
    /// process) are skipped — push replication still covers them.
    fn peers_for(&self, kg: &str) -> Vec<AePeer> {
        if let Some(placement) = self.placement.read().unwrap().clone() {
            if placement.has_keygroup(kg) {
                let Some(ring) = placement.ring(kg) else {
                    return Vec::new();
                };
                return ring
                    .nodes()
                    .iter()
                    .filter(|n| *n != &self.name)
                    .filter_map(|n| {
                        let kv = placement.node_addr(n)?;
                        let ae = placement.ae_addr(n)?;
                        Some(AePeer { kv, ae })
                    })
                    .collect();
            }
        }
        let subscribed = self.peers.lock().unwrap().get(kg).cloned().unwrap_or_default();
        let ae_map = self.ae_map.lock().unwrap();
        subscribed
            .into_iter()
            .filter_map(|kv| ae_map.get(&kv).map(|ae| AePeer { kv, ae: *ae }))
            .collect()
    }

    /// Walk one peer's tree for `kg` and repair this side. The peer
    /// repairs itself inside its `/ae/keys` handler.
    fn sync_keygroup(&self, kg: &str, peer: &AePeer) -> Result<u64> {
        self.rounds.fetch_add(1, Ordering::SeqCst);
        let mine = self.forest.digest(kg, &self.store);
        // Hard-bounded connect and I/O, like the failure detector's
        // probes: a wedged peer (accepts TCP, never answers — exactly
        // the failure class repair exists for) must cost one timeout,
        // not a walker stalled under `round_lock` forever. The checkout
        // reuses the previous round's keep-alive connection, so a
        // converged fleet's steady-state rounds cost zero connects.
        let timeout = self.probe_timeout();
        let mut conn = self.digest_pool.checkout_timeout(peer.ae, timeout)?;
        // Step 1: root digests. Equal roots end the round at O(1) bytes.
        let resp = conn.round_trip(&Request::post_json(
            "/ae/root",
            &Value::obj().set("kg", kg).to_json(),
        ))?;
        let v = json::parse(resp.body_str()?)?;
        if v.req_u64("leaves")? as usize != mine.leaves.len() {
            // Mismatched fanout config: digests are incomparable. Push
            // replication still converges the pair; nothing to do here.
            return Ok(0);
        }
        if parse_hash(&v, "root")? == mine.root {
            // Equal roots prove this (peer, keygroup) slice converged:
            // whatever replication lag was recorded against it is
            // healed, whichever path (replay, repair, late ack) did it.
            if let Some(l) = &self.lag {
                l.clear_converged(peer.kv, kg);
            }
            return Ok(0);
        }
        // Step 2: internal level — find mismatched subtrees.
        let resp = conn.round_trip(&Request::post_json(
            "/ae/level",
            &Value::obj().set("kg", kg).to_json(),
        ))?;
        let theirs_l1 = parse_hash_list(&json::parse(resp.body_str()?)?, "hashes")?;
        let parents: Vec<Value> = mine
            .level1
            .iter()
            .enumerate()
            .filter(|(i, h)| theirs_l1.get(*i) != Some(*h))
            .map(|(i, _)| Value::from(i))
            .collect();
        if parents.is_empty() {
            return Ok(0);
        }
        // Step 3: leaf hashes under the mismatched parents only.
        let resp = conn.round_trip(&Request::post_json(
            "/ae/level",
            &Value::obj().set("kg", kg).set("parents", parents).to_json(),
        ))?;
        let v = json::parse(resp.body_str()?)?;
        let mut buckets: Vec<usize> = Vec::new();
        for pair in v.get("buckets").and_then(|b| b.as_array()).unwrap_or(&[]) {
            let items = pair.as_array().unwrap_or(&[]);
            let (Some(idx), Some(hash)) = (
                items.first().and_then(Value::as_u64),
                items.get(1).and_then(Value::as_str),
            ) else {
                continue;
            };
            let idx = idx as usize;
            if idx < mine.leaves.len() && hash_from_hex(hash) != Some(mine.leaves[idx]) {
                buckets.push(idx);
            }
        }
        if buckets.is_empty() {
            return Ok(0);
        }
        // Step 4: exchange per-key records for the diverged buckets. The
        // peer repairs itself from our records before answering.
        let my_records = self.records_for(kg, &buckets);
        let req = Value::obj()
            .set("kg", kg)
            .set("kv", self.kv_addr.to_string())
            .set(
                "buckets",
                buckets.iter().map(|b| Value::from(*b)).collect::<Vec<Value>>(),
            )
            .set("keys", records_to_json(&my_records));
        // The peer repairs itself (bounded sequential pulls) before
        // answering, so this step needs a far looser bound than the
        // digest probes — the peer already proved responsive in steps
        // 1-3, and a wedge mid-exchange costs one capped wait, not a
        // stalled walker. Same pooled connection, loosened in place;
        // the pool restores its default policy on return.
        let keys_timeout = timeout.max(Duration::from_secs(30));
        conn.set_io_timeout(Some(keys_timeout))?;
        let resp = conn.round_trip(&Request::post_json("/ae/keys", &req.to_json()))?;
        let v = json::parse(resp.body_str()?)?;
        let their_records = records_from_json(&v);
        Ok(self.repair_from(kg, &their_records, peer.kv))
    }

    /// Per-exchange connect/I-O bound: one repair step against a wedged
    /// peer costs at most this, never a stalled thread.
    fn probe_timeout(&self) -> Duration {
        self.cfg
            .interval
            .clamp(Duration::from_millis(100), Duration::from_secs(5))
    }

    /// Live `(key, version, content hash)` records in the given buckets.
    fn records_for(&self, kg: &str, buckets: &[usize]) -> Vec<(String, u64, u64)> {
        let wanted: HashSet<usize> = buckets.iter().copied().collect();
        let now = Instant::now();
        self.store.with_keygroup_sorted(kg, |items| {
            items
                .iter()
                .filter(|(_, e)| !e.is_expired(now))
                .filter(|(k, _)| wanted.contains(&self.forest.bucket_of(k)))
                .map(|(k, e)| ((*k).clone(), e.version, content_hash(&e.value, e.version)))
                .collect()
        })
    }

    /// Pull every entry `source` holds a better copy of, version-aware:
    /// newer version wins; equal version + different bytes, the higher
    /// content hash wins on both sides. Pulls ride `fetch_entry` (TTL
    /// preserved; an entry expired at the source is never resurrected).
    /// Bounded by `max_keys_per_round`.
    fn repair_from(&self, kg: &str, remote: &[(String, u64, u64)], source_kv: SocketAddr) -> u64 {
        let placement = self.placement.read().unwrap().clone();
        let mut pulled = 0u64;
        for (key, r_ver, r_hash) in remote {
            let (pull, conflict) = match self.store.read(kg, key) {
                None => (true, false),
                Some(local) if *r_ver > local.version => (true, false),
                Some(local) if *r_ver == local.version => {
                    let l_hash = content_hash(&local.value, local.version);
                    (l_hash != *r_hash && *r_hash > l_hash, l_hash != *r_hash)
                }
                Some(_) => (false, false),
            };
            if !pull {
                continue;
            }
            if let Some(p) = &placement {
                // Only a home replica of the key repairs itself: pulling
                // onto a non-replica would spread the key outside its
                // preference list (a read-repair cache there ages out by
                // TTL instead). Pulling *from* a non-replica is fine —
                // a write-through cache can legitimately hold the newest
                // version — and the version compare already rejects
                // anything stale.
                if p.has_keygroup(kg) && !p.is_replica(&self.name, kg, key) {
                    continue;
                }
            }
            if pulled >= self.cfg.max_keys_per_round as u64 {
                break;
            }
            let fetched = fetch_entry(
                &self.fetch_pool,
                source_kv,
                kg,
                key,
                Some(self.probe_timeout()),
            );
            match fetched {
                Ok(Some(entry)) => {
                    let remaining = entry
                        .expires_at
                        .map(|t| t.saturating_duration_since(Instant::now()));
                    self.store.keygroups.write().unwrap().insert(kg.to_string());
                    // `apply` marks the bucket through the installed
                    // forest — only store mutations touch the tree.
                    if self.store.apply(kg, key, entry.value, entry.version, remaining) {
                        pulled += 1;
                        self.repaired.fetch_add(1, Ordering::SeqCst);
                        if conflict {
                            self.conflicts.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
                // Gone at the source (expired / evicted): skip — TTL
                // cleanup is the deletion mechanism, never resurrect.
                Ok(None) | Err(_) => {}
            }
        }
        pulled
    }
}

/// Hex framing for 64-bit hashes: the crate's JSON numbers are i64-backed,
/// which cannot round-trip the top bit of a hash.
fn hash_to_hex(h: u64) -> String {
    format!("{h:016x}")
}

fn hash_from_hex(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

fn parse_hash(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(|h| h.as_str())
        .and_then(hash_from_hex)
        .ok_or_else(|| crate::Error::KvStore(format!("ae response missing hash `{key}`")))
}

fn parse_hash_list(v: &Value, key: &str) -> Result<Vec<u64>> {
    v.get(key)
        .and_then(|h| h.as_array())
        .map(|items| {
            items
                .iter()
                .filter_map(|i| i.as_str().and_then(hash_from_hex))
                .collect()
        })
        .ok_or_else(|| crate::Error::KvStore(format!("ae response missing list `{key}`")))
}

fn records_to_json(records: &[(String, u64, u64)]) -> Vec<Value> {
    records
        .iter()
        .map(|(key, ver, hash)| {
            Value::from(vec![
                Value::Str(key.clone()),
                Value::from(*ver),
                Value::Str(hash_to_hex(*hash)),
            ])
        })
        .collect()
}

fn records_from_json(v: &Value) -> Vec<(String, u64, u64)> {
    v.get("keys")
        .and_then(|k| k.as_array())
        .map(|items| {
            items
                .iter()
                .filter_map(|rec| {
                    let parts = rec.as_array()?;
                    Some((
                        parts.first()?.as_str()?.to_string(),
                        parts.get(1)?.as_u64()?,
                        parts.get(2)?.as_str().and_then(hash_from_hex)?,
                    ))
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Start the node's dedicated anti-entropy listener under the node's
/// transport limits. Rides its own server + meter so digest traffic
/// never pollutes the replication-port byte accounting (the same
/// separation the heartbeat listeners use).
pub fn serve(runtime: Arc<AeRuntime>, limits: ServerLimits) -> Result<Server> {
    let link = runtime.link.clone();
    let handler: Handler = Arc::new(move |req: &Request| ae_endpoint(&runtime, req));
    Server::serve_with(0, link, limits, handler)
}

/// The `/ae/*` verbs (responder side of the digest walk).
fn ae_endpoint(rt: &AeRuntime, req: &Request) -> Response {
    if req.method != "POST" {
        return Response::error(404, "not found");
    }
    let v = match req.body_str().and_then(json::parse) {
        Ok(v) => v,
        Err(e) => return Response::error(400, &format!("bad json: {e}")),
    };
    let Ok(kg) = v.req_str("kg") else {
        return Response::error(400, "missing keygroup");
    };
    match req.path.as_str() {
        "/ae/root" => {
            let digest = rt.forest.digest(&kg, &rt.store);
            Response::json(
                &Value::obj()
                    .set("root", hash_to_hex(digest.root))
                    .set("leaves", digest.leaves.len())
                    .to_json(),
            )
        }
        "/ae/level" => {
            let digest = rt.forest.digest(&kg, &rt.store);
            match v.get("parents").and_then(|p| p.as_array()) {
                // Leaf hashes under the requested internal nodes.
                Some(parents) => {
                    let fanout = digest.level1.len();
                    let mut out: Vec<Value> = Vec::new();
                    for p in parents.iter().filter_map(Value::as_u64) {
                        let p = p as usize;
                        for b in (p * fanout)..((p + 1) * fanout).min(digest.leaves.len()) {
                            out.push(Value::from(vec![
                                Value::from(b),
                                Value::Str(hash_to_hex(digest.leaves[b])),
                            ]));
                        }
                    }
                    Response::json(&Value::obj().set("buckets", out).to_json())
                }
                // The whole internal level.
                None => {
                    let hashes: Vec<Value> = digest
                        .level1
                        .iter()
                        .map(|h| Value::Str(hash_to_hex(*h)))
                        .collect();
                    Response::json(&Value::obj().set("hashes", hashes).to_json())
                }
            }
        }
        "/ae/keys" => {
            let buckets: Vec<usize> = v
                .get("buckets")
                .and_then(|b| b.as_array())
                .map(|items| {
                    items
                        .iter()
                        .filter_map(Value::as_u64)
                        .map(|b| b as usize)
                        .collect()
                })
                .unwrap_or_default();
            // Snapshot local records *before* repairing: the initiator
            // compares against our pre-repair state, so both sides make
            // independent, symmetric pull decisions.
            let local = rt.records_for(&kg, &buckets);
            let initiator_records = records_from_json(&v);
            if let Some(kv) = v
                .get("kv")
                .and_then(|a| a.as_str())
                .and_then(|a| a.parse::<SocketAddr>().ok())
            {
                rt.repair_from(&kg, &initiator_records, kv);
            }
            Response::json(&Value::obj().set("keys", records_to_json(&local)).to_json())
        }
        _ => Response::error(404, "not found"),
    }
}

/// The background repair thread: waits out the configured interval (or
/// an on-demand [`Kick`] — damage reports and topology changes request
/// immediate rounds) and runs [`AeRuntime::run_once`].
pub struct AntiEntropy {
    stop: Arc<AtomicBool>,
    kick: Arc<Kick>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl AntiEntropy {
    /// Spawn the round loop for `runtime`.
    pub fn start(runtime: Arc<AeRuntime>, kick: Arc<Kick>) -> AntiEntropy {
        let stop = Arc::new(AtomicBool::new(false));
        let t_stop = stop.clone();
        let t_kick = kick.clone();
        let interval = runtime.cfg.interval;
        let thread = std::thread::Builder::new()
            .name(format!("kv-ae-{}", runtime.name))
            .spawn(move || loop {
                t_kick.wait(interval);
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                runtime.run_once();
            })
            .expect("spawn anti-entropy");
        AntiEntropy {
            stop,
            kick,
            thread: Some(thread),
        }
    }

    /// Ask the loop to exit without joining (kill-through-&self path).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.kick.kick();
    }

    /// Stop the loop and join the thread.
    pub fn shutdown(&mut self) {
        self.request_stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for AntiEntropy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(entries: &[(&str, &str, u64)]) -> Arc<Store> {
        let store = Store::new();
        for (key, value, version) in entries {
            store.apply("m", key, value.to_string(), *version, None);
        }
        store.keygroups.write().unwrap().insert("m".into());
        store
    }

    #[test]
    fn identical_stores_have_identical_digests() {
        let entries: Vec<(String, String, u64)> = (0..200)
            .map(|i| (format!("u{i}/s{i}"), format!("value-{i}"), 1 + i % 5))
            .collect();
        let refs: Vec<(&str, &str, u64)> = entries
            .iter()
            .map(|(k, v, ver)| (k.as_str(), v.as_str(), *ver))
            .collect();
        let (a, b) = (store_with(&refs), store_with(&refs));
        let (fa, fb) = (MerkleForest::new(8), MerkleForest::new(8));
        let (da, db) = (fa.digest("m", &a), fb.digest("m", &b));
        assert_eq!(da, db, "same contents must hash identically");
        assert_eq!(da.level1.len(), 8);
        assert_eq!(da.leaves.len(), 64);
    }

    #[test]
    fn divergence_is_visible_at_every_level() {
        let a = store_with(&[("u/s1", "v", 1), ("u/s2", "w", 1)]);
        let b = store_with(&[("u/s1", "v", 1), ("u/s2", "DIFFERENT", 1)]);
        let (fa, fb) = (MerkleForest::new(4), MerkleForest::new(4));
        let (da, db) = (fa.digest("m", &a), fb.digest("m", &b));
        assert_ne!(da.root, db.root);
        let bucket = fa.bucket_of("u/s2");
        assert_ne!(da.leaves[bucket], db.leaves[bucket]);
        assert_eq!(
            da.leaves
                .iter()
                .zip(&db.leaves)
                .filter(|(x, y)| x != y)
                .count(),
            1,
            "only the diverged key's bucket may differ"
        );
    }

    #[test]
    fn dirty_marking_refreshes_only_changed_buckets() {
        let store = store_with(&[("u/s1", "v1", 1)]);
        let forest = MerkleForest::new(4);
        let before = forest.digest("m", &store);
        // Mutate without marking: the (stale) digest must not change —
        // proof that clean buckets are not re-hashed.
        store.apply("m", "u/s1", "v2".into(), 2, None);
        assert_eq!(forest.digest("m", &store).root, before.root);
        // Marking the key refreshes its bucket.
        forest.mark("m", "u/s1");
        let after = forest.digest("m", &store);
        assert_ne!(after.root, before.root);
        // And matches a from-scratch tree over the same store.
        assert_eq!(after, MerkleForest::new(4).digest("m", &store));
    }

    #[test]
    fn expired_entries_hash_as_absent() {
        let live = store_with(&[("u/s1", "v", 1)]);
        let with_expired = store_with(&[("u/s1", "v", 1)]);
        with_expired.apply("m", "u/s2", "dying".into(), 1, Some(Duration::from_millis(5)));
        std::thread::sleep(Duration::from_millis(20));
        // Unswept-but-expired on one side, never-written on the other:
        // identical digests, so no spurious repair round.
        let (fa, fb) = (MerkleForest::new(4), MerkleForest::new(4));
        assert_eq!(
            fa.digest("m", &live).root,
            fb.digest("m", &with_expired).root
        );
    }

    #[test]
    fn bucket_assignment_spreads_keys() {
        let forest = MerkleForest::new(16);
        let mut used = HashSet::new();
        for i in 0..1000 {
            used.insert(forest.bucket_of(&format!("u{i}/s{i}")));
        }
        assert!(
            used.len() > forest.leaf_count() / 2,
            "keys must spread over buckets ({} of {})",
            used.len(),
            forest.leaf_count()
        );
    }

    #[test]
    fn hash_hex_round_trips() {
        for h in [0u64, 1, u64::MAX, 0xdead_beef_cafe_f00d] {
            assert_eq!(hash_from_hex(&hash_to_hex(h)), Some(h));
        }
        assert_eq!(hash_from_hex("not hex"), None);
    }

    #[test]
    fn content_hash_separates_versions_and_bytes() {
        assert_ne!(content_hash("v", 1), content_hash("v", 2));
        assert_ne!(content_hash("a", 1), content_hash("b", 1));
        assert_eq!(content_hash("a", 3), content_hash("a", 3));
    }

    #[test]
    fn sink_counts_losses_and_logs_once_per_peer() {
        let kick = Kick::new();
        let sink = AeSink::new(kick, crate::obs::Obs::disabled());
        let peer: SocketAddr = "127.0.0.1:1".parse().unwrap();
        sink.note_lost(peer, "m", "u/s1");
        sink.note_lost(peer, "m", "u/s2");
        assert_eq!(sink.lost(), 2);
        // (The damaged keys' buckets were already marked by the local
        // writes that spawned the pushes — only store mutations touch
        // the forest.)
    }

    #[test]
    fn installed_forest_marks_on_every_store_mutation() {
        // The invariant the sink relies on: a store with a forest
        // installed dirties the bucket on apply, so the divergence a
        // lost push leaves behind is already visible to the next digest.
        let store = store_with(&[("u/s1", "v", 1)]);
        let forest = MerkleForest::new(4);
        store.install_forest(forest.clone());
        let before = forest.digest("m", &store);
        store.apply("m", "u/s1", "v2".into(), 2, None);
        assert_ne!(forest.digest("m", &store).root, before.root);
    }
}
