//! FReD-like geo-distributed in-memory key-value store (paper §3.3).
//!
//! Each edge node runs one [`KvNode`]: a local replica plus a replication
//! engine. Mirroring FReD's design:
//!
//! - keys are grouped into **keygroups** (DisCEdge uses one per language
//!   model) with independent replication membership;
//! - nodes exchange data **peer-to-peer** (push replication over a
//!   dedicated TCP port, which is where the paper pointed tcpdump);
//! - consistency between replicas is **eventual**; entries carry a
//!   monotonically increasing `version` (the session turn) and conflicts
//!   resolve last-writer-wins by version;
//! - entries carry a **TTL** and are lazily evicted on read plus swept by a
//!   background janitor;
//! - all reads/writes are served from memory (FReD persists
//!   asynchronously; so do we — see the **persistence** note below —
//!   and by default, matching the paper's memory-only evaluation, not
//!   at all).
//!
//! The session-level consistency that DisCEdge needs (read-your-writes as
//! the user roams) is *not* provided here — exactly as in the paper, it is
//! layered on top by the Context Manager's turn-counter protocol.
//!
//! **Placement.** By default a write is pushed to every peer subscribed to
//! the keygroup (the paper's replicate-to-all testbed behaviour). When a
//! [`Placement`] is installed (see [`KvNode::set_placement`]), writes go
//! only to the session's consistent-hash **preference list** of N replica
//! nodes, and a node outside that list serves reads by fetching from a
//! home replica and read-repairing the entry locally ([`HashRing`] docs).
//!
//! **Delta sync.** Session documents are append-only per turn, so with
//! `replication.delta_sync` on, [`KvNode::put_ttl_append`] replicates only
//! the turn's fragment (base version `n-1` → `n`) instead of the whole
//! value; per-turn sync bytes stay O(new tokens) instead of O(history).
//! The receiving `/replicate` handler applies a delta **iff** its local
//! entry is exactly at the base version (equal-or-newer versions are
//! idempotent no-ops); on a gap it falls back to a full-state `/fetch`
//! from the sender — the same remote-read path ring mobility uses. The
//! fragment payload is a `context::codec` document, the one place the KV
//! layer knows about the context format. Default **off**: the seed's
//! full-state wire format, byte-for-byte.
//!
//! **Anti-entropy repair.** Push replication, delta sync, and hinted
//! handoff can all still lose an update (exhausted retries without
//! membership, a hint queue past its bound). With `antientropy.enabled`,
//! each node maintains per-keygroup Merkle trees over its entries and a
//! background thread exchanges digests with replica peers over a
//! dedicated listener, pulling diverged entries back over the `/fetch`
//! path — see [`antientropy`](self::AntiEntropyConfig) ([`MerkleForest`],
//! `rust/src/kvstore/antientropy.rs`) for tree shape and who-wins rules.
//! Default **off**; with zero divergence the replication-port byte
//! accounting is untouched.
//!
//! **Persistence.** The in-memory store is lock-striped (16 shards by
//! key hash) so concurrent session writes scale with cores, and with
//! `storage.enabled` each node keeps an opt-in write-ahead log plus
//! periodic snapshot ([`storage`](self::StorageConfig),
//! `rust/src/kvstore/storage.rs`). On restart a node recovers committed
//! entries from local disk *first*; hint replay and an anti-entropy kick
//! then reconcile only the tail written while it was down. Default
//! **off**: no files, no write-path clones, the seed's behaviour
//! byte-for-byte.

mod antientropy;
mod lag;
mod replication;
mod ring;
mod storage;

pub use antientropy::{AeSink, AntiEntropyConfig, MerkleForest, TreeDigest};
pub use lag::{LagTracker, PeerLag};
pub use replication::{ReplicationConfig, Replicator};
pub use ring::{HashRing, Placement};
pub use storage::{Storage, StorageConfig};

use antientropy::{AeRuntime, AntiEntropy, Kick};

use std::collections::{BTreeMap, HashMap, HashSet};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::cluster::{HintConfig, HintedHandoff};
use crate::http::{Handler, Request, Response, Server};
use crate::json::{self, Value};
use crate::netsim::{LinkModel, TrafficMeter};
use crate::sync::{classes, OrderedRwLock};
use crate::transport::{NetStats, PeerPool, TransportConfig};
use crate::{Error, Result};

/// A versioned value.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    /// Stored payload (DisCEdge stores JSON documents here).
    pub value: String,
    /// Monotonic version; DisCEdge uses the session turn counter.
    pub version: u64,
    /// Absolute expiry instant (None = no TTL).
    pub expires_at: Option<Instant>,
}

impl Entry {
    fn is_expired(&self, now: Instant) -> bool {
        self.expires_at.map_or(false, |e| e <= now)
    }
}

/// Number of lock stripes in [`Store`]. A power of two so the shard pick
/// is a mask of the key hash; 16 stripes keep writer collisions rare at
/// edge core counts without bloating per-node memory.
const STORE_SHARDS: usize = 16;

/// One lock stripe: an independent `keygroup -> key -> entry` map
/// guarding the keys whose hash lands on this stripe. The lockdep rank
/// is the stripe index, so debug builds panic on out-of-index-order
/// multi-stripe acquisition as well as on any lock nested under a stripe.
type Shard = OrderedRwLock<HashMap<String, BTreeMap<String, Entry>>>;

/// In-memory replica state shared between the public API, the replication
/// receiver, and the janitor.
///
/// Lock-striped: keys spread over [`STORE_SHARDS`] independent maps by
/// FNV-1a key hash, so concurrent session writes (distinct sessions ⇒
/// distinct keys ⇒ almost always distinct stripes) no longer serialize on
/// one global lock. Lock order, crate-wide: a thread holding a shard lock
/// takes **no other lock** — forest marks and WAL appends happen strictly
/// after the shard guard drops, and multi-shard readers (sweep, digest,
/// snapshot) take shard locks in index order only.
#[derive(Debug)]
pub struct Store {
    /// The stripes; index = `fnv1a(key) & (STORE_SHARDS - 1)`.
    shards: Vec<Shard>,
    /// known keygroups
    keygroups: RwLock<HashSet<String>>,
    /// Merkle forest for anti-entropy digests; installed when repair is
    /// enabled so every mutation marks the key's bucket dirty. `None`
    /// (the default) keeps mutations free of tracking work.
    forest: RwLock<Option<Arc<MerkleForest>>>,
    /// Local persistence engine; installed (after recovery) when
    /// `storage.enabled` so every applied mutation appends a WAL record.
    /// `None` (the default) keeps the write path clone- and I/O-free.
    storage: RwLock<Option<Arc<Storage>>>,
}

impl Store {
    fn new() -> Arc<Store> {
        Arc::new(Store {
            shards: (0..STORE_SHARDS)
                .map(|i| OrderedRwLock::with_rank(&classes::STORE_STRIPE, i as u32, HashMap::new()))
                .collect(),
            keygroups: RwLock::new(HashSet::new()),
            forest: RwLock::new(None),
            storage: RwLock::new(None),
        })
    }

    /// The stripe guarding `key`.
    fn shard(&self, key: &str) -> &Shard {
        &self.shards[crate::testkit::fnv1a(key.as_bytes()) as usize & (STORE_SHARDS - 1)]
    }

    /// Attach the anti-entropy forest; from now on every mutation marks
    /// the touched bucket dirty.
    fn install_forest(&self, forest: Arc<MerkleForest>) {
        *self.forest.write().unwrap() = Some(forest);
    }

    /// Attach the persistence engine; from now on every applied mutation
    /// is WAL-logged. Call *after* [`Storage::recover`] — replay must not
    /// re-log itself.
    fn install_storage(&self, storage: Arc<Storage>) {
        *self.storage.write().unwrap() = Some(storage);
    }

    /// Dirty-mark `key`'s tree bucket. Called *after* the shard lock is
    /// released (the forest has its own lock; nesting them would deadlock
    /// against a concurrent digest rebuild reading the data).
    fn mark_ae(&self, keygroup: &str, key: &str) {
        if let Some(forest) = self.forest.read().unwrap().as_ref() {
            forest.mark(keygroup, key);
        }
    }

    /// Apply a write if it is newer than what we have. Returns true when
    /// the write was applied (or equal-version idempotent re-apply).
    fn apply(
        &self,
        keygroup: &str,
        key: &str,
        value: String,
        version: u64,
        ttl: Option<Duration>,
    ) -> bool {
        let storage = self.storage.read().unwrap().clone();
        // The value moves into the map under the lock; keep a copy for
        // the WAL only when one is attached (the default path stays
        // allocation-identical to the seed).
        let logged = storage.as_ref().map(|_| value.clone());
        let applied = {
            let mut data = self.shard(key).write().unwrap();
            let kg = data.entry(keygroup.to_string()).or_default();
            match kg.get(key) {
                Some(existing) if existing.version > version => false,
                _ => {
                    kg.insert(
                        key.to_string(),
                        Entry {
                            value,
                            version,
                            expires_at: ttl.map(|t| Instant::now() + t),
                        },
                    );
                    true
                }
            }
        };
        if applied {
            if let Some(s) = &storage {
                s.log_put(keygroup, key, logged.as_deref().unwrap_or(""), version, ttl);
                s.maybe_snapshot(self);
            }
            self.mark_ae(keygroup, key);
        }
        applied
    }

    fn read(&self, keygroup: &str, key: &str) -> Option<Entry> {
        let now = Instant::now();
        let data = self.shard(key).read().unwrap();
        data.get(keygroup)
            .and_then(|kg| kg.get(key))
            .filter(|e| !e.is_expired(now))
            .cloned()
    }

    fn remove(&self, keygroup: &str, key: &str) -> bool {
        let removed = {
            let mut data = self.shard(key).write().unwrap();
            data.get_mut(keygroup).and_then(|kg| kg.remove(key))
        };
        let Some(entry) = removed else {
            return false;
        };
        let storage = self.storage.read().unwrap().clone();
        if let Some(s) = storage {
            s.log_delete(keygroup, key, entry.version);
            s.maybe_snapshot(self);
        }
        self.mark_ae(keygroup, key);
        true
    }

    /// Recovery-only delete: remove iff the live entry's version is
    /// `<= version` (the version captured when the delete was logged), so
    /// replaying an old WAL delete never clobbers a newer snapshot entry.
    fn remove_if_not_newer(&self, keygroup: &str, key: &str, version: u64) -> bool {
        let removed = {
            let mut data = self.shard(key).write().unwrap();
            match data.get_mut(keygroup) {
                Some(kg) => match kg.get(key) {
                    Some(e) if e.version <= version => kg.remove(key).is_some(),
                    _ => false,
                },
                None => false,
            }
        };
        if removed {
            self.mark_ae(keygroup, key);
        }
        removed
    }

    /// Sweep expired entries; returns the number evicted. Evictions are
    /// not WAL-logged: records persist absolute expiry deadlines, so
    /// recovery re-drops anything past its deadline on its own.
    fn sweep(&self) -> usize {
        let now = Instant::now();
        // Evicted keys are collected only when a forest will consume
        // them — the default (repair-off) janitor stays allocation-free.
        let track = self.forest.read().unwrap().is_some();
        let mut evicted: Vec<(String, String)> = Vec::new();
        let mut count = 0usize;
        for shard in &self.shards {
            let mut data = shard.write().unwrap();
            for (kg_name, kg) in data.iter_mut() {
                kg.retain(|key, e| {
                    let keep = !e.is_expired(now);
                    if !keep {
                        count += 1;
                        if track {
                            evicted.push((kg_name.clone(), key.clone()));
                        }
                    }
                    keep
                });
            }
        }
        for (kg, key) in &evicted {
            self.mark_ae(kg, key);
        }
        count
    }

    fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|shard| shard.read().unwrap().values().map(|kg| kg.len()).sum::<usize>())
            .sum()
    }

    /// Run `f` over the keygroup's entries in **key order** (the order the
    /// anti-entropy digest fold is defined over — it was the single
    /// BTreeMap's iteration order before striping). Holds every shard
    /// read lock, in index order, for the duration of `f`.
    fn with_keygroup_sorted<R>(
        &self,
        keygroup: &str,
        f: impl FnOnce(&[(&String, &Entry)]) -> R,
    ) -> R {
        let guards: Vec<_> = self.shards.iter().map(|shard| shard.read().unwrap()).collect();
        let mut items: Vec<(&String, &Entry)> = Vec::new();
        for g in &guards {
            if let Some(kg) = g.get(keygroup) {
                items.extend(kg.iter());
            }
        }
        items.sort_unstable_by(|a, b| a.0.cmp(b.0));
        f(&items)
    }

    /// Clone out every live entry with its remaining TTL — the snapshot
    /// writer's state capture. Shard read locks are taken sequentially;
    /// the WAL mutex (held by the caller) is what freezes the
    /// snapshot/WAL cut line, not the shard locks.
    fn dump_live(&self) -> Vec<(String, String, String, u64, Option<Duration>)> {
        let now = Instant::now();
        let mut out = Vec::new();
        for shard in &self.shards {
            let data = shard.read().unwrap();
            for (kg_name, kg) in data.iter() {
                for (key, e) in kg {
                    if e.is_expired(now) {
                        continue;
                    }
                    let remaining = e.expires_at.map(|t| t.saturating_duration_since(now));
                    out.push((kg_name.clone(), key.clone(), e.value.clone(), e.version, remaining));
                }
            }
        }
        out
    }
}

/// Configuration of one KV node.
#[derive(Debug, Clone)]
pub struct KvConfig {
    /// Port for the replication listener (0 = ephemeral).
    pub port: u16,
    /// Link model for the inter-node replication hops.
    pub peer_link: LinkModel,
    /// Replication behaviour.
    pub replication: ReplicationConfig,
    /// Default TTL applied when the writer does not specify one.
    pub default_ttl: Option<Duration>,
    /// Janitor sweep interval.
    pub sweep_interval: Duration,
    /// Hinted handoff for unreachable peers (set when cluster membership
    /// is enabled). `None` keeps the seed's drop-after-retries behaviour.
    pub hints: Option<HintConfig>,
    /// Merkle-tree anti-entropy repair (default off: no listener, no
    /// digest traffic — the seed's wire behaviour, byte-for-byte).
    pub antientropy: AntiEntropyConfig,
    /// Transport layer: outbound pool idle bound and the inbound
    /// listener budget applied to this node's replication and
    /// anti-entropy listeners.
    pub transport: TransportConfig,
    /// Local persistence: WAL + snapshot + crash recovery (default off:
    /// memory-only, no files touched — the seed's behaviour).
    pub storage: StorageConfig,
    /// Node observability state shared with the owning server (spans,
    /// events). The default disabled state records nothing and never
    /// originates a trace header.
    pub obs: Arc<crate::obs::Obs>,
}

impl Default for KvConfig {
    fn default() -> KvConfig {
        KvConfig {
            port: 0,
            peer_link: LinkModel::lan(),
            replication: ReplicationConfig::default(),
            default_ttl: Some(Duration::from_secs(3600)),
            sweep_interval: Duration::from_millis(500),
            hints: None,
            antientropy: AntiEntropyConfig::default(),
            transport: TransportConfig::default(),
            storage: StorageConfig::default(),
            obs: crate::obs::Obs::disabled(),
        }
    }
}

/// One node's replica of the distributed KV store.
pub struct KvNode {
    /// Node name (for logs/metrics).
    pub name: String,
    store: Arc<Store>,
    replicator: Replicator,
    server: Server,
    /// keygroup -> peers receiving its updates (replicate-to-all path)
    peers: Arc<Mutex<HashMap<String, Vec<SocketAddr>>>>,
    /// Ring placement; when set, writes target preference lists instead of
    /// the full `peers` subscription. Shared (`Arc`) with the
    /// anti-entropy runtime so placement swaps are visible to repair.
    placement: Arc<RwLock<Option<Arc<Placement>>>>,
    /// Replication address -> anti-entropy listener address of known
    /// peers (the replicate-to-all analogue of the placement's AE map).
    ae_map: Arc<Mutex<HashMap<SocketAddr, SocketAddr>>>,
    /// Anti-entropy machinery (None when disabled).
    ae: Option<AeParts>,
    /// Pool for outbound `/fetch` reads (mobility / read-repair / delta
    /// fallback / repair pulls), carrying the fetch meter.
    fetch_pool: Arc<PeerPool>,
    /// Node-wide connection-lifecycle counters, shared by every pool
    /// and listener of this node.
    net: Arc<NetStats>,
    /// Remote reads issued because the local replica missed.
    fetches: AtomicU64,
    /// Remote reads that repaired a newer entry into the local store.
    read_repairs: AtomicU64,
    /// Inbound deltas applied contiguously (shared with the endpoint).
    delta_applies: Arc<AtomicU64>,
    /// Inbound deltas recovered via full-state fallback fetch.
    delta_fallbacks: Arc<AtomicU64>,
    /// Hinted handoff shared with the replicator (membership mode only).
    handoff: Option<Arc<HintedHandoff>>,
    /// Replication-lag tracker shared with the replicator and the
    /// anti-entropy heal hook (None with observability off — the seed's
    /// bookkeeping-free push path).
    lag: Option<Arc<LagTracker>>,
    /// Local persistence engine (None when `storage.enabled` is off).
    storage: Option<Arc<Storage>>,
    config: KvConfig,
    janitor_stop: Arc<std::sync::atomic::AtomicBool>,
    janitor: Option<std::thread::JoinHandle<()>>,
}

/// One node's anti-entropy machinery: the shared runtime, the damage
/// sink the replication pipeline reports losses to, the round latch, the
/// dedicated digest listener, and the background round thread.
struct AeParts {
    runtime: Arc<AeRuntime>,
    sink: Arc<AeSink>,
    kick: Arc<Kick>,
    server: Server,
    engine: AntiEntropy,
}

/// Shared state of the inbound replication endpoint: the store plus what
/// the delta fallback path needs (the node's fetch pool, to `/fetch`
/// full state from the sender) and the delta counters.
struct ReplicaCtx {
    store: Arc<Store>,
    /// Pool shared with [`KvNode::fetch_pool`]: fallback fetches are
    /// remote-read traffic, accounted like ring mobility reads.
    fetch_pool: Arc<PeerPool>,
    /// Deltas applied contiguously onto the local entry.
    delta_applies: Arc<AtomicU64>,
    /// Deltas that could not apply (gap/mismatch) and were recovered via a
    /// full-state fetch from the sender.
    delta_fallbacks: Arc<AtomicU64>,
    /// Serve-side span recording: an inbound request carrying a trace
    /// context gets its handling recorded as a child span on this node.
    obs: Arc<crate::obs::Obs>,
}

impl KvNode {
    /// Start a node: replication listener + sender + janitor.
    pub fn start(name: &str, config: KvConfig) -> Result<KvNode> {
        let store = Store::new();
        // Recovery-from-disk comes FIRST in the rejoin sequence: the
        // store is repopulated from snapshot + WAL before the replication
        // listener, hint replay, or anti-entropy can observe it, so the
        // network paths only reconcile the tail written while this node
        // was down. `install_storage` follows recovery so replay does not
        // re-log itself; the forest (installed below) starts all-dirty,
        // so its first digest covers every recovered entry.
        let storage = if config.storage.enabled {
            let s = Storage::open(&config.storage)?;
            s.recover(&store)?;
            store.install_storage(s.clone());
            Some(s)
        } else {
            None
        };
        let net = NetStats::new();
        let limits = config.transport.server_limits(Some(net.clone()));
        let fetch_pool = Arc::new(config.transport.pool(
            TrafficMeter::new(),
            config.peer_link.clone(),
            net.clone(),
        ));
        let delta_applies = Arc::new(AtomicU64::new(0));
        let delta_fallbacks = Arc::new(AtomicU64::new(0));
        let ctx = ReplicaCtx {
            store: store.clone(),
            fetch_pool: fetch_pool.clone(),
            delta_applies: delta_applies.clone(),
            delta_fallbacks: delta_fallbacks.clone(),
            obs: config.obs.clone(),
        };
        let handler: Handler = Arc::new(move |req: &Request| {
            let started = Instant::now();
            let resp = replication_endpoint(&ctx, req);
            // An inbound push/fetch carrying a trace context (installed
            // by the HTTP server from `x-pallas-trace`) records its
            // handling as this node's child span — the remote half of a
            // roaming turn's stitched trace. No-op otherwise.
            if let Some(parent) = crate::obs::current() {
                let name = match req.path.as_str() {
                    "/fetch" => "serve_fetch",
                    _ => "repl_apply",
                };
                let child = ctx.obs.child(parent);
                ctx.obs.record_span(
                    child,
                    Some(parent.span_id),
                    name,
                    &req.path,
                    started,
                    started.elapsed(),
                );
            }
            resp
        });
        let server =
            Server::serve_with(config.port, config.peer_link.clone(), limits.clone(), handler)?;
        let handoff = config.hints.clone().map(HintedHandoff::new);
        let placement: Arc<RwLock<Option<Arc<Placement>>>> = Arc::new(RwLock::new(None));
        let peers: Arc<Mutex<HashMap<String, Vec<SocketAddr>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let ae_map: Arc<Mutex<HashMap<SocketAddr, SocketAddr>>> =
            Arc::new(Mutex::new(HashMap::new()));
        // Lag bookkeeping rides the observability switch: purely local
        // (never on the wire), but still zero work on the default path.
        let lag = if config.obs.enabled() {
            Some(LagTracker::new())
        } else {
            None
        };
        let ae = if config.antientropy.enabled {
            let forest = MerkleForest::new(config.antientropy.fanout);
            store.install_forest(forest.clone());
            let kick = Kick::new();
            let sink = AeSink::new(kick.clone(), config.obs.clone());
            if let Some(h) = &handoff {
                // A hint evicted by the per-peer bound is data the push
                // pipeline can no longer deliver: hand it to repair.
                let s = sink.clone();
                h.set_eviction_hook(Arc::new(move |peer, hint| {
                    s.note_lost(peer, &hint.keygroup, &hint.key);
                }));
            }
            let digest_pool =
                config.transport.pool(TrafficMeter::new(), config.peer_link.clone(), net.clone());
            let runtime = AeRuntime::new(
                name,
                config.antientropy.clone(),
                store.clone(),
                forest,
                placement.clone(),
                peers.clone(),
                ae_map.clone(),
                handoff.clone(),
                config.peer_link.clone(),
                server.addr,
                fetch_pool.clone(),
                digest_pool,
                config.obs.clone(),
                lag.clone(),
            );
            let ae_server = antientropy::serve(runtime.clone(), limits)?;
            let engine = AntiEntropy::start(runtime.clone(), kick.clone());
            Some(AeParts {
                runtime,
                sink,
                kick,
                server: ae_server,
                engine,
            })
        } else {
            None
        };
        let replicator = Replicator::start(
            name.to_string(),
            config.replication.clone(),
            config.transport.pool(TrafficMeter::new(), config.peer_link.clone(), net.clone()),
            handoff.clone(),
            ae.as_ref().map(|parts| parts.sink.clone()),
            lag.clone(),
        );
        let janitor_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let jstop = janitor_stop.clone();
        let jstore = store.clone();
        let jstorage = storage.clone();
        let interval = config.sweep_interval;
        let janitor = std::thread::Builder::new()
            .name(format!("kv-janitor-{name}"))
            .spawn(move || {
                while !jstop.load(std::sync::atomic::Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    jstore.sweep();
                    // The janitor doubles as the snapshot pacer, so a
                    // node that stops writing still compacts a due WAL.
                    if let Some(s) = &jstorage {
                        s.maybe_snapshot(&jstore);
                    }
                }
            })?;
        Ok(KvNode {
            name: name.to_string(),
            store,
            replicator,
            server,
            peers,
            placement,
            ae_map,
            ae,
            fetch_pool,
            net,
            fetches: AtomicU64::new(0),
            read_repairs: AtomicU64::new(0),
            delta_applies,
            delta_fallbacks,
            handoff,
            lag,
            storage,
            config,
            janitor_stop,
            janitor: Some(janitor),
        })
    }

    /// Address of this node's replication listener.
    pub fn replication_addr(&self) -> SocketAddr {
        self.server.addr
    }

    /// Register a keygroup on this node (idempotent).
    pub fn create_keygroup(&self, keygroup: &str) {
        self.store
            .keygroups
            .write()
            .unwrap()
            .insert(keygroup.to_string());
    }

    /// Whether the keygroup exists on this node.
    pub fn has_keygroup(&self, keygroup: &str) -> bool {
        self.store.keygroups.read().unwrap().contains(keygroup)
    }

    /// Subscribe `peer` to updates of `keygroup` (push replication,
    /// FReD-style: only nodes serving the same model share the keygroup).
    pub fn add_peer(&self, keygroup: &str, peer: SocketAddr) {
        self.peers
            .lock()
            .unwrap()
            .entry(keygroup.to_string())
            .or_default()
            .push(peer);
    }

    /// Re-address every subscription of `old` to `new` (a peer restarted
    /// on a fresh port). No-op when `old` appears nowhere.
    pub fn replace_peer(&self, old: SocketAddr, new: SocketAddr) {
        if old == new {
            return;
        }
        for list in self.peers.lock().unwrap().values_mut() {
            for addr in list.iter_mut() {
                if *addr == old {
                    *addr = new;
                }
            }
        }
    }

    /// Failure-detector downcall: pushes addressed to `peer` park as
    /// hints immediately instead of burning connect attempts. No-op
    /// without hinted handoff.
    pub fn mark_peer_down(&self, peer: SocketAddr) {
        if let Some(h) = &self.handoff {
            h.set_down(peer);
        }
    }

    /// Failure-detector upcall: clear the down mark and replay hints
    /// parked while the peer (previously at `old`) was away, addressed to
    /// its current listener `new`. No-op without hinted handoff.
    pub fn mark_peer_alive(&self, old: SocketAddr, new: SocketAddr) {
        if let Some(h) = &self.handoff {
            // Forward first: a push already in flight to the old listener
            // parks under the new key, where replay will find it.
            h.set_forward(old, new);
            h.set_up(old);
            h.set_up(new);
            self.replicator.replay_hints(old, new);
            if old != new {
                // Drain anything parked under the new key too (forwarded
                // parks from a prior rejoin of this same peer).
                self.replicator.replay_hints(new, new);
            }
            // Hints bounded by `max_per_peer` may have evicted during the
            // outage: schedule an immediate anti-entropy round so the
            // returning peer heals past what replay could restore.
            if let Some(ae) = &self.ae {
                ae.kick.kick();
            }
        }
    }

    /// Install ring placement. From then on, writes to keygroups the
    /// placement knows about target the session's preference list instead
    /// of every subscribed peer, and [`KvNode::get_or_fetch`] may read
    /// through to home replicas.
    pub fn set_placement(&self, placement: Arc<Placement>) {
        *self.placement.write().unwrap() = Some(placement);
        // Topology changed (join, failure, rejoin): repair soon, with
        // the fresh preference lists.
        if let Some(ae) = &self.ae {
            ae.kick.kick();
        }
    }

    /// The installed placement, if any.
    pub fn placement(&self) -> Option<Arc<Placement>> {
        self.placement.read().unwrap().clone()
    }

    /// Write locally and asynchronously push to keygroup peers.
    pub fn put(&self, keygroup: &str, key: &str, value: String, version: u64) -> Result<()> {
        self.put_ttl(keygroup, key, value, version, self.config.default_ttl)
    }

    /// Write with an explicit TTL.
    pub fn put_ttl(
        &self,
        keygroup: &str,
        key: &str,
        value: String,
        version: u64,
        ttl: Option<Duration>,
    ) -> Result<()> {
        self.put_ttl_append(keygroup, key, value, version, ttl, None)
    }

    /// Write with an explicit TTL, optionally describing the write as an
    /// **append**: `fragment` is the part of `value` added on top of
    /// version `version - 1` (a `context::codec` fragment document).
    ///
    /// The local replica always stores the full `value`. With
    /// `replication.delta_sync` on and a fragment present, peers receive a
    /// delta record (base `version - 1`, the fragment, and this node's
    /// listener address for their full-state fallback) instead of the full
    /// value; otherwise the seed's full-state push is used. Version 1
    /// writes always push full state — there is nothing to append onto.
    pub fn put_ttl_append(
        &self,
        keygroup: &str,
        key: &str,
        value: String,
        version: u64,
        ttl: Option<Duration>,
        fragment: Option<&str>,
    ) -> Result<()> {
        if !self.has_keygroup(keygroup) {
            return Err(Error::KvStore(format!("unknown keygroup {keygroup}")));
        }
        let applied = self
            .store
            .apply(keygroup, key, value.clone(), version, ttl);
        if !applied {
            return Err(Error::KvStore(format!(
                "stale write to {keygroup}/{key} v{version}"
            )));
        }
        let peers = self.write_targets(keygroup, key);
        if !peers.is_empty() {
            match fragment {
                Some(frag) if self.config.replication.delta_sync && version > 1 => {
                    self.replicator.push_delta(
                        peers,
                        keygroup,
                        key,
                        frag,
                        version - 1,
                        version,
                        ttl,
                        self.replication_addr(),
                    );
                }
                _ => {
                    self.replicator
                        .push(peers, keygroup, key, &value, version, ttl);
                }
            }
        }
        Ok(())
    }

    /// Replica addresses a write to `keygroup/key` must be pushed to.
    ///
    /// With ring placement: the session's preference list minus this node
    /// (a writer outside the list pushes to all N home replicas — the
    /// write-through half of the mobility path). Without placement: every
    /// peer subscribed to the keygroup, the seed's replicate-to-all
    /// behaviour, byte-for-byte.
    fn write_targets(&self, keygroup: &str, key: &str) -> Vec<SocketAddr> {
        if let Some(placement) = self.placement() {
            if placement.has_keygroup(keygroup) {
                return placement
                    .replicas(keygroup, key)
                    .into_iter()
                    .filter(|(name, _)| name != &self.name)
                    .map(|(_, addr)| addr)
                    .collect();
            }
        }
        self.peers
            .lock()
            .unwrap()
            .get(keygroup)
            .cloned()
            .unwrap_or_default()
    }

    /// Read from the local replica only (DisCEdge's CM always reads local;
    /// waiting for replication is the CM's retry loop, not a remote read).
    pub fn get(&self, keygroup: &str, key: &str) -> Option<Entry> {
        self.store.read(keygroup, key)
    }

    /// Read with ring-aware read-through: serve locally when the local
    /// entry is at least `min_version`; otherwise, if this node is *not*
    /// one of the session's home replicas, fetch from the home replicas,
    /// **read-repair** the best entry into the local store, and return it.
    ///
    /// On a home replica (or without placement) this is exactly [`Self::get`]:
    /// waiting out replication lag stays the Context Manager's retry loop.
    /// The returned entry may still be older than `min_version` — the
    /// caller's consistency protocol decides what staleness means.
    pub fn get_or_fetch(&self, keygroup: &str, key: &str, min_version: u64) -> Option<Entry> {
        let local = self.store.read(keygroup, key);
        if let Some(e) = &local {
            if e.version >= min_version {
                return local;
            }
        }
        let placement = match self.placement() {
            Some(p) if p.has_keygroup(keygroup) => p,
            _ => return local,
        };
        // One ring walk: the preference list doubles as the membership
        // check for this node.
        let replicas = placement.replicas(keygroup, key);
        if replicas.iter().any(|(n, _)| n == &self.name) {
            return local;
        }
        let local_version = local.as_ref().map(|e| e.version);
        let mut best = local;
        let trace = crate::obs::current();
        let fetch_started = Instant::now();
        for (_, addr) in replicas {
            self.fetches.fetch_add(1, Ordering::SeqCst);
            if let Ok(Some(remote)) = self.fetch_from(addr, keygroup, key) {
                if best.as_ref().map_or(true, |b| remote.version > b.version) {
                    best = Some(remote);
                }
                if best.as_ref().map_or(false, |b| b.version >= min_version) {
                    break;
                }
            }
        }
        // The mobility read is the phase the paper's roaming penalty
        // lives in — record it as a child span of the turn's trace.
        if let Some(parent) = trace {
            let obs = &self.config.obs;
            let child = obs.child(parent);
            obs.record_span(
                child,
                Some(parent.span_id),
                "remote_fetch",
                &format!("{keygroup}/{key}"),
                fetch_started,
                fetch_started.elapsed(),
            );
        }
        if let Some(e) = &best {
            if local_version.map_or(true, |v| e.version > v) {
                // Read-repair: cache the fetched entry locally with its
                // remaining TTL so the node keeps serving this session
                // without refetching every turn.
                let ttl = e
                    .expires_at
                    .map(|t| t.saturating_duration_since(Instant::now()));
                if self.store.apply(keygroup, key, e.value.clone(), e.version, ttl) {
                    self.read_repairs.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
        best
    }

    /// One synchronous remote read from a peer's replication listener.
    fn fetch_from(&self, addr: SocketAddr, keygroup: &str, key: &str) -> Result<Option<Entry>> {
        fetch_entry(&self.fetch_pool, addr, keygroup, key, None)
    }

    /// Delete locally (client's explicit request, §3.3). Not replicated as
    /// a tombstone in the prototype; TTL handles remote cleanup — matching
    /// the paper's prototype scope.
    pub fn delete(&self, keygroup: &str, key: &str) -> bool {
        self.store.remove(keygroup, key)
    }

    /// Total live entries on this replica.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// True when the replica holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes received on this node's replication port (inbound sync).
    pub fn sync_rx_bytes(&self) -> u64 {
        self.server.meter.rx.get() + self.server.meter.tx.get()
    }

    /// Bytes sent by this node's replicator (outbound sync, incl. acks)
    /// plus outbound remote-read traffic. Zero fetches keep this identical
    /// to the seed's accounting.
    pub fn sync_tx_bytes(&self) -> u64 {
        self.replicator.meter().total() + self.fetch_pool.meter().total()
    }

    /// Connection-lifecycle counters aggregated across this node's
    /// transport pools (replication, fetch, digest walks) and listeners
    /// (`net_conns_*` on `/metrics`).
    pub fn net_stats(&self) -> &Arc<NetStats> {
        &self.net
    }

    /// Per-replica push targets enqueued by this node's writes (see
    /// [`Replicator::push_targets`]).
    pub fn push_targets(&self) -> u64 {
        self.replicator.push_targets()
    }

    /// Remote reads issued for sessions homed elsewhere.
    pub fn remote_fetches(&self) -> u64 {
        self.fetches.load(Ordering::SeqCst)
    }

    /// Remote reads that repaired an entry into the local store.
    pub fn read_repairs(&self) -> u64 {
        self.read_repairs.load(Ordering::SeqCst)
    }

    /// Whether this node replicates appends as deltas
    /// (`replication.delta_sync`). Writers use this to skip building
    /// fragment documents that would never go on the wire.
    pub fn delta_sync_enabled(&self) -> bool {
        self.config.replication.delta_sync
    }

    /// Inbound deltas applied contiguously onto the local entry.
    pub fn delta_applies(&self) -> u64 {
        self.delta_applies.load(Ordering::SeqCst)
    }

    /// Inbound deltas that hit a version gap (or mode mismatch) and were
    /// recovered via a full-state fetch from the sender.
    pub fn delta_fallbacks(&self) -> u64 {
        self.delta_fallbacks.load(Ordering::SeqCst)
    }

    /// Whether hinted handoff is configured on this node (it rides
    /// cluster membership; without it writes to down peers just drop).
    pub fn hints_enabled(&self) -> bool {
        self.handoff.is_some()
    }

    /// Updates parked as hints for unreachable peers (0 when disabled).
    pub fn hints_queued(&self) -> u64 {
        self.handoff.as_ref().map_or(0, |h| h.queued())
    }

    /// Hint records handed back for replay after a peer returned.
    pub fn hints_replayed(&self) -> u64 {
        self.handoff.as_ref().map_or(0, |h| h.replayed())
    }

    /// Hint records evicted by the per-peer bound.
    pub fn hints_dropped(&self) -> u64 {
        self.handoff.as_ref().map_or(0, |h| h.dropped())
    }

    /// Whether replication-lag bookkeeping is attached (observability
    /// on). The accessors below read 0/`None` when it is not.
    pub fn lag_tracking_enabled(&self) -> bool {
        self.lag.is_some()
    }

    /// Largest version gap between this node's head and any peer's last
    /// ack, over every key (`kv_repl_max_lag_versions`).
    pub fn max_lag_versions(&self) -> u64 {
        self.lag.as_ref().map_or(0, |l| l.max_lag_versions())
    }

    /// Keys currently behind on at least one peer (`kv_repl_lag_keys`).
    pub fn lag_keys(&self) -> u64 {
        self.lag.as_ref().map_or(0, |l| l.lag_keys())
    }

    /// Age in ms of the oldest unacknowledged update (`None` when every
    /// peer is caught up or tracking is off) — the node's estimated
    /// worst-case staleness window in `/status`.
    pub fn staleness_ms(&self) -> Option<u64> {
        self.lag.as_ref().and_then(|l| l.staleness_ms())
    }

    /// Per-peer lag rollup for `/status` (empty when clean or off).
    pub fn lag_per_peer(&self) -> Vec<PeerLag> {
        self.lag.as_ref().map_or_else(Vec::new, |l| l.per_peer())
    }

    /// Whether local persistence (WAL + snapshot) is running on this node.
    pub fn storage_enabled(&self) -> bool {
        self.storage.is_some()
    }

    /// WAL records appended (`kv_wal_appends`; 0 when storage is off).
    pub fn wal_appends(&self) -> u64 {
        self.storage.as_ref().map_or(0, |s| s.wal_appends())
    }

    /// Framed WAL bytes written (`kv_wal_bytes`).
    pub fn wal_bytes(&self) -> u64 {
        self.storage.as_ref().map_or(0, |s| s.wal_bytes())
    }

    /// Snapshots taken (`kv_snapshots`).
    pub fn snapshots_taken(&self) -> u64 {
        self.storage.as_ref().map_or(0, |s| s.snapshots())
    }

    /// Entries replayed from local disk at start (`kv_recovered_entries`).
    pub fn recovered_entries(&self) -> u64 {
        self.storage.as_ref().map_or(0, |s| s.recovered_entries())
    }

    /// Torn/corrupt log tails detected and truncated during recovery
    /// (`kv_wal_truncations`).
    pub fn wal_truncations(&self) -> u64 {
        self.storage.as_ref().map_or(0, |s| s.wal_truncations())
    }

    /// Milliseconds since the last snapshot completed (`None` before the
    /// first snapshot or with storage off) — `/status` freshness.
    pub fn snapshot_age_ms(&self) -> Option<u64> {
        self.storage
            .as_ref()
            .and_then(|s| s.snapshot_age())
            .map(|d| d.as_millis() as u64)
    }

    /// Snapshot the store to disk now (tests, examples, orderly
    /// shutdown). No-op without storage.
    pub fn snapshot_now(&self) -> Result<()> {
        match &self.storage {
            Some(s) => s.snapshot(&self.store),
            None => Ok(()),
        }
    }

    /// Whether Merkle-tree anti-entropy repair is running on this node.
    pub fn antientropy_enabled(&self) -> bool {
        self.ae.is_some()
    }

    /// Address of this node's anti-entropy listener (None when disabled).
    pub fn ae_addr(&self) -> Option<SocketAddr> {
        self.ae.as_ref().map(|parts| parts.server.addr)
    }

    /// Teach this node where a peer's anti-entropy listener lives
    /// (replicate-to-all wiring; placement-mode fleets carry the map in
    /// the [`Placement`] instead). The mapping is inert with repair
    /// disabled.
    pub fn map_ae_peer(&self, peer_kv: SocketAddr, peer_ae: SocketAddr) {
        self.ae_map.lock().unwrap().insert(peer_kv, peer_ae);
    }

    /// Run one synchronous anti-entropy round now (tests, benches, the
    /// demo example). Returns entries repaired on this side; 0 when
    /// disabled.
    pub fn run_antientropy_round(&self) -> u64 {
        self.ae.as_ref().map_or(0, |parts| parts.runtime.run_once())
    }

    /// Digest exchanges initiated by this node's repair engine.
    pub fn ae_rounds(&self) -> u64 {
        self.ae.as_ref().map_or(0, |parts| parts.runtime.rounds())
    }

    /// Milliseconds since the last anti-entropy round started (`None`
    /// before the first round or with repair off) — `/status` freshness.
    pub fn ae_last_round_age_ms(&self) -> Option<u64> {
        self.ae
            .as_ref()
            .and_then(|parts| parts.runtime.last_round_age())
            .map(|d| d.as_millis() as u64)
    }

    /// This node's observability state (shared with the owning server).
    pub fn obs(&self) -> &Arc<crate::obs::Obs> {
        &self.config.obs
    }

    /// Entries pulled and applied by anti-entropy repair.
    pub fn ae_keys_repaired(&self) -> u64 {
        self.ae.as_ref().map_or(0, |parts| parts.runtime.repaired())
    }

    /// Equal-version byte mismatches repaired deterministically.
    pub fn ae_conflicts(&self) -> u64 {
        self.ae.as_ref().map_or(0, |parts| parts.runtime.conflicts())
    }

    /// Bytes moved by the digest walk, both directions: this node's
    /// outbound `/ae/*` requests plus everything through its anti-entropy
    /// listener. Rides dedicated meters — never part of the
    /// replication-port accounting ([`KvNode::sync_rx_bytes`] /
    /// [`KvNode::sync_tx_bytes`]).
    pub fn ae_digest_bytes(&self) -> u64 {
        self.ae.as_ref().map_or(0, |parts| {
            parts.runtime.digest_tx_bytes() + parts.server.meter.total()
        })
    }

    /// Updates the push pipeline reported as lost (exhausted drops, hint
    /// evictions) and handed to repair.
    pub fn ae_lost_updates(&self) -> u64 {
        self.ae.as_ref().map_or(0, |parts| parts.sink.lost())
    }

    /// Replication pushes dropped, all causes combined.
    pub fn repl_dropped_total(&self) -> u64 {
        self.replicator.dropped_total()
    }

    /// Replication pushes dropped by failure injection.
    pub fn repl_dropped_injected(&self) -> u64 {
        self.replicator.dropped_injected()
    }

    /// Replication pushes dropped after exhausting attempts.
    pub fn repl_dropped_exhausted(&self) -> u64 {
        self.replicator.dropped_exhausted()
    }

    /// Replication pushes dropped at/after shutdown or hard kill.
    pub fn repl_dropped_shutdown(&self) -> u64 {
        self.replicator.dropped_shutdown()
    }

    /// Wait until the replicator's queue is drained (test/benchmark sync).
    pub fn quiesce(&self) {
        self.replicator.quiesce();
    }

    /// Crash emulation (test hook): sever the replication listener and
    /// its accepted connections so peers' pushes fail immediately, and
    /// discard this node's own outbound queue — a killed node must
    /// neither apply nor send another write. Callable through the shared
    /// handle; background threads are joined later when the node drops.
    pub fn kill(&self) {
        self.janitor_stop
            .store(true, std::sync::atomic::Ordering::SeqCst);
        self.replicator.abort();
        self.server.request_stop();
        if let Some(ae) = &self.ae {
            // A killed node must neither answer digest walks nor repair.
            ae.engine.request_stop();
            ae.server.request_stop();
        }
    }

    /// Stop all background machinery.
    pub fn shutdown(&mut self) {
        self.janitor_stop
            .store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(j) = self.janitor.take() {
            let _ = j.join();
        }
        if let Some(ae) = &mut self.ae {
            ae.engine.shutdown();
            ae.server.shutdown();
        }
        self.replicator.shutdown();
        self.server.shutdown();
    }
}

impl Drop for KvNode {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One synchronous `/fetch` round-trip to a peer's replication listener,
/// shared by ring-mobility reads ([`KvNode::get_or_fetch`]), the delta
/// fallback path in [`replication_endpoint`], and anti-entropy repair
/// pulls — all riding the node's keep-alive fetch pool. `timeout` bounds
/// connect and I/O when given (the repair path must survive a wedged
/// peer); `None` keeps the seed's blocking behaviour for the
/// request-path reads.
fn fetch_entry(
    pool: &PeerPool,
    addr: SocketAddr,
    keygroup: &str,
    key: &str,
    timeout: Option<Duration>,
) -> Result<Option<Entry>> {
    let payload = Value::obj().set("kg", keygroup).set("key", key).to_json();
    let mut conn = match timeout {
        Some(t) => pool.checkout_timeout(addr, t)?,
        None => pool.checkout(addr)?,
    };
    let resp = conn.round_trip(&Request::post_json("/fetch", &payload))?;
    if resp.status != 200 {
        return Err(Error::KvStore(format!(
            "fetch {keygroup}/{key} from {addr}: status {}",
            resp.status
        )));
    }
    let v = json::parse(resp.body_str()?)?;
    if v.get("found").and_then(|f| f.as_bool()) != Some(true) {
        return Ok(None);
    }
    let (val, ver) = match (v.req_str("val"), v.req_u64("ver")) {
        (Ok(val), Ok(ver)) => (val, ver),
        _ => return Err(Error::KvStore("fetch response missing fields".into())),
    };
    let expires_at = v
        .get("ttl_ms")
        .and_then(|t| t.as_u64())
        .map(|ms| Instant::now() + Duration::from_millis(ms));
    Ok(Some(Entry {
        value: val,
        version: ver,
        expires_at,
    }))
}

/// Inbound replication endpoint: applies pushed writes to the local store
/// (`POST /replicate`, full-state or delta records) and answers remote
/// reads from non-replica nodes (`POST /fetch`, the ring mobility path —
/// also the delta fallback's recovery read).
fn replication_endpoint(ctx: &ReplicaCtx, req: &Request) -> Response {
    if req.method != "POST" || (req.path != "/replicate" && req.path != "/fetch") {
        return Response::error(404, "not found");
    }
    let store = &ctx.store;
    let body = match req.body_str() {
        Ok(b) => b,
        Err(_) => return Response::error(400, "body not utf-8"),
    };
    let v = match json::parse(body) {
        Ok(v) => v,
        Err(e) => return Response::error(400, &format!("bad json: {e}")),
    };
    if req.path == "/fetch" {
        let (kg, key) = match (v.req_str("kg"), v.req_str("key")) {
            (Ok(kg), Ok(key)) => (kg, key),
            _ => return Response::error(400, "missing fields"),
        };
        return match store.read(&kg, &key) {
            Some(e) => {
                let mut out = Value::obj()
                    .set("found", true)
                    .set("val", e.value.as_str())
                    .set("ver", e.version);
                if let Some(t) = e.expires_at {
                    let left = t.saturating_duration_since(Instant::now());
                    out = out.set("ttl_ms", left.as_millis() as u64);
                }
                Response::json(&out.to_json())
            }
            None => Response::json(&Value::obj().set("found", false).to_json()),
        };
    }
    if v.get("op").and_then(|o| o.as_str()) == Some("delta") {
        return apply_delta(ctx, &v);
    }
    let (kg, key, val, ver) = match (
        v.req_str("kg"),
        v.req_str("key"),
        v.req_str("val"),
        v.req_u64("ver"),
    ) {
        (Ok(kg), Ok(key), Ok(val), Ok(ver)) => (kg, key, val, ver),
        _ => return Response::error(400, "missing fields"),
    };
    let ttl = v
        .get("ttl_ms")
        .and_then(|t| t.as_u64())
        .map(Duration::from_millis);
    // Keygroups auto-create on receive: membership was already checked on
    // the sending side (only subscribed peers get pushes).
    store
        .keygroups
        .write()
        .unwrap()
        .insert(kg.clone());
    let applied = store.apply(&kg, &key, val, ver, ttl);
    Response::json(&Value::obj().set("applied", applied).to_json())
}

/// Apply a delta record: append the fragment iff the local entry is
/// exactly at the base version; treat equal-or-newer local versions as an
/// idempotent no-op; on a gap (or fragment/mode mismatch), recover by
/// fetching full state from the sender.
fn apply_delta(ctx: &ReplicaCtx, v: &Value) -> Response {
    let store = &ctx.store;
    let (kg, key, frag) = match (v.req_str("kg"), v.req_str("key"), v.req_str("frag")) {
        (Ok(kg), Ok(key), Ok(frag)) => (kg, key, frag),
        _ => return Response::error(400, "missing delta fields"),
    };
    let (base, ver) = match (v.req_u64("base"), v.req_u64("ver")) {
        (Ok(base), Ok(ver)) => (base, ver),
        _ => return Response::error(400, "missing delta versions"),
    };
    let ttl = v
        .get("ttl_ms")
        .and_then(|t| t.as_u64())
        .map(Duration::from_millis);
    store.keygroups.write().unwrap().insert(kg.clone());
    match store.read(&kg, &key) {
        // Already at (or past) the delta's target: idempotent re-apply.
        Some(local) if local.version >= ver => {
            return Response::json(&Value::obj().set("applied", true).to_json());
        }
        // Contiguous: splice the fragment onto the local document. A
        // mode-mismatched fragment falls through to the fetch fallback.
        Some(local) if local.version == base => {
            if let Ok(doc) = crate::context::codec::append_to_doc(&local.value, &frag, ver) {
                let applied = store.apply(&kg, &key, doc, ver, ttl);
                if applied {
                    ctx.delta_applies.fetch_add(1, Ordering::SeqCst);
                }
                return Response::json(&Value::obj().set("applied", applied).to_json());
            }
        }
        // Missing, expired, or behind the base: a gap.
        _ => {}
    }
    // Fallback: full-state read-repair from the sender (PR 1's /fetch
    // path). The sender holds at least `ver`, so one fetch converges.
    ctx.delta_fallbacks.fetch_add(1, Ordering::SeqCst);
    let from = match v.req_str("from").ok().and_then(|f| f.parse::<SocketAddr>().ok()) {
        Some(a) => a,
        None => return Response::error(400, "delta record missing sender address"),
    };
    match fetch_entry(&ctx.fetch_pool, from, &kg, &key, None) {
        Ok(Some(remote)) => {
            let remaining = remote
                .expires_at
                .map(|t| t.saturating_duration_since(Instant::now()));
            let applied = store.apply(&kg, &key, remote.value, remote.version, remaining);
            Response::json(
                &Value::obj()
                    .set("applied", applied)
                    .set("fallback", "fetch")
                    .to_json(),
            )
        }
        // Sender no longer has it (expired/evicted): report not applied;
        // TTL cleanup makes this benign, as in the seed's drop handling.
        Ok(None) | Err(_) => Response::json(
            &Value::obj()
                .set("applied", false)
                .set("fallback", "fetch")
                .to_json(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(name: &str) -> KvNode {
        let cfg = KvConfig {
            peer_link: LinkModel::ideal(),
            ..KvConfig::default()
        };
        KvNode::start(name, cfg).unwrap()
    }

    #[test]
    fn local_put_get() {
        let n = node("a");
        n.create_keygroup("m");
        n.put("m", "s1", "v1".into(), 1).unwrap();
        assert_eq!(n.get("m", "s1").unwrap().value, "v1");
        assert_eq!(n.get("m", "s1").unwrap().version, 1);
        assert!(n.get("m", "nope").is_none());
        assert!(n.get("other", "s1").is_none());
    }

    #[test]
    fn unknown_keygroup_rejected() {
        let n = node("a");
        assert!(n.put("nope", "k", "v".into(), 1).is_err());
    }

    #[test]
    fn version_conflicts_lww() {
        let n = node("a");
        n.create_keygroup("m");
        n.put("m", "k", "v2".into(), 2).unwrap();
        // Older write rejected.
        assert!(n.put("m", "k", "v1".into(), 1).is_err());
        assert_eq!(n.get("m", "k").unwrap().value, "v2");
        // Newer write wins.
        n.put("m", "k", "v3".into(), 3).unwrap();
        assert_eq!(n.get("m", "k").unwrap().value, "v3");
    }

    #[test]
    fn replication_two_nodes() {
        let a = node("a");
        let b = node("b");
        a.create_keygroup("m");
        b.create_keygroup("m");
        a.add_peer("m", b.replication_addr());
        a.put("m", "sess", "ctx-v1".into(), 1).unwrap();
        a.quiesce();
        let got = wait_for(|| b.get("m", "sess"), Duration::from_secs(2));
        let e = got.expect("replication should deliver");
        assert_eq!(e.value, "ctx-v1");
        assert_eq!(e.version, 1);
        // Sync traffic was metered on both ends.
        assert!(a.sync_tx_bytes() > 0);
        assert!(b.sync_rx_bytes() > 0);
    }

    #[test]
    fn replication_only_for_subscribed_keygroup() {
        let a = node("a");
        let b = node("b");
        a.create_keygroup("m1");
        a.create_keygroup("m2");
        b.create_keygroup("m1");
        a.add_peer("m1", b.replication_addr());
        a.put("m2", "x", "secret".into(), 1).unwrap();
        a.quiesce();
        std::thread::sleep(Duration::from_millis(50));
        assert!(b.get("m2", "x").is_none(), "m2 must not replicate");
    }

    #[test]
    fn ttl_expiry() {
        let n = node("a");
        n.create_keygroup("m");
        n.put_ttl("m", "k", "v".into(), 1, Some(Duration::from_millis(30)))
            .unwrap();
        assert!(n.get("m", "k").is_some());
        std::thread::sleep(Duration::from_millis(60));
        assert!(n.get("m", "k").is_none(), "expired entry visible");
    }

    #[test]
    fn delete_local() {
        let n = node("a");
        n.create_keygroup("m");
        n.put("m", "k", "v".into(), 1).unwrap();
        assert!(n.delete("m", "k"));
        assert!(!n.delete("m", "k"));
        assert!(n.get("m", "k").is_none());
    }

    #[test]
    fn sweep_evicts() {
        let s = Store::new();
        s.apply("m", "k", "v".into(), 1, Some(Duration::from_millis(1)));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(s.sweep(), 1);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn bidirectional_replication_converges() {
        let a = node("a");
        let b = node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        a.add_peer("m", b.replication_addr());
        b.add_peer("m", a.replication_addr());
        a.put("m", "k", "from-a".into(), 1).unwrap();
        a.quiesce();
        wait_for(|| b.get("m", "k"), Duration::from_secs(2)).unwrap();
        b.put("m", "k", "from-b".into(), 2).unwrap();
        b.quiesce();
        let got = wait_for(
            || a.get("m", "k").filter(|e| e.version == 2),
            Duration::from_secs(2),
        );
        assert_eq!(got.unwrap().value, "from-b");
    }

    /// Placement over already-started nodes, one keygroup "m".
    fn placement_over(nodes: &[&KvNode], rf: usize) -> Arc<Placement> {
        let members: Vec<(String, std::net::SocketAddr)> = nodes
            .iter()
            .map(|n| (n.name.clone(), n.replication_addr()))
            .collect();
        let mut p = Placement::new(rf);
        p.add_keygroup("m", &members, 32);
        for n in nodes {
            if let Some(ae) = n.ae_addr() {
                p.set_ae_addr(&n.name, ae);
            }
        }
        let p = Arc::new(p);
        for n in nodes {
            n.set_placement(p.clone());
        }
        p
    }

    #[test]
    fn sharded_put_reaches_only_the_preference_list() {
        let (a, b, c) = (node("a"), node("b"), node("c"));
        for n in [&a, &b, &c] {
            n.create_keygroup("m");
        }
        let placement = placement_over(&[&a, &b, &c], 2);
        let mut expected_targets = 0u64;
        let keys: Vec<String> = (0..8).map(|i| format!("u{i}/s{i}")).collect();
        for (i, key) in keys.iter().enumerate() {
            a.put("m", key, format!("v{i}"), 1).unwrap();
            let reps = placement.replicas("m", key);
            assert_eq!(reps.len(), 2);
            expected_targets += reps.iter().filter(|(n, _)| n != "a").count() as u64;
        }
        a.quiesce();
        assert_eq!(a.push_targets(), expected_targets);
        for key in &keys {
            let reps = placement.replicas("m", key);
            for n in [&b, &c] {
                let is_replica = reps.iter().any(|(name, _)| name == &n.name);
                if is_replica {
                    let arrived =
                        wait_for(|| n.get("m", key), Duration::from_secs(2)).is_some();
                    assert!(arrived, "replica {} must receive {key}", n.name);
                } else {
                    // The sender already quiesced; any stray delivery
                    // would be visible by now.
                    assert!(
                        n.get("m", key).is_none(),
                        "non-replica {} must not receive {key}",
                        n.name
                    );
                }
            }
        }
    }

    #[test]
    fn non_replica_read_fetches_and_repairs() {
        let (a, b) = (node("a"), node("b"));
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        let placement = placement_over(&[&a, &b], 1);
        // Pick a key homed on b, so a is outside the preference list.
        let key = (0..64)
            .map(|i| format!("u/s{i}"))
            .find(|k| placement.replicas("m", k)[0].0 == "b")
            .expect("some key must hash to b");
        b.put("m", &key, "ctx".into(), 3).unwrap();
        b.quiesce();
        assert!(a.get("m", &key).is_none(), "a is not a home replica");
        let e = a.get_or_fetch("m", &key, 3).expect("fetch from home replica");
        assert_eq!(e.value, "ctx");
        assert_eq!(e.version, 3);
        assert!(a.remote_fetches() >= 1);
        assert_eq!(a.read_repairs(), 1);
        // Read-repaired entry now serves locally without another fetch.
        let fetches_before = a.remote_fetches();
        assert_eq!(a.get_or_fetch("m", &key, 3).unwrap().value, "ctx");
        assert_eq!(a.remote_fetches(), fetches_before);
    }

    #[test]
    fn home_replica_never_fetches() {
        let (a, b) = (node("a"), node("b"));
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        let placement = placement_over(&[&a, &b], 1);
        let key = (0..64)
            .map(|i| format!("u/s{i}"))
            .find(|k| placement.replicas("m", k)[0].0 == "a")
            .expect("some key must hash to a");
        // a is home but has nothing yet: get_or_fetch must stay local
        // (waiting out lag is the Context Manager's retry loop).
        assert!(a.get_or_fetch("m", &key, 1).is_none());
        assert_eq!(a.remote_fetches(), 0);
    }

    #[test]
    fn without_placement_get_or_fetch_is_local_get() {
        let n = node("a");
        n.create_keygroup("m");
        n.put("m", "k", "v".into(), 2).unwrap();
        assert_eq!(n.get_or_fetch("m", "k", 2).unwrap().value, "v");
        // Stale relative to min_version: still returned as-is, no fetch.
        assert_eq!(n.get_or_fetch("m", "k", 5).unwrap().version, 2);
        assert_eq!(n.remote_fetches(), 0);
    }

    #[test]
    fn kill_severs_the_replication_listener() {
        let a = node("a");
        let b = node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        a.add_peer("m", b.replication_addr());
        b.kill();
        // The stop wake-up lets the severed listener finish tearing down.
        std::thread::sleep(Duration::from_millis(20));
        a.put("m", "k", "v".into(), 1).unwrap();
        a.quiesce();
        assert!(b.get("m", "k").is_none(), "killed node must not apply writes");
        assert_eq!(a.repl_dropped_exhausted(), 1);
        assert_eq!(a.repl_dropped_total(), 1);
    }

    #[test]
    fn hinted_handoff_replays_to_restarted_peer() {
        let cfg = KvConfig {
            peer_link: LinkModel::ideal(),
            hints: Some(crate::cluster::HintConfig::default()),
            replication: ReplicationConfig {
                max_attempts: 2,
                retry_backoff: Duration::ZERO,
                ..ReplicationConfig::default()
            },
            ..KvConfig::default()
        };
        let a = KvNode::start("a", cfg).unwrap();
        let b = node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        let old = b.replication_addr();
        a.add_peer("m", old);
        b.kill();
        std::thread::sleep(Duration::from_millis(20));
        a.mark_peer_down(old);
        // Writes during the outage park (and coalesce via LWW supersede).
        a.put("m", "s", "v1".into(), 1).unwrap();
        a.put("m", "s", "v2".into(), 2).unwrap();
        a.quiesce();
        assert_eq!(a.repl_dropped_total(), 0, "outage writes must be hinted");
        assert_eq!(a.hints_queued(), 2);
        // "Restart" the peer at a fresh address and replay.
        let b2 = node("b-restarted");
        b2.create_keygroup("m");
        a.replace_peer(old, b2.replication_addr());
        a.mark_peer_alive(old, b2.replication_addr());
        a.quiesce();
        let e = wait_for(
            || b2.get("m", "s").filter(|e| e.version == 2),
            Duration::from_secs(2),
        )
        .expect("replayed hint must reach the restarted peer");
        assert_eq!(e.value, "v2");
        assert_eq!(a.hints_replayed(), 1, "v2 superseded v1 in the queue");
        assert_eq!(a.hints_dropped(), 0);
    }

    fn wait_for<T>(mut f: impl FnMut() -> Option<T>, timeout: Duration) -> Option<T> {
        let start = Instant::now();
        while start.elapsed() < timeout {
            if let Some(v) = f() {
                return Some(v);
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        None
    }

    // ---- delta-append replication ----

    use crate::context::{StoredContext, TokenCodec};

    const CODEC: TokenCodec = TokenCodec::BinaryU16;

    fn delta_node(name: &str) -> KvNode {
        let cfg = KvConfig {
            peer_link: LinkModel::ideal(),
            replication: ReplicationConfig {
                delta_sync: true,
                ..ReplicationConfig::default()
            },
            ..KvConfig::default()
        };
        KvNode::start(name, cfg).unwrap()
    }

    fn doc(ids: &[u32], turns: u64) -> String {
        StoredContext::Tokens(ids.to_vec()).to_kv(turns, CODEC)
    }

    fn frag(ids: &[u32]) -> String {
        StoredContext::Tokens(ids.to_vec()).to_fragment(CODEC)
    }

    #[test]
    fn delta_applies_contiguously() {
        let a = delta_node("a");
        let b = delta_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        a.add_peer("m", b.replication_addr());
        // Turn 1 always ships full state.
        a.put_ttl_append("m", "s", doc(&[1, 2], 1), 1, None, Some(frag(&[1, 2]).as_str()))
            .unwrap();
        a.quiesce();
        wait_for(|| b.get("m", "s"), Duration::from_secs(2)).unwrap();
        // Turn 2 ships only the fragment; b splices it on.
        a.put_ttl_append("m", "s", doc(&[1, 2, 3], 2), 2, None, Some(frag(&[3]).as_str()))
            .unwrap();
        a.quiesce();
        let e = wait_for(
            || b.get("m", "s").filter(|e| e.version == 2),
            Duration::from_secs(2),
        )
        .unwrap();
        assert_eq!(e.value, doc(&[1, 2, 3], 2), "delta result == full-state doc");
        assert_eq!(b.delta_applies(), 1);
        assert_eq!(b.delta_fallbacks(), 0);
    }

    #[test]
    fn delta_gap_falls_back_to_full_fetch() {
        let a = delta_node("a");
        let b = delta_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        a.add_peer("m", b.replication_addr());
        // b never saw v1/v2 (peer wired up late): the v3 delta has a gap.
        a.put_ttl_append("m", "s", doc(&[1], 1), 1, None, None).unwrap();
        a.quiesce();
        wait_for(|| b.get("m", "s"), Duration::from_secs(2)).unwrap();
        b.delete("m", "s"); // simulate b having lost the entry
        a.put_ttl_append("m", "s", doc(&[1, 2], 2), 2, None, Some(frag(&[2]).as_str()))
            .unwrap();
        a.quiesce();
        // b cannot apply base=1 onto nothing -> fetches full state from a.
        let e = wait_for(
            || b.get("m", "s").filter(|e| e.version == 2),
            Duration::from_secs(2),
        )
        .expect("fallback must converge");
        assert_eq!(e.value, doc(&[1, 2], 2));
        assert_eq!(b.delta_fallbacks(), 1);
        assert_eq!(b.delta_applies(), 0);
    }

    #[test]
    fn delta_equal_version_is_idempotent() {
        let a = delta_node("a");
        let b = delta_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        a.add_peer("m", b.replication_addr());
        a.put_ttl_append("m", "s", doc(&[7], 1), 1, None, None).unwrap();
        a.quiesce();
        wait_for(|| b.get("m", "s"), Duration::from_secs(2)).unwrap();
        // Replay the same v2 delta twice directly through the replicator
        // (models a duplicate push after a sender retry).
        for _ in 0..2 {
            a.replicator.push_delta(
                vec![b.replication_addr()],
                "m",
                "s",
                &frag(&[8]),
                1,
                2,
                None,
                a.replication_addr(),
            );
        }
        a.quiesce();
        let e = wait_for(
            || b.get("m", "s").filter(|e| e.version == 2),
            Duration::from_secs(2),
        )
        .unwrap();
        // Applied exactly once: no doubled fragment, no fallback.
        assert_eq!(e.value, doc(&[7, 8], 2));
        assert_eq!(b.delta_applies(), 1);
        assert_eq!(b.delta_fallbacks(), 0);
    }

    #[test]
    fn delta_preserves_ttl() {
        let a = delta_node("a");
        let b = delta_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        a.add_peer("m", b.replication_addr());
        let ttl = Some(Duration::from_secs(60));
        a.put_ttl_append("m", "s", doc(&[1], 1), 1, ttl, None).unwrap();
        a.quiesce();
        wait_for(|| b.get("m", "s"), Duration::from_secs(2)).unwrap();
        a.put_ttl_append("m", "s", doc(&[1, 2], 2), 2, ttl, Some(frag(&[2]).as_str()))
            .unwrap();
        a.quiesce();
        let e = wait_for(
            || b.get("m", "s").filter(|e| e.version == 2),
            Duration::from_secs(2),
        )
        .unwrap();
        let left = e
            .expires_at
            .expect("delta apply must refresh the TTL")
            .saturating_duration_since(Instant::now());
        assert!(left > Duration::from_secs(50), "{left:?}");
        assert!(left <= Duration::from_secs(60));
    }

    // ---- anti-entropy repair ----

    /// Node with repair enabled but the background thread dormant
    /// (hour-long interval): tests drive rounds manually.
    fn ae_node(name: &str) -> KvNode {
        let cfg = KvConfig {
            peer_link: LinkModel::ideal(),
            replication: ReplicationConfig {
                max_attempts: 1,
                retry_backoff: Duration::ZERO,
                ..ReplicationConfig::default()
            },
            antientropy: AntiEntropyConfig {
                enabled: true,
                interval: Duration::from_secs(3600),
                ..AntiEntropyConfig::default()
            },
            ..KvConfig::default()
        };
        KvNode::start(name, cfg).unwrap()
    }

    /// Wire `a` and `b` as replicate-to-all peers with AE listener maps.
    fn wire_ae(a: &KvNode, b: &KvNode) {
        a.add_peer("m", b.replication_addr());
        a.map_ae_peer(b.replication_addr(), b.ae_addr().unwrap());
        b.add_peer("m", a.replication_addr());
        b.map_ae_peer(a.replication_addr(), a.ae_addr().unwrap());
    }

    #[test]
    fn antientropy_heals_exhausted_drop_divergence() {
        // Regression for the "diverged forever" hole: without hints, a
        // push that exhausts its attempts used to only bump a counter —
        // now it is handed to repair, and one round heals the peer.
        let a = ae_node("a");
        let b = ae_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        a.add_peer("m", dead);
        a.put("m", "u/s", "ctx-v1".into(), 1).unwrap();
        a.quiesce();
        assert_eq!(a.repl_dropped_exhausted(), 1);
        assert_eq!(
            a.ae_lost_updates(),
            1,
            "exhausted drop must be reported to repair"
        );
        assert!(b.get("m", "u/s").is_none(), "b must have diverged");
        // The peer becomes reachable (re-addressed to b's listeners);
        // one digest round repairs b from a's replica.
        a.replace_peer(dead, b.replication_addr());
        a.map_ae_peer(b.replication_addr(), b.ae_addr().unwrap());
        a.run_antientropy_round();
        let e = b.get("m", "u/s").expect("repair must restore the entry");
        assert_eq!(e.value, "ctx-v1");
        assert_eq!(e.version, 1);
        assert_eq!(b.ae_keys_repaired(), 1, "the responder pulled the entry");
        assert!(a.ae_rounds() >= 1);
        assert!(a.ae_digest_bytes() > 0, "digest walk must be metered");
        assert_eq!(a.ae_conflicts() + b.ae_conflicts(), 0);
    }

    #[test]
    fn antientropy_converged_round_is_digest_only() {
        let a = ae_node("a");
        let b = ae_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        wire_ae(&a, &b);
        a.put("m", "u/s", "v".into(), 1).unwrap();
        a.quiesce();
        wait_for(|| b.get("m", "u/s"), Duration::from_secs(2)).unwrap();
        let tx_before = (a.sync_tx_bytes(), b.sync_tx_bytes());
        let root_only = a.ae_digest_bytes();
        assert_eq!(a.run_antientropy_round(), 0);
        assert_eq!(b.run_antientropy_round(), 0);
        assert_eq!(a.ae_keys_repaired() + b.ae_keys_repaired(), 0);
        // Converged trees stop at the root exchange...
        assert!(a.ae_digest_bytes() > root_only);
        // ...and never touch the replication-port accounting.
        assert_eq!((a.sync_tx_bytes(), b.sync_tx_bytes()), tx_before);
    }

    #[test]
    fn antientropy_resolves_equal_version_conflicts_deterministically() {
        // Equal versions with different bytes are beyond LWW's reach; the
        // higher content hash wins on both sides.
        let a = ae_node("a");
        let b = ae_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        // Diverge before wiring so the writes stay local.
        a.put("m", "u/s", "from-a".into(), 2).unwrap();
        b.put("m", "u/s", "from-b".into(), 2).unwrap();
        wire_ae(&a, &b);
        a.run_antientropy_round();
        let (ea, eb) = (a.get("m", "u/s").unwrap(), b.get("m", "u/s").unwrap());
        assert_eq!(ea.value, eb.value, "both sides must converge");
        assert_eq!(ea.version, 2);
        assert!(
            ["from-a", "from-b"].contains(&ea.value.as_str()),
            "winner must be one of the divergent values"
        );
        assert_eq!(
            a.ae_conflicts() + b.ae_conflicts(),
            1,
            "exactly one side pulled the conflict winner"
        );
        // A second round finds nothing left to do.
        assert_eq!(a.run_antientropy_round(), 0);
        assert_eq!(b.run_antientropy_round(), 0);
    }

    #[test]
    fn antientropy_repair_preserves_remaining_ttl() {
        let a = ae_node("a");
        let b = ae_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        // b unreachable at write time: divergence with a live TTL on a.
        a.put_ttl("m", "u/s", "v".into(), 1, Some(Duration::from_secs(60)))
            .unwrap();
        wire_ae(&a, &b);
        a.run_antientropy_round();
        let e = b.get("m", "u/s").expect("repair must deliver the entry");
        let left = e
            .expires_at
            .expect("repaired entry must keep its TTL")
            .saturating_duration_since(Instant::now());
        assert!(left > Duration::from_secs(50), "{left:?}");
        assert!(left <= Duration::from_secs(60));
    }

    #[test]
    fn antientropy_never_resurrects_expired_entries() {
        let a = ae_node("a");
        let b = ae_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        // Both writes stay local (peers unwired): one live entry, one
        // that expires before the first round.
        a.put("m", "u/live", "v".into(), 1).unwrap();
        a.put_ttl("m", "u/dying", "soon".into(), 1, Some(Duration::from_millis(20)))
            .unwrap();
        std::thread::sleep(Duration::from_millis(40));
        assert!(a.get("m", "u/dying").is_none(), "expired on a");
        wire_ae(&a, &b);
        a.run_antientropy_round();
        b.run_antientropy_round();
        // Repair delivered the live entry but never the expired one —
        // whether or not a's janitor swept it yet.
        assert_eq!(b.get("m", "u/live").unwrap().value, "v");
        assert!(
            b.get("m", "u/dying").is_none(),
            "repair must not resurrect an expired entry"
        );
    }

    #[test]
    fn antientropy_respects_preference_lists() {
        // Under ring placement only a key's home replicas repair it: a
        // non-replica never pulls (its cache ages out by TTL instead).
        let a = ae_node("a");
        let b = ae_node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        let placement = placement_over(&[&a, &b], 1);
        // A key homed on b that only b holds: a must not pull it.
        let key = (0..64)
            .map(|i| format!("u/s{i}"))
            .find(|k| placement.replicas("m", k)[0].0 == "b")
            .expect("some key must hash to b");
        b.put("m", &key, "homed-on-b".into(), 1).unwrap();
        b.quiesce();
        a.run_antientropy_round();
        b.run_antientropy_round();
        assert!(
            a.get("m", &key).is_none(),
            "non-replica must not pull keys homed elsewhere"
        );
        assert_eq!(a.ae_keys_repaired(), 0);
    }

    #[test]
    fn delta_disabled_keeps_full_state_pushes() {
        // With the default config the fragment hint must be ignored: the
        // peer receives full state (seed wire format) and never counts
        // delta activity.
        let a = node("a");
        let b = node("b");
        for n in [&a, &b] {
            n.create_keygroup("m");
        }
        a.add_peer("m", b.replication_addr());
        a.put_ttl_append("m", "s", doc(&[1], 1), 1, None, Some(frag(&[1]).as_str()))
            .unwrap();
        a.put_ttl_append("m", "s", doc(&[1, 2], 2), 2, None, Some(frag(&[2]).as_str()))
            .unwrap();
        a.quiesce();
        let e = wait_for(
            || b.get("m", "s").filter(|e| e.version == 2),
            Duration::from_secs(2),
        )
        .unwrap();
        assert_eq!(e.value, doc(&[1, 2], 2));
        assert_eq!(b.delta_applies(), 0);
        assert_eq!(b.delta_fallbacks(), 0);
    }

    /// One recorded mutation of the concurrency stress test below.
    enum StressOp {
        Put { kg: &'static str, key: String, val: String, ver: u64 },
        PutTtl { kg: &'static str, key: String, val: String, ver: u64 },
        Del { kg: &'static str, key: String },
    }

    /// `(keygroup, key, value, version)` of every live entry, sorted.
    fn live_state(store: &Store, keygroups: &[&str]) -> Vec<(String, String, String, u64)> {
        let mut out = Vec::new();
        for kg in keygroups {
            store.with_keygroup_sorted(kg, |items| {
                let now = Instant::now();
                for (key, e) in items {
                    if !e.is_expired(now) {
                        out.push((kg.to_string(), (*key).clone(), e.value.clone(), e.version));
                    }
                }
            });
        }
        out.sort();
        out
    }

    #[test]
    fn striped_store_concurrent_writers_match_single_threaded_replay() {
        // The regression gate for lock striping: N writer threads hammer
        // puts / gets / deletes / TTL writes across two keygroups while a
        // sweeper loops, then the final state AND the Merkle digest must
        // equal a single-threaded replay of the recorded operations.
        //
        // Determinism under interleaving is by construction: shared keys
        // take LWW writes with versions unique across threads (so the max
        // version — and its value — is interleaving-independent), deletes
        // touch only keys owned by a single thread (so their order is
        // program order), and TTL writes go to per-thread doomed keys that
        // both stores agree are expired by comparison time.
        const THREADS: usize = 8;
        const OPS: usize = 300;
        const KEYGROUPS: [&str; 2] = ["model-a", "model-b"];
        let store = Store::new();
        let forest = MerkleForest::new(4);
        store.install_forest(forest.clone());

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let sweeper = {
            let s = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    s.sweep();
                    std::thread::yield_now();
                }
            })
        };

        let mut workers = Vec::new();
        for t in 0..THREADS {
            let s = store.clone();
            workers.push(std::thread::spawn(move || {
                let mut rng = crate::testkit::Rng::new(0x57E55 + t as u64);
                let mut log: Vec<StressOp> = Vec::new();
                for i in 0..OPS {
                    let kg = *rng.pick(&KEYGROUPS);
                    match rng.below(10) {
                        0..=5 => {
                            // Shared key, thread-unique version: LWW makes
                            // the outcome order-independent.
                            let key = format!("shared-{}", rng.below(32));
                            let ver = (i * THREADS + t + 1) as u64;
                            let val = format!("v{ver}");
                            s.apply(kg, &key, val.clone(), ver, None);
                            log.push(StressOp::Put { kg, key, val, ver });
                        }
                        6 | 7 => {
                            // Thread-owned key: put then sometimes delete;
                            // single-writer, so program order replays.
                            let key = format!("own-{t}-{}", rng.below(8));
                            let ver = (i + 1) as u64;
                            let val = format!("own-v{ver}");
                            s.apply(kg, &key, val.clone(), ver, None);
                            log.push(StressOp::Put { kg, key: key.clone(), val, ver });
                            if rng.chance(0.3) {
                                s.remove(kg, &key);
                                log.push(StressOp::Del { kg, key });
                            }
                        }
                        8 => {
                            // Reads race the writers; the value, if any,
                            // must be internally consistent.
                            let key = format!("shared-{}", rng.below(32));
                            if let Some(e) = s.read(kg, &key) {
                                assert_eq!(e.value, format!("v{}", e.version));
                            }
                        }
                        _ => {
                            // Doomed TTL entry the sweeper races to evict.
                            let key = format!("doomed-{t}-{i}");
                            s.apply(kg, &key, "x".into(), 1, Some(Duration::from_millis(1)));
                            log.push(StressOp::PutTtl {
                                kg,
                                key,
                                val: "x".into(),
                                ver: 1,
                            });
                        }
                    }
                }
                log
            }));
        }
        let logs: Vec<Vec<StressOp>> = workers.into_iter().map(|w| w.join().unwrap()).collect();
        stop.store(true, Ordering::SeqCst);
        sweeper.join().unwrap();

        // Single-threaded replay: each thread's log in program order.
        let replay = Store::new();
        let replay_forest = MerkleForest::new(4);
        replay.install_forest(replay_forest.clone());
        for log in &logs {
            for op in log {
                match op {
                    StressOp::Put { kg, key, val, ver } => {
                        replay.apply(kg, key, val.clone(), *ver, None);
                    }
                    StressOp::PutTtl { kg, key, val, ver } => {
                        replay.apply(kg, key, val.clone(), *ver, Some(Duration::from_millis(1)));
                    }
                    StressOp::Del { kg, key } => {
                        replay.remove(kg, key);
                    }
                }
            }
        }
        // Let every doomed entry cross its 1 ms deadline before comparing.
        std::thread::sleep(Duration::from_millis(10));

        assert_eq!(
            live_state(&store, &KEYGROUPS),
            live_state(&replay, &KEYGROUPS),
            "concurrent final state must equal the single-threaded replay"
        );
        for kg in KEYGROUPS {
            assert_eq!(
                forest.digest(kg, &store).root,
                replay_forest.digest(kg, &replay).root,
                "Merkle digest must agree for {kg}"
            );
        }
    }

    #[test]
    fn striped_store_spreads_keys_and_keeps_len() {
        // Cheap sanity on the striping itself: distinct keys land on
        // multiple stripes and the aggregate count is exact.
        let s = Store::new();
        for i in 0..200 {
            s.apply("m", &format!("u/s{i}"), "v".into(), 1, None);
        }
        assert_eq!(s.len(), 200);
        let populated = s
            .shards
            .iter()
            .filter(|sh| sh.read().unwrap().values().any(|kg| !kg.is_empty()))
            .count();
        assert!(populated > STORE_SHARDS / 2, "only {populated} stripes used");
        for i in 0..200 {
            assert!(s.read("m", &format!("u/s{i}")).is_some());
        }
    }
}
