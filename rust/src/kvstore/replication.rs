//! Asynchronous push replication between KV nodes (FReD peer protocol
//! substitute).
//!
//! A background sender thread drains a queue of writes and POSTs each one
//! to every subscribed peer over keep-alive HTTP connections on the peer
//! replication port. An optional artificial delay models replication lag
//! (used by the consistency ablation to force the Context Manager's retry
//! path, which the paper observed "never needs more than two retries").

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::http::{Connection, Request};
use crate::json::Value;
use crate::netsim::{LinkModel, TrafficMeter};

/// Replication engine configuration.
#[derive(Debug, Clone)]
pub struct ReplicationConfig {
    /// Artificial delay before each push (models replication lag / FReD's
    /// async pipeline). Default: none.
    pub delay: Duration,
    /// Per-push connect/retry attempts before dropping the update.
    pub max_attempts: u32,
    /// Probability in [0,1] of dropping a push (failure injection).
    pub drop_probability: f64,
}

impl Default for ReplicationConfig {
    fn default() -> ReplicationConfig {
        ReplicationConfig {
            delay: Duration::ZERO,
            max_attempts: 3,
            drop_probability: 0.0,
        }
    }
}

struct Job {
    peers: Vec<SocketAddr>,
    payload: String,
}

/// Handle to the background replication sender.
pub struct Replicator {
    tx: Option<Sender<Job>>,
    thread: Option<std::thread::JoinHandle<()>>,
    meter: Arc<TrafficMeter>,
    queued: Arc<AtomicU64>,
    done: Arc<AtomicU64>,
    targets: Arc<AtomicU64>,
    /// Pushes dropped after exhausting attempts (or by failure injection).
    pub dropped: Arc<AtomicU64>,
}

impl Replicator {
    /// Spawn the sender thread.
    pub fn start(name: String, config: ReplicationConfig, link: LinkModel) -> Replicator {
        let (tx, rx) = channel::<Job>();
        let meter = TrafficMeter::new();
        let queued = Arc::new(AtomicU64::new(0));
        let done = Arc::new(AtomicU64::new(0));
        let dropped = Arc::new(AtomicU64::new(0));
        let t_meter = meter.clone();
        let t_done = done.clone();
        let t_dropped = dropped.clone();
        let thread = std::thread::Builder::new()
            .name(format!("kv-repl-{name}"))
            .spawn(move || {
                let mut rng = crate::testkit::Rng::new(0x5EED ^ name.len() as u64);
                let mut conns: HashMap<SocketAddr, Connection> = HashMap::new();
                while let Ok(job) = rx.recv() {
                    if !config.delay.is_zero() {
                        std::thread::sleep(config.delay);
                    }
                    for peer in &job.peers {
                        if config.drop_probability > 0.0 && rng.chance(config.drop_probability) {
                            t_dropped.fetch_add(1, Ordering::SeqCst);
                            continue;
                        }
                        let req = Request::post_json("/replicate", &job.payload);
                        let mut ok = false;
                        for _ in 0..config.max_attempts {
                            // Reuse a cached connection; reconnect on error.
                            let conn = match conns.entry(*peer) {
                                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                                std::collections::hash_map::Entry::Vacant(e) => {
                                    match Connection::open(*peer, t_meter.clone(), link.clone()) {
                                        Ok(c) => e.insert(c),
                                        Err(_) => continue,
                                    }
                                }
                            };
                            match conn.round_trip(&req) {
                                Ok(resp) if resp.status == 200 => {
                                    ok = true;
                                    break;
                                }
                                _ => {
                                    conns.remove(peer);
                                }
                            }
                        }
                        if !ok {
                            t_dropped.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    t_done.fetch_add(1, Ordering::SeqCst);
                }
            })
            .expect("spawn replicator");
        Replicator {
            tx: Some(tx),
            thread: Some(thread),
            meter,
            queued,
            done,
            targets: Arc::new(AtomicU64::new(0)),
            dropped,
        }
    }

    /// Enqueue a write for async push to `peers`.
    pub fn push(
        &self,
        peers: Vec<SocketAddr>,
        keygroup: &str,
        key: &str,
        value: &str,
        version: u64,
        ttl: Option<Duration>,
    ) {
        let mut payload = Value::obj()
            .set("kg", keygroup)
            .set("key", key)
            .set("val", value)
            .set("ver", version);
        if let Some(t) = ttl {
            payload = payload.set("ttl_ms", t.as_millis() as u64);
        }
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.targets.fetch_add(peers.len() as u64, Ordering::SeqCst);
        if let Some(tx) = &self.tx {
            let _ = tx.send(Job {
                peers,
                payload: payload.to_json(),
            });
        }
    }

    /// Bytes moved by this node's outbound replication.
    pub fn meter(&self) -> &Arc<TrafficMeter> {
        &self.meter
    }

    /// Total per-peer push targets enqueued: each write counts once per
    /// replica it is addressed to. With ring placement this is exactly
    /// `|preference list \ {writer}|` per write; with replicate-to-all it
    /// is the keygroup's subscriber count.
    pub fn push_targets(&self) -> u64 {
        self.targets.load(Ordering::SeqCst)
    }

    /// Block until every queued push has been processed.
    pub fn quiesce(&self) {
        while self.done.load(Ordering::SeqCst) < self.queued.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Stop the sender thread (drains remaining queue first).
    pub fn shutdown(&mut self) {
        self.tx.take(); // closes the channel; thread exits after drain
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Replicator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::{Response, Server};
    use std::sync::Mutex;

    #[test]
    fn pushes_reach_peer() {
        let received = Arc::new(Mutex::new(Vec::<String>::new()));
        let r2 = received.clone();
        let server = Server::serve(
            0,
            LinkModel::ideal(),
            Arc::new(move |req: &Request| {
                r2.lock().unwrap().push(req.body_str().unwrap().to_string());
                Response::json("{\"applied\":true}")
            }),
        )
        .unwrap();
        let repl = Replicator::start("t".into(), ReplicationConfig::default(), LinkModel::ideal());
        repl.push(vec![server.addr], "kg", "k", "v", 1, None);
        repl.quiesce();
        let msgs = received.lock().unwrap();
        assert_eq!(msgs.len(), 1);
        assert!(msgs[0].contains("\"ver\":1"));
        assert!(repl.meter().tx.get() > 0);
        assert_eq!(repl.push_targets(), 1);
    }

    #[test]
    fn drop_injection_counts() {
        let cfg = ReplicationConfig {
            drop_probability: 1.0,
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start("t".into(), cfg, LinkModel::ideal());
        // Peer doesn't even need to exist: drop happens first.
        repl.push(vec!["127.0.0.1:1".parse().unwrap()], "kg", "k", "v", 1, None);
        repl.quiesce();
        assert_eq!(repl.dropped.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn unreachable_peer_drops_after_attempts() {
        let cfg = ReplicationConfig {
            max_attempts: 2,
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start("t".into(), cfg, LinkModel::ideal());
        repl.push(vec!["127.0.0.1:1".parse().unwrap()], "kg", "k", "v", 1, None);
        repl.quiesce();
        assert_eq!(repl.dropped.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn delay_is_applied() {
        let server = Server::serve(
            0,
            LinkModel::ideal(),
            Arc::new(|_req: &Request| Response::json("{\"applied\":true}")),
        )
        .unwrap();
        let cfg = ReplicationConfig {
            delay: Duration::from_millis(30),
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start("t".into(), cfg, LinkModel::ideal());
        let t = std::time::Instant::now();
        repl.push(vec![server.addr], "kg", "k", "v", 1, None);
        repl.quiesce();
        assert!(t.elapsed() >= Duration::from_millis(30));
    }
}
