//! Asynchronous push replication between KV nodes (FReD peer protocol
//! substitute).
//!
//! A background sender thread drains a queue of updates and POSTs each one
//! to every subscribed peer over a shared [`PeerPool`] of keep-alive HTTP
//! connections on the peer replication port (stale sockets are replaced
//! transparently; the pool carries this sender's meter). An optional
//! artificial delay models replication lag (used by the consistency
//! ablation to force the Context Manager's retry path, which the paper
//! observed "never needs more than two retries").
//!
//! Two kinds of update travel through the queue (fields listed here in
//! spirit; the JSON serializer emits keys sorted):
//!
//! - **full-state** (`{kg, key, val, ver, ttl_ms}`): the seed protocol,
//!   byte-for-byte — the whole document every write;
//! - **delta** (`{op: "delta", kg, key, base, ver, frag, from, ttl_ms}`):
//!   only the turn's appended fragment, sent when `delta_sync` is on.
//!   Queued deltas for the same key **coalesce**: a delta whose base
//!   equals a queued delta's target version merges into it (fragments
//!   concatenated via [`crate::context::codec::concat_fragment_docs`]),
//!   so a burst of turns costs one push. The receiver applies a delta
//!   only when its local entry is exactly at `base`; otherwise it
//!   recovers via a full-state `/fetch` from `from` (see
//!   `kvstore::replication_endpoint`).
//!
//! With a [`HintedHandoff`] attached (cluster membership enabled), a push
//! to a peer the failure detector marks `Down` — or one that exhausts its
//! attempts during the detection window — is **parked** as a hint instead
//! of dropped, and replayed in order when the peer returns (see
//! [`Replicator::replay_hints`]). Without one, exhausted pushes drop as
//! in the seed; the drop counter is split by cause
//! (injected / exhausted / shutdown) with the combined total kept for
//! compatibility.

use std::collections::VecDeque;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar};
use std::time::Duration;

use super::antientropy::AeSink;
use super::lag::LagTracker;
use crate::cluster::{Hint, HintUpdate, HintedHandoff};
use crate::http::Request;
use crate::json::Value;
use crate::netsim::TrafficMeter;
use crate::sync::{classes, OrderedMutex};
use crate::transport::PeerPool;

/// Replication engine configuration.
#[derive(Debug, Clone)]
pub struct ReplicationConfig {
    /// Artificial delay before each push (models replication lag / FReD's
    /// async pipeline). Default: none.
    pub delay: Duration,
    /// Per-push connect/retry attempts before dropping the update.
    pub max_attempts: u32,
    /// Pause between attempts to the same peer, so a restarting peer gets
    /// a window to come back instead of all attempts burning in
    /// microseconds. Default: 2 ms.
    pub retry_backoff: Duration,
    /// Probability in [0,1] of dropping a push (failure injection).
    pub drop_probability: f64,
    /// Replicate context updates as append-only deltas instead of full
    /// state. Default **off**: the wire format stays byte-for-byte the
    /// seed protocol.
    pub delta_sync: bool,
}

impl Default for ReplicationConfig {
    fn default() -> ReplicationConfig {
        ReplicationConfig {
            delay: Duration::ZERO,
            max_attempts: 3,
            retry_backoff: Duration::from_millis(2),
            drop_probability: 0.0,
            delta_sync: false,
        }
    }
}

/// What a queued job carries to its peers.
#[derive(Debug)]
enum Update {
    /// Whole-document write (seed protocol).
    Full {
        /// Serialized document.
        value: String,
    },
    /// Append-only fragment on top of `base`.
    Delta {
        /// Version the receiver must hold for the delta to apply.
        base: u64,
        /// Self-describing fragment document (`context::codec`).
        frag: String,
        /// This node's replication listener, for the receiver's
        /// full-state fallback fetch.
        from: SocketAddr,
    },
}

#[derive(Debug)]
struct Job {
    peers: Vec<SocketAddr>,
    keygroup: String,
    key: String,
    update: Update,
    version: u64,
    ttl_ms: Option<u64>,
    /// How many pushes were folded into this job (1 + coalesced deltas);
    /// completing the job credits this many toward `done`.
    merged: u64,
    /// Trace context of the turn that enqueued this push, carried across
    /// the queue so the async sender's round trips stitch under the
    /// originating trace (None with observability off — and then no
    /// header ever reaches the wire).
    trace: Option<crate::obs::TraceCtx>,
}

impl Job {
    /// The job's payload for one peer, reshaped as a parkable hint.
    fn to_hint(&self) -> Hint {
        Hint {
            keygroup: self.keygroup.clone(),
            key: self.key.clone(),
            update: match &self.update {
                Update::Full { value } => HintUpdate::Full {
                    value: value.clone(),
                },
                Update::Delta { base, frag, from } => HintUpdate::Delta {
                    base: *base,
                    frag: frag.clone(),
                    from: *from,
                },
            },
            version: self.version,
            ttl_ms: self.ttl_ms,
        }
    }

    fn payload(&self) -> String {
        let mut v = Value::obj()
            .set("kg", self.keygroup.as_str())
            .set("key", self.key.as_str())
            .set("ver", self.version);
        match &self.update {
            Update::Full { value } => {
                v = v.set("val", value.as_str());
            }
            Update::Delta { base, frag, from } => {
                v = v
                    .set("op", "delta")
                    .set("base", *base)
                    .set("frag", frag.as_str())
                    .set("from", from.to_string());
            }
        }
        if let Some(ms) = self.ttl_ms {
            v = v.set("ttl_ms", ms);
        }
        v.to_json()
    }
}

/// Queue shared between `push()` and the sender thread.
struct Queue {
    jobs: VecDeque<Job>,
    /// False once `shutdown()` ran; late pushes are dropped (and counted)
    /// instead of queuing work nobody will ever drain — the fix for the
    /// quiesce()-spins-forever bug.
    open: bool,
}

/// Try to fold `job` into an already-queued delta for the same key and
/// peer set (newest first). Returns the job back when nothing matched.
fn coalesce_into(jobs: &mut VecDeque<Job>, job: Job) -> Option<Job> {
    let Update::Delta { base, frag, .. } = &job.update else {
        return Some(job);
    };
    for queued in jobs.iter_mut().rev() {
        if queued.keygroup != job.keygroup
            || queued.key != job.key
            || queued.peers != job.peers
        {
            continue;
        }
        let Update::Delta {
            frag: qfrag,
            ..
        } = &mut queued.update
        else {
            // A queued full-state write for this key is already newer or
            // will be superseded by LWW; don't merge across kinds.
            return Some(job);
        };
        if queued.version != *base {
            return Some(job);
        }
        match crate::context::codec::concat_fragment_docs(qfrag, frag) {
            Ok(merged) => {
                *qfrag = merged;
                queued.version = job.version;
                queued.ttl_ms = job.ttl_ms;
                queued.merged += job.merged;
                return None;
            }
            Err(_) => return Some(job),
        }
    }
    Some(job)
}

/// Handle to the background replication sender.
pub struct Replicator {
    queue: Arc<(OrderedMutex<Queue>, Condvar)>,
    thread: Option<std::thread::JoinHandle<()>>,
    meter: Arc<TrafficMeter>,
    queued: Arc<AtomicU64>,
    done: Arc<AtomicU64>,
    targets: Arc<AtomicU64>,
    /// Combined drop count (all causes), kept for compatibility with the
    /// pre-split counter. Always equals injected + exhausted + shutdown.
    pub dropped: Arc<AtomicU64>,
    /// Pushes dropped by failure injection (`drop_probability`).
    dropped_injected: Arc<AtomicU64>,
    /// Pushes dropped after exhausting connect/retry attempts (only
    /// without hinted handoff — with it they park instead).
    dropped_exhausted: Arc<AtomicU64>,
    /// Pushes dropped because they arrived after shutdown, or were still
    /// queued when the node was hard-killed.
    dropped_shutdown: Arc<AtomicU64>,
    /// Hard-stop flag: discard the queue instead of draining it.
    abort_flag: Arc<AtomicBool>,
    /// Hinted handoff for unreachable peers (None = seed drop behaviour).
    handoff: Option<Arc<HintedHandoff>>,
    /// Per-peer replication-lag bookkeeping (None = no tracking — the
    /// seed's zero-overhead path).
    lag: Option<Arc<LagTracker>>,
}

impl Replicator {
    /// Spawn the sender thread, pushing over `pool` (which carries the
    /// meter charged with this sender's outbound bytes). With a
    /// [`HintedHandoff`], pushes to down or unreachable peers are parked
    /// there instead of dropped. With an [`AeSink`], every exhausted
    /// drop is also reported to anti-entropy repair — the damage this
    /// sender can no longer fix is handed off instead of lost silently.
    /// With a [`LagTracker`], every addressed push records the peer's
    /// head and every 200 records its ack, so `/status` can report how
    /// far each replica is behind.
    pub fn start(
        name: String,
        config: ReplicationConfig,
        pool: PeerPool,
        handoff: Option<Arc<HintedHandoff>>,
        ae: Option<Arc<AeSink>>,
        lag: Option<Arc<LagTracker>>,
    ) -> Replicator {
        let queue = Arc::new((
            OrderedMutex::new(
                &classes::REPL_QUEUE,
                Queue {
                    jobs: VecDeque::new(),
                    open: true,
                },
            ),
            Condvar::new(),
        ));
        let meter = pool.meter().clone();
        let queued = Arc::new(AtomicU64::new(0));
        let done = Arc::new(AtomicU64::new(0));
        let dropped = Arc::new(AtomicU64::new(0));
        let dropped_injected = Arc::new(AtomicU64::new(0));
        let dropped_exhausted = Arc::new(AtomicU64::new(0));
        let dropped_shutdown = Arc::new(AtomicU64::new(0));
        let abort_flag = Arc::new(AtomicBool::new(false));
        let t_queue = queue.clone();
        let t_queued = queued.clone();
        let t_done = done.clone();
        let t_dropped = dropped.clone();
        let t_injected = dropped_injected.clone();
        let t_exhausted = dropped_exhausted.clone();
        let t_shutdown = dropped_shutdown.clone();
        let t_abort = abort_flag.clone();
        let t_handoff = handoff.clone();
        let t_ae = ae;
        let t_lag = lag.clone();
        let thread = std::thread::Builder::new()
            .name(format!("kv-repl-{name}"))
            .spawn(move || {
                // Seeded from the node-name hash so distinct names get
                // distinct injection streams (name.len() collides for
                // every same-length fleet name).
                let mut rng =
                    crate::testkit::Rng::new(0x5EED ^ crate::testkit::fnv1a(name.as_bytes()));
                loop {
                    let job = {
                        let (queue, cvar) = &*t_queue;
                        let mut q = queue.lock().unwrap();
                        loop {
                            if t_abort.load(Ordering::SeqCst) {
                                // Hard kill: whatever is still queued
                                // dies with the "process".
                                while let Some(j) = q.jobs.pop_front() {
                                    let n = j.peers.len().max(1) as u64;
                                    t_shutdown.fetch_add(n, Ordering::SeqCst);
                                    t_dropped.fetch_add(n, Ordering::SeqCst);
                                    t_done.fetch_add(j.merged, Ordering::SeqCst);
                                }
                                break None;
                            }
                            if let Some(j) = q.jobs.pop_front() {
                                break Some(j);
                            }
                            if !q.open {
                                break None;
                            }
                            q = q.wait(cvar).unwrap();
                        }
                    };
                    let Some(job) = job else { break };
                    // Re-adopt the enqueuing turn's trace context for the
                    // pushes below, so the pool injects its header.
                    let _trace = crate::obs::set_current(job.trace);
                    if !config.delay.is_zero() {
                        std::thread::sleep(config.delay);
                    }
                    let req = Request::post_json("/replicate", &job.payload());
                    let mut replay_to: Vec<SocketAddr> = Vec::new();
                    for peer in &job.peers {
                        // Whatever happens below — delivery, park, or
                        // drop — this version is now the peer's head
                        // for the key; only an ack moves it forward.
                        if let Some(l) = &t_lag {
                            l.record_head(*peer, &job.keygroup, &job.key, job.version);
                        }
                        if let Some(h) = &t_handoff {
                            // A peer the failure detector declared down:
                            // park immediately, no doomed attempts.
                            if h.is_down(*peer) {
                                h.park(*peer, job.to_hint());
                                continue;
                            }
                        }
                        if config.drop_probability > 0.0 && rng.chance(config.drop_probability) {
                            t_injected.fetch_add(1, Ordering::SeqCst);
                            t_dropped.fetch_add(1, Ordering::SeqCst);
                            continue;
                        }
                        let mut ok = false;
                        for attempt in 0..config.max_attempts {
                            if attempt > 0 && !config.retry_backoff.is_zero() {
                                std::thread::sleep(config.retry_backoff);
                            }
                            // One pooled round trip per attempt: reuse
                            // the peer's keep-alive connection, with a
                            // stale socket transparently replaced by a
                            // fresh connect inside the pool.
                            if let Ok(resp) = pool.round_trip(*peer, &req) {
                                if resp.status == 200 {
                                    ok = true;
                                    break;
                                }
                            }
                        }
                        if ok {
                            if let Some(l) = &t_lag {
                                l.record_ack(*peer, &job.keygroup, &job.key, job.version);
                            }
                            // The peer answered: if older hints are still
                            // parked for it (it died and came back before
                            // the detector noticed), requeue them now.
                            if let Some(h) = &t_handoff {
                                if !h.is_down(*peer) && h.has_hints(*peer) {
                                    replay_to.push(*peer);
                                }
                            }
                        } else if let Some(h) = &t_handoff {
                            // Unreachable but not (yet) declared down —
                            // the detection window. Park, don't drop.
                            h.park(*peer, job.to_hint());
                            // If the peer restarted elsewhere while this
                            // push was burning attempts, the rejoin
                            // replay has already run — requeue the
                            // forwarded queue so this park cannot
                            // strand. (A forward is the restart signal;
                            // same-address parks wait for the detector,
                            // avoiding a retry hot-loop against a peer
                            // that is simply still dead.)
                            let current = h.resolve_addr(*peer);
                            if current != *peer && !h.is_down(current) {
                                replay_to.push(current);
                            }
                        } else {
                            t_exhausted.fetch_add(1, Ordering::SeqCst);
                            t_dropped.fetch_add(1, Ordering::SeqCst);
                            // Without hints this update is gone for good
                            // as far as the push path is concerned — hand
                            // the damage to anti-entropy repair.
                            if let Some(sink) = &t_ae {
                                sink.note_lost(*peer, &job.keygroup, &job.key);
                            }
                        }
                    }
                    t_done.fetch_add(job.merged, Ordering::SeqCst);
                    if let Some(h) = &t_handoff {
                        for peer in replay_to {
                            requeue_hints(
                                &t_queue, &t_queued, &t_dropped, &t_shutdown, h, peer, peer,
                            );
                        }
                    }
                }
            })
            .expect("spawn replicator");
        Replicator {
            queue,
            thread: Some(thread),
            meter,
            queued,
            done,
            targets: Arc::new(AtomicU64::new(0)),
            dropped,
            dropped_injected,
            dropped_exhausted,
            dropped_shutdown,
            abort_flag,
            handoff,
            lag,
        }
    }

    /// Enqueue a full-state write for async push to `peers`.
    pub fn push(
        &self,
        peers: Vec<SocketAddr>,
        keygroup: &str,
        key: &str,
        value: &str,
        version: u64,
        ttl: Option<Duration>,
    ) {
        self.enqueue(Job {
            peers,
            keygroup: keygroup.to_string(),
            key: key.to_string(),
            update: Update::Full {
                value: value.to_string(),
            },
            version,
            ttl_ms: ttl.map(|t| t.as_millis() as u64),
            merged: 1,
            trace: crate::obs::current(),
        });
    }

    /// Enqueue a delta (fragment appended on top of `base`, producing
    /// `version`). `from` is this node's replication listener, used by a
    /// receiver that cannot apply the delta to fetch full state.
    #[allow(clippy::too_many_arguments)]
    pub fn push_delta(
        &self,
        peers: Vec<SocketAddr>,
        keygroup: &str,
        key: &str,
        frag_doc: &str,
        base: u64,
        version: u64,
        ttl: Option<Duration>,
        from: SocketAddr,
    ) {
        self.enqueue(Job {
            peers,
            keygroup: keygroup.to_string(),
            key: key.to_string(),
            update: Update::Delta {
                base,
                frag: frag_doc.to_string(),
                from,
            },
            version,
            ttl_ms: ttl.map(|t| t.as_millis() as u64),
            merged: 1,
            trace: crate::obs::current(),
        });
    }

    fn enqueue(&self, job: Job) {
        let n_targets = job.peers.len() as u64;
        let (queue, cvar) = &*self.queue;
        let mut q = queue.lock().unwrap();
        if !q.open {
            // Late push after shutdown: nobody will ever drain it. Count a
            // drop per addressed peer and bail out so quiesce() cannot
            // spin on a queued-but-never-done update.
            drop(q);
            self.dropped_shutdown
                .fetch_add(n_targets.max(1), Ordering::SeqCst);
            self.dropped.fetch_add(n_targets.max(1), Ordering::SeqCst);
            return;
        }
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.targets.fetch_add(n_targets, Ordering::SeqCst);
        // A push folded into a queued delta needs no new job: the merged
        // job's `merged` count credits `done` for it on completion.
        if let Some(job) = coalesce_into(&mut q.jobs, job) {
            q.jobs.push_back(job);
        }
        cvar.notify_one();
    }

    /// Bytes moved by this node's outbound replication.
    pub fn meter(&self) -> &Arc<TrafficMeter> {
        &self.meter
    }

    /// Total per-peer push targets enqueued: each write counts once per
    /// replica it is addressed to (even when later coalesced into another
    /// queued delta). With ring placement this is exactly
    /// `|preference list \ {writer}|` per write; with replicate-to-all it
    /// is the keygroup's subscriber count.
    pub fn push_targets(&self) -> u64 {
        self.targets.load(Ordering::SeqCst)
    }

    /// Pushes dropped by failure injection.
    pub fn dropped_injected(&self) -> u64 {
        self.dropped_injected.load(Ordering::SeqCst)
    }

    /// Pushes dropped after exhausting all attempts (hint-less mode).
    pub fn dropped_exhausted(&self) -> u64 {
        self.dropped_exhausted.load(Ordering::SeqCst)
    }

    /// Pushes dropped at or after shutdown (late pushes + aborted queue).
    pub fn dropped_shutdown(&self) -> u64 {
        self.dropped_shutdown.load(Ordering::SeqCst)
    }

    /// Combined drop count across all causes.
    pub fn dropped_total(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }

    /// Re-enqueue every hint parked for `parked_at`, in park order, ahead
    /// of the queue, addressed to `deliver_to` (differs from `parked_at`
    /// when the peer restarted on a new port). Called by the cluster
    /// coordinator when the failure detector reports the peer up.
    pub fn replay_hints(&self, parked_at: SocketAddr, deliver_to: SocketAddr) {
        if let Some(h) = &self.handoff {
            // The peer moved: its lag records must follow, or the old
            // address would read as lagging forever while the acks land
            // on the new one.
            if let Some(l) = &self.lag {
                l.forward(parked_at, deliver_to);
            }
            requeue_hints(
                &self.queue,
                &self.queued,
                &self.dropped,
                &self.dropped_shutdown,
                h,
                parked_at,
                deliver_to,
            );
        }
    }

    /// Block until every queued push has been processed.
    pub fn quiesce(&self) {
        while self.done.load(Ordering::SeqCst) < self.queued.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Hard stop (node-kill emulation): close the queue and discard
    /// whatever is still in it (counted as shutdown drops) instead of
    /// draining. Callable through a shared reference; the thread is
    /// joined later by `shutdown()`/`Drop`.
    pub fn abort(&self) {
        self.abort_flag.store(true, Ordering::SeqCst);
        let (queue, cvar) = &*self.queue;
        {
            let mut q = queue.lock().unwrap();
            q.open = false;
        }
        cvar.notify_all();
    }

    /// Stop the sender thread (drains remaining queue first).
    pub fn shutdown(&mut self) {
        {
            let (queue, cvar) = &*self.queue;
            let mut q = queue.lock().unwrap();
            q.open = false;
            cvar.notify_all();
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Move `parked_at`'s hints back into the job queue (front, in order) as
/// single-peer jobs addressed to `deliver_to`. Hints arriving after the
/// queue closed are accounted as shutdown drops — they can never be
/// delivered by this sender again.
fn requeue_hints(
    queue: &Arc<(OrderedMutex<Queue>, Condvar)>,
    queued: &Arc<AtomicU64>,
    dropped: &Arc<AtomicU64>,
    dropped_shutdown: &Arc<AtomicU64>,
    handoff: &HintedHandoff,
    parked_at: SocketAddr,
    deliver_to: SocketAddr,
) {
    let hints = handoff.take(parked_at);
    if hints.is_empty() {
        return;
    }
    let (queue, cvar) = &**queue;
    let mut q = queue.lock().unwrap();
    if !q.open {
        let n = hints.len() as u64;
        dropped_shutdown.fetch_add(n, Ordering::SeqCst);
        dropped.fetch_add(n, Ordering::SeqCst);
        return;
    }
    for (i, hint) in hints.into_iter().enumerate() {
        queued.fetch_add(1, Ordering::SeqCst);
        let job = Job {
            peers: vec![deliver_to],
            keygroup: hint.keygroup,
            key: hint.key,
            update: match hint.update {
                HintUpdate::Full { value } => Update::Full { value },
                HintUpdate::Delta { base, frag, from } => Update::Delta { base, frag, from },
            },
            version: hint.version,
            ttl_ms: hint.ttl_ms,
            merged: 1,
            trace: None,
        };
        q.jobs.insert(i, job);
    }
    cvar.notify_all();
}

impl Drop for Replicator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::{StoredContext, TokenCodec};
    use crate::http::{Response, Server};
    use crate::netsim::LinkModel;
    use std::sync::Mutex;

    /// Fresh pool over an ideal link (each test sender gets its own
    /// meter, exactly as each seed sender had).
    fn ideal_pool() -> PeerPool {
        PeerPool::new(TrafficMeter::new(), LinkModel::ideal())
    }

    #[test]
    fn pushes_reach_peer() {
        let received = Arc::new(Mutex::new(Vec::<String>::new()));
        let r2 = received.clone();
        let server = Server::serve(
            0,
            LinkModel::ideal(),
            Arc::new(move |req: &Request| {
                r2.lock().unwrap().push(req.body_str().unwrap().to_string());
                Response::json("{\"applied\":true}")
            }),
        )
        .unwrap();
        let repl = Replicator::start(
            "t".into(),
            ReplicationConfig::default(),
            ideal_pool(),
            None,
            None,
            None,
        );
        repl.push(vec![server.addr], "kg", "k", "v", 1, None);
        repl.quiesce();
        let msgs = received.lock().unwrap();
        assert_eq!(msgs.len(), 1);
        assert!(msgs[0].contains("\"ver\":1"));
        assert!(repl.meter().tx.get() > 0);
        assert_eq!(repl.push_targets(), 1);
    }

    #[test]
    fn full_payload_matches_seed_wire_format() {
        // Default mode must stay byte-for-byte the seed protocol.
        let job = Job {
            peers: vec![],
            keygroup: "kg".into(),
            key: "k".into(),
            update: Update::Full { value: "v".into() },
            version: 3,
            ttl_ms: Some(1500),
            merged: 1,
            trace: None,
        };
        // Value::Object serializes keys sorted ("key" < "kg").
        assert_eq!(
            job.payload(),
            r#"{"key":"k","kg":"kg","ttl_ms":1500,"val":"v","ver":3}"#
        );
    }

    #[test]
    fn drop_injection_counts() {
        let cfg = ReplicationConfig {
            drop_probability: 1.0,
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start("t".into(), cfg, ideal_pool(), None, None, None);
        // Peer doesn't even need to exist: drop happens first.
        repl.push(vec!["127.0.0.1:1".parse().unwrap()], "kg", "k", "v", 1, None);
        repl.quiesce();
        assert_eq!(repl.dropped.load(Ordering::SeqCst), 1);
        // The split counters attribute the precise cause.
        assert_eq!(repl.dropped_injected(), 1);
        assert_eq!(repl.dropped_exhausted(), 0);
        assert_eq!(repl.dropped_shutdown(), 0);
    }

    #[test]
    fn unreachable_peer_drops_after_attempts() {
        let cfg = ReplicationConfig {
            max_attempts: 2,
            retry_backoff: Duration::ZERO,
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start("t".into(), cfg, ideal_pool(), None, None, None);
        repl.push(vec!["127.0.0.1:1".parse().unwrap()], "kg", "k", "v", 1, None);
        repl.quiesce();
        assert_eq!(repl.dropped.load(Ordering::SeqCst), 1);
        assert_eq!(repl.dropped_exhausted(), 1);
        assert_eq!(repl.dropped_injected(), 0);
        assert_eq!(repl.dropped_shutdown(), 0);
    }

    #[test]
    fn retries_are_backed_off() {
        // Regression: a failed connect used to consume an attempt with
        // zero backoff, burning all attempts in microseconds.
        let cfg = ReplicationConfig {
            max_attempts: 3,
            retry_backoff: Duration::from_millis(20),
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start("t".into(), cfg, ideal_pool(), None, None, None);
        let t = std::time::Instant::now();
        repl.push(vec!["127.0.0.1:1".parse().unwrap()], "kg", "k", "v", 1, None);
        repl.quiesce();
        // Two inter-attempt pauses for three attempts.
        assert!(t.elapsed() >= Duration::from_millis(40), "{:?}", t.elapsed());
        assert_eq!(repl.dropped.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn push_after_shutdown_drops_instead_of_deadlocking() {
        // Regression: `push()` used to increment `queued` before noticing
        // the closed channel, so a late push made quiesce() spin forever.
        let mut repl = Replicator::start(
            "t".into(),
            ReplicationConfig::default(),
            ideal_pool(),
            None,
            None,
            None,
        );
        repl.shutdown();
        repl.push(vec!["127.0.0.1:1".parse().unwrap()], "kg", "k", "v", 1, None);
        repl.quiesce(); // must return immediately
        assert_eq!(repl.dropped.load(Ordering::SeqCst), 1);
        assert_eq!(repl.dropped_shutdown(), 1);
        assert_eq!(repl.dropped_exhausted(), 0);
        assert_eq!(repl.push_targets(), 0, "dropped push is not a target");
    }

    #[test]
    fn abort_discards_queue_as_shutdown_drops() {
        // A hard kill must not drain queued pushes to peers — they die
        // with the "process" and are attributed to the shutdown cause.
        let cfg = ReplicationConfig {
            // Slow first job keeps the rest queued while we abort.
            delay: Duration::from_millis(50),
            max_attempts: 1,
            retry_backoff: Duration::ZERO,
            ..ReplicationConfig::default()
        };
        let mut repl = Replicator::start("t".into(), cfg, ideal_pool(), None, None, None);
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        for i in 0..3 {
            repl.push(vec![dead], "kg", &format!("k{i}"), "v", 1, None);
        }
        repl.abort();
        repl.shutdown();
        repl.quiesce(); // all jobs accounted for despite the discard
        assert_eq!(
            repl.dropped_shutdown() + repl.dropped_exhausted(),
            3,
            "every queued push must be accounted to a drop cause"
        );
        assert!(repl.dropped_shutdown() >= 2, "queued jobs discarded on abort");
    }

    #[test]
    fn exhausted_push_parks_as_hint_instead_of_dropping() {
        use crate::cluster::{HintConfig, HintedHandoff};
        let handoff = HintedHandoff::new(HintConfig::default());
        let cfg = ReplicationConfig {
            max_attempts: 2,
            retry_backoff: Duration::ZERO,
            ..ReplicationConfig::default()
        };
        let repl =
            Replicator::start("t".into(), cfg, ideal_pool(), Some(handoff.clone()), None, None);
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        repl.push(vec![dead], "kg", "k", "v", 3, None);
        repl.quiesce();
        assert_eq!(repl.dropped.load(Ordering::SeqCst), 0, "hinted, not dropped");
        assert_eq!(handoff.queued(), 1);
        assert_eq!(handoff.len(dead), 1);
    }

    #[test]
    fn lag_is_recorded_on_park_and_cleared_by_replay() {
        use super::super::lag::LagTracker;
        use crate::cluster::{HintConfig, HintedHandoff};
        let handoff = HintedHandoff::new(HintConfig::default());
        let lag = LagTracker::new();
        let cfg = ReplicationConfig {
            max_attempts: 1,
            retry_backoff: Duration::ZERO,
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start(
            "t".into(),
            cfg,
            ideal_pool(),
            Some(handoff.clone()),
            None,
            Some(lag.clone()),
        );
        // Unreachable peer: the push parks and the key reads as lagging.
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        repl.push(vec![dead], "kg", "k", "v", 3, None);
        repl.quiesce();
        assert_eq!(lag.lag_keys(), 1);
        assert!(lag.max_lag_versions() >= 1);
        // The peer "restarts" on a live address: replay delivers the
        // parked hint, the ack clears the lag.
        let server = Server::serve(
            0,
            LinkModel::ideal(),
            Arc::new(|_req: &Request| Response::json("{\"applied\":true}")),
        )
        .unwrap();
        repl.replay_hints(dead, server.addr);
        repl.quiesce();
        assert_eq!(lag.lag_keys(), 0, "delivered + acked => caught up");
        assert_eq!(lag.max_lag_versions(), 0);
    }

    #[test]
    fn down_peer_parks_without_attempting() {
        use crate::cluster::{HintConfig, HintedHandoff};
        let handoff = HintedHandoff::new(HintConfig::default());
        let dead: SocketAddr = "127.0.0.1:1".parse().unwrap();
        handoff.set_down(dead);
        let cfg = ReplicationConfig {
            // Would take ≥ 200 ms if the sender attempted + backed off.
            max_attempts: 100,
            retry_backoff: Duration::from_millis(2),
            ..ReplicationConfig::default()
        };
        let repl =
            Replicator::start("t".into(), cfg, ideal_pool(), Some(handoff.clone()), None, None);
        let t = std::time::Instant::now();
        repl.push(vec![dead], "kg", "k", "v", 1, None);
        repl.quiesce();
        assert!(t.elapsed() < Duration::from_millis(100), "{:?}", t.elapsed());
        assert_eq!(handoff.len(dead), 1);
    }

    #[test]
    fn replay_hints_delivers_in_order_to_the_new_address() {
        use crate::cluster::{Hint, HintConfig, HintUpdate, HintedHandoff};
        let received = Arc::new(Mutex::new(Vec::<String>::new()));
        let r2 = received.clone();
        let server = Server::serve(
            0,
            LinkModel::ideal(),
            Arc::new(move |req: &Request| {
                r2.lock().unwrap().push(req.body_str().unwrap().to_string());
                Response::json("{\"applied\":true}")
            }),
        )
        .unwrap();
        let handoff = HintedHandoff::new(HintConfig::default());
        // Hints were parked for the peer's *old* (now dead) address.
        let old: SocketAddr = "127.0.0.1:1".parse().unwrap();
        for v in 1..=3u64 {
            handoff.park(
                old,
                Hint {
                    keygroup: "kg".into(),
                    key: format!("s{v}"),
                    update: HintUpdate::Full {
                        value: format!("v{v}"),
                    },
                    version: v,
                    ttl_ms: None,
                },
            );
        }
        let repl = Replicator::start(
            "t".into(),
            ReplicationConfig::default(),
            ideal_pool(),
            Some(handoff.clone()),
            None,
            None,
        );
        repl.replay_hints(old, server.addr);
        repl.quiesce();
        let msgs = received.lock().unwrap();
        assert_eq!(msgs.len(), 3);
        for (i, m) in msgs.iter().enumerate() {
            assert!(
                m.contains(&format!("\"key\":\"s{}\"", i + 1)),
                "replay out of order: {m}"
            );
        }
        assert_eq!(handoff.replayed(), 3);
        assert!(!handoff.has_hints(old));
    }

    #[test]
    fn delay_is_applied() {
        let server = Server::serve(
            0,
            LinkModel::ideal(),
            Arc::new(|_req: &Request| Response::json("{\"applied\":true}")),
        )
        .unwrap();
        let cfg = ReplicationConfig {
            delay: Duration::from_millis(30),
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start("t".into(), cfg, ideal_pool(), None, None, None);
        let t = std::time::Instant::now();
        repl.push(vec![server.addr], "kg", "k", "v", 1, None);
        repl.quiesce();
        assert!(t.elapsed() >= Duration::from_millis(30));
    }

    fn delta_job(peers: Vec<SocketAddr>, base: u64, ver: u64, ids: Vec<u32>) -> Job {
        Job {
            peers,
            keygroup: "kg".into(),
            key: "k".into(),
            update: Update::Delta {
                base,
                frag: StoredContext::Tokens(ids).to_fragment(TokenCodec::BinaryU16),
                from: "127.0.0.1:9".parse().unwrap(),
            },
            version: ver,
            ttl_ms: None,
            merged: 1,
            trace: None,
        }
    }

    #[test]
    fn contiguous_queued_deltas_coalesce() {
        let peers: Vec<SocketAddr> = vec!["127.0.0.1:1".parse().unwrap()];
        let mut jobs = VecDeque::new();
        jobs.push_back(delta_job(peers.clone(), 1, 2, vec![10]));
        // base 2 continues the queued target version 2 -> merge.
        assert!(coalesce_into(&mut jobs, delta_job(peers.clone(), 2, 3, vec![11])).is_none());
        assert_eq!(jobs.len(), 1);
        let j = &jobs[0];
        assert_eq!(j.version, 3);
        assert_eq!(j.merged, 2);
        let Update::Delta { base, frag, .. } = &j.update else {
            panic!("expected delta")
        };
        assert_eq!(*base, 1);
        assert_eq!(
            StoredContext::from_fragment(frag).unwrap(),
            StoredContext::Tokens(vec![10, 11])
        );
        // Gap (base 5 on target 3) must NOT merge.
        let back = coalesce_into(&mut jobs, delta_job(peers.clone(), 5, 6, vec![12]));
        assert!(back.is_some());
        // Different key must not merge either.
        let mut other = delta_job(peers.clone(), 3, 4, vec![13]);
        other.key = "other".into();
        assert!(coalesce_into(&mut jobs, other).is_some());
        // Different peer set must not merge.
        let two: Vec<SocketAddr> = vec!["127.0.0.1:2".parse().unwrap()];
        assert!(coalesce_into(&mut jobs, delta_job(two, 3, 4, vec![14])).is_some());
    }

    #[test]
    fn coalesced_deltas_count_toward_quiesce() {
        // End-to-end: a burst of contiguous deltas behind a slow first job
        // must fully drain (done catches up with queued even when merged).
        let received = Arc::new(Mutex::new(Vec::<String>::new()));
        let r2 = received.clone();
        let server = Server::serve(
            0,
            LinkModel::ideal(),
            Arc::new(move |req: &Request| {
                r2.lock().unwrap().push(req.body_str().unwrap().to_string());
                Response::json("{\"applied\":true}")
            }),
        )
        .unwrap();
        let cfg = ReplicationConfig {
            delay: Duration::from_millis(40),
            ..ReplicationConfig::default()
        };
        let repl = Replicator::start("t".into(), cfg, ideal_pool(), None, None, None);
        let frag = |id: u32| StoredContext::Tokens(vec![id]).to_fragment(TokenCodec::BinaryU16);
        let from: SocketAddr = "127.0.0.1:9".parse().unwrap();
        repl.push(vec![server.addr], "kg", "k", "v1", 1, None);
        repl.push_delta(vec![server.addr], "kg", "k", &frag(10), 1, 2, None, from);
        repl.push_delta(vec![server.addr], "kg", "k", &frag(11), 2, 3, None, from);
        repl.quiesce();
        let msgs = received.lock().unwrap();
        // At least the full write arrived; the two deltas arrived either
        // merged (2 messages total) or separate (3) depending on timing.
        assert!(msgs.len() >= 2 && msgs.len() <= 3, "{}", msgs.len());
        assert!(msgs.last().unwrap().contains("\"ver\":3"));
        assert_eq!(repl.push_targets(), 3);
    }
}
