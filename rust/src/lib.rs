//! # DisCEdge — Distributed Context Management for LLMs at the Edge
//!
//! A from-scratch reproduction of *DisCEdge* (Malekabbasi, Wang, Bermbach;
//! CS.DC 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: a per-edge-node
//!   [`context::ContextManager`] that stores session context *pre-tokenized*,
//!   a FReD-like geo-distributed [`kvstore`] with keygroups, asynchronous
//!   peer replication, and consistent-hash session sharding
//!   ([`kvstore::HashRing`]) with a bounded replication factor, an [`llm`]
//!   service that accepts pre-tokenized context, and an HTTP [`server`] /
//!   [`client`] pair implementing the paper's extended `/completion` API
//!   with a client-driven turn-counter consistency protocol. The
//!   [`cluster`] module adds runtime membership: heartbeat failure
//!   detection, epoch-versioned placement swaps, and hinted handoff for
//!   writes addressed to down replicas. All node-to-node plumbing rides
//!   the [`transport`] layer: pooled keep-alive peer connections
//!   ([`transport::PeerPool`]) and a bounded inbound listener budget.
//! - **Layer 2 (build time, `python/compile/model.py`)** — a Qwen-style
//!   decoder-only transformer in JAX, AOT-lowered to HLO text.
//! - **Layer 1 (build time, `python/compile/kernels/`)** — Pallas attention
//!   kernels (flash prefill + cached decode) called from the L2 graph.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT; Python never
//! runs on the request path.
//!
//! `README.md` covers the quickstart and the benchmark suite;
//! `docs/ARCHITECTURE.md` walks the request path and the replication path
//! (including ring placement) end to end.

pub mod analysis;
pub mod benchkit;
pub mod cli;
pub mod client;
pub mod cluster;
pub mod config;
pub mod context;
pub mod http;
pub mod json;
pub mod kvstore;
pub mod llm;
pub mod metrics;
pub mod netsim;
pub mod obs;
pub mod profile;
pub mod runtime;
pub mod server;
pub mod sync;
pub mod testkit;
pub mod tokenizer;
pub mod transport;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type. Hand-rolled `Display`/`Error` impls keep the
/// default build free of external dependencies (no proc-macro crates in
/// the offline registry).
#[derive(Debug)]
pub enum Error {
    /// I/O failure (sockets, files).
    Io(std::io::Error),
    /// JSON parse/encode failure.
    Json(String),
    /// HTTP protocol violation.
    Http(String),
    /// Tokenizer failure (unknown id, bad vocab file...).
    Tokenizer(String),
    /// KV store failure.
    KvStore(String),
    /// Consistency protocol gave up (stale context after retries).
    Consistency(String),
    /// Context manager / session failure.
    Context(String),
    /// Inference engine failure.
    Engine(String),
    /// XLA/PJRT runtime failure.
    Runtime(String),
    /// Configuration error.
    Config(String),
    /// Invalid client request.
    BadRequest(String),
    /// Node is temporarily over capacity (admission queue full) — maps
    /// to HTTP 503; the client may retry.
    Unavailable(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(m) => write!(f, "json: {m}"),
            Error::Http(m) => write!(f, "http: {m}"),
            Error::Tokenizer(m) => write!(f, "tokenizer: {m}"),
            Error::KvStore(m) => write!(f, "kvstore: {m}"),
            Error::Consistency(m) => write!(f, "consistency: {m}"),
            Error::Context(m) => write!(f, "context: {m}"),
            Error::Engine(m) => write!(f, "engine: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::BadRequest(m) => write!(f, "bad request: {m}"),
            Error::Unavailable(m) => write!(f, "unavailable: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}
