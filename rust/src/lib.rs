//! # DisCEdge — Distributed Context Management for LLMs at the Edge
//!
//! A from-scratch reproduction of *DisCEdge* (Malekabbasi, Wang, Bermbach;
//! CS.DC 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: a per-edge-node
//!   [`context::ContextManager`] that stores session context *pre-tokenized*,
//!   a FReD-like geo-distributed [`kvstore`] with keygroups, asynchronous
//!   peer replication, and consistent-hash session sharding
//!   ([`kvstore::HashRing`]) with a bounded replication factor, an [`llm`]
//!   service that accepts pre-tokenized context, and an HTTP [`server`] /
//!   [`client`] pair implementing the paper's extended `/completion` API
//!   with a client-driven turn-counter consistency protocol.
//! - **Layer 2 (build time, `python/compile/model.py`)** — a Qwen-style
//!   decoder-only transformer in JAX, AOT-lowered to HLO text.
//! - **Layer 1 (build time, `python/compile/kernels/`)** — Pallas attention
//!   kernels (flash prefill + cached decode) called from the L2 graph.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT; Python never
//! runs on the request path.
//!
//! `README.md` covers the quickstart and the benchmark suite;
//! `docs/ARCHITECTURE.md` walks the request path and the replication path
//! (including ring placement) end to end.

pub mod benchkit;
pub mod cli;
pub mod client;
pub mod config;
pub mod context;
pub mod http;
pub mod json;
pub mod kvstore;
pub mod llm;
pub mod metrics;
pub mod netsim;
pub mod profile;
pub mod runtime;
pub mod server;
pub mod testkit;
pub mod tokenizer;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// I/O failure (sockets, files).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// JSON parse/encode failure.
    #[error("json: {0}")]
    Json(String),
    /// HTTP protocol violation.
    #[error("http: {0}")]
    Http(String),
    /// Tokenizer failure (unknown id, bad vocab file...).
    #[error("tokenizer: {0}")]
    Tokenizer(String),
    /// KV store failure.
    #[error("kvstore: {0}")]
    KvStore(String),
    /// Consistency protocol gave up (stale context after retries).
    #[error("consistency: {0}")]
    Consistency(String),
    /// Context manager / session failure.
    #[error("context: {0}")]
    Context(String),
    /// Inference engine failure.
    #[error("engine: {0}")]
    Engine(String),
    /// XLA/PJRT runtime failure.
    #[error("runtime: {0}")]
    Runtime(String),
    /// Configuration error.
    #[error("config: {0}")]
    Config(String),
    /// Invalid client request.
    #[error("bad request: {0}")]
    BadRequest(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}
