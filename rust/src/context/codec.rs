//! Stored-context representations and their KV serialization.
//!
//! The paper stores context either as **raw text** or as **token ids**
//! (DisCEdge). Token ids go on the wire as a JSON int array — which is why
//! the paper's sync savings are a modest 13–15 %: JSON ints cost ~5–6
//! bytes/token vs ~4–6 bytes/token for text. A denser base64(u16-LE)
//! framing is implemented as well and evaluated in ablation A1 (the paper
//! leaves this optimization on the table).
//!
//! **Delta fragments.** Session context is append-only per turn, so the
//! replication layer can ship just the turn's new fragment instead of the
//! whole document (`delta_sync`, see `kvstore`). A fragment is framed
//! exactly like a stored document ([`StoredContext::to_fragment`]), and
//! [`append_to_doc`] / [`concat_fragment_docs`] are the merge operations
//! the KV store applies on receive / the replicator uses to coalesce
//! queued deltas. The merged result is byte-for-byte identical to what a
//! full-state write of the same history would have stored.

use crate::json::{self, Value};
use crate::{Error, Result};

/// How token ids are framed inside the stored JSON document.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenCodec {
    /// JSON array of integers (paper-faithful).
    JsonInts,
    /// base64-encoded little-endian u16 ids (ablation A1).
    BinaryU16,
}

/// A session context as stored in the KV store.
#[derive(Debug, Clone, PartialEq)]
pub enum StoredContext {
    /// Pre-tokenized history (DisCEdge mode).
    Tokens(Vec<u32>),
    /// Raw ChatML transcript text (baseline mode).
    Text(String),
}

impl StoredContext {
    /// Serialize to the KV document. `turns` is the version stamp kept in
    /// the document for debuggability (the KV entry version is
    /// authoritative).
    pub fn to_kv(&self, turns: u64, codec: TokenCodec) -> String {
        match self {
            StoredContext::Tokens(ids) => match codec {
                TokenCodec::JsonInts => Value::obj()
                    .set("fmt", "tok")
                    .set("turns", turns)
                    .set("ids", ids.clone())
                    .to_json(),
                TokenCodec::BinaryU16 => Value::obj()
                    .set("fmt", "tokb")
                    .set("turns", turns)
                    .set("ids", base64_encode(&ids_to_u16_le(ids)))
                    .to_json(),
            },
            StoredContext::Text(text) => Value::obj()
                .set("fmt", "raw")
                .set("turns", turns)
                .set("text", text.as_str())
                .to_json(),
        }
    }

    /// Parse back from the KV document.
    pub fn from_kv(doc: &str) -> Result<(StoredContext, u64)> {
        let (ctx, turns, _) = decode_doc(&json::parse(doc)?)?;
        Ok((ctx, turns))
    }

    /// Length in tokens (tokens) or bytes (text) — for metrics.
    pub fn size_units(&self) -> usize {
        match self {
            StoredContext::Tokens(ids) => ids.len(),
            StoredContext::Text(t) => t.len(),
        }
    }

    /// Serialize an append-only **delta fragment** (the new tokens / text
    /// of one turn). Same framing as [`Self::to_kv`] so a fragment is
    /// self-describing; its `turns` field is 0 (the KV delta record
    /// carries the authoritative base/target versions).
    pub fn to_fragment(&self, codec: TokenCodec) -> String {
        self.to_kv(0, codec)
    }

    /// Parse a delta fragment produced by [`Self::to_fragment`].
    pub fn from_fragment(doc: &str) -> Result<StoredContext> {
        Ok(StoredContext::from_kv(doc)?.0)
    }
}

/// Decode a parsed document into its context, `turns` stamp, and the
/// codec it was framed with (one parse serves all three — the delta apply
/// path runs this on O(history)-sized documents every turn). Raw-text
/// docs report `JsonInts`; the codec only matters for token payloads.
fn decode_doc(v: &json::Value) -> Result<(StoredContext, u64, TokenCodec)> {
    let turns = v.req_u64("turns")?;
    let fmt = v.req_str("fmt")?;
    let (ctx, codec) = match fmt.as_str() {
        "tok" => {
            let ids = v
                .get("ids")
                .and_then(|i| i.as_int_array())
                .ok_or_else(|| Error::Context("tok doc missing ids".into()))?;
            (StoredContext::Tokens(ids), TokenCodec::JsonInts)
        }
        "tokb" => {
            let b64 = v.req_str("ids")?;
            let bytes =
                base64_decode(&b64).ok_or_else(|| Error::Context("bad base64 ids".into()))?;
            (
                StoredContext::Tokens(u16_le_to_ids(&bytes)?),
                TokenCodec::BinaryU16,
            )
        }
        "raw" => (StoredContext::Text(v.req_str("text")?), TokenCodec::JsonInts),
        other => return Err(Error::Context(format!("unknown context fmt {other}"))),
    };
    Ok((ctx, turns, codec))
}

/// Append a delta fragment to a stored context document, producing the
/// document a full-state write of the same history would have produced
/// (same codec as the base document, `turns` advanced to `new_turns`).
///
/// Fails when the fragment's mode (tokens vs text) does not match the
/// base document — the caller falls back to full-state transfer.
pub fn append_to_doc(base_doc: &str, frag_doc: &str, new_turns: u64) -> Result<String> {
    let (base, _, codec) = decode_doc(&json::parse(base_doc)?)?;
    let frag = StoredContext::from_fragment(frag_doc)?;
    match (base, frag) {
        (StoredContext::Tokens(mut ids), StoredContext::Tokens(f)) => {
            ids.extend_from_slice(&f);
            Ok(StoredContext::Tokens(ids).to_kv(new_turns, codec))
        }
        (StoredContext::Text(mut t), StoredContext::Text(f)) => {
            t.push_str(&f);
            Ok(StoredContext::Text(t).to_kv(new_turns, codec))
        }
        _ => Err(Error::Context("delta fragment mode mismatch".into())),
    }
}

/// Concatenate two delta fragments (the replicator's per-key coalescing:
/// turn `n`'s fragment followed by turn `n+1`'s collapses into one delta
/// covering both turns). Keeps the first fragment's codec.
pub fn concat_fragment_docs(a: &str, b: &str) -> Result<String> {
    let (a_ctx, _, codec) = decode_doc(&json::parse(a)?)?;
    match (a_ctx, StoredContext::from_fragment(b)?) {
        (StoredContext::Tokens(mut x), StoredContext::Tokens(y)) => {
            x.extend_from_slice(&y);
            Ok(StoredContext::Tokens(x).to_fragment(codec))
        }
        (StoredContext::Text(mut x), StoredContext::Text(y)) => {
            x.push_str(&y);
            Ok(StoredContext::Text(x).to_fragment(codec))
        }
        _ => Err(Error::Context("cannot coalesce fragments of mixed modes".into())),
    }
}

fn ids_to_u16_le(ids: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(ids.len() * 2);
    for &id in ids {
        // Vocab is < 65536 by construction; saturate defensively.
        let v = id.min(u16::MAX as u32) as u16;
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn u16_le_to_ids(bytes: &[u8]) -> Result<Vec<u32>> {
    if bytes.len() % 2 != 0 {
        return Err(Error::Context("odd u16 payload".into()));
    }
    Ok(bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]) as u32)
        .collect())
}

const B64: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 with padding.
pub fn base64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity((data.len() + 2) / 3 * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(B64[(n >> 18) as usize & 63] as char);
        out.push(B64[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            B64[(n >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            B64[n as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

/// Decode standard base64 (with padding). None on malformed input.
pub fn base64_decode(s: &str) -> Option<Vec<u8>> {
    fn val(c: u8) -> Option<u32> {
        match c {
            b'A'..=b'Z' => Some((c - b'A') as u32),
            b'a'..=b'z' => Some((c - b'a' + 26) as u32),
            b'0'..=b'9' => Some((c - b'0' + 52) as u32),
            b'+' => Some(62),
            b'/' => Some(63),
            _ => None,
        }
    }
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for chunk in bytes.chunks(4) {
        let pad = chunk.iter().filter(|&&c| c == b'=').count();
        if pad > 2 {
            return None;
        }
        let mut n = 0u32;
        for (i, &c) in chunk.iter().enumerate() {
            let v = if c == b'=' {
                if i < 2 {
                    return None; // padding only in last two slots
                }
                0
            } else {
                val(c)?
            };
            n = (n << 6) | v;
        }
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn tokens_json_roundtrip() {
        let c = StoredContext::Tokens(vec![1, 2, 300, 4095]);
        let doc = c.to_kv(7, TokenCodec::JsonInts);
        let (back, turns) = StoredContext::from_kv(&doc).unwrap();
        assert_eq!(back, c);
        assert_eq!(turns, 7);
    }

    #[test]
    fn tokens_binary_roundtrip() {
        let c = StoredContext::Tokens(vec![0, 65535, 42, 4095]);
        let doc = c.to_kv(3, TokenCodec::BinaryU16);
        let (back, turns) = StoredContext::from_kv(&doc).unwrap();
        assert_eq!(back, c);
        assert_eq!(turns, 3);
    }

    #[test]
    fn text_roundtrip() {
        let c = StoredContext::Text("<|im_start|>user\nhi ü<|im_end|>\n".into());
        let (back, _) = StoredContext::from_kv(&c.to_kv(1, TokenCodec::JsonInts)).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn binary_is_smaller_than_json() {
        let ids: Vec<u32> = (0..500).map(|i| (i * 7) % 4096).collect();
        let c = StoredContext::Tokens(ids);
        let json_len = c.to_kv(1, TokenCodec::JsonInts).len();
        let bin_len = c.to_kv(1, TokenCodec::BinaryU16).len();
        assert!(
            (bin_len as f64) < 0.7 * json_len as f64,
            "binary {bin_len} vs json {json_len}"
        );
    }

    #[test]
    fn base64_known_vectors() {
        assert_eq!(base64_encode(b""), "");
        assert_eq!(base64_encode(b"f"), "Zg==");
        assert_eq!(base64_encode(b"fo"), "Zm8=");
        assert_eq!(base64_encode(b"foo"), "Zm9v");
        assert_eq!(base64_encode(b"foobar"), "Zm9vYmFy");
        assert_eq!(base64_decode("Zm9vYmFy").unwrap(), b"foobar");
        assert_eq!(base64_decode("Zg==").unwrap(), b"f");
        assert!(base64_decode("Zg=").is_none());
        assert!(base64_decode("@@@@").is_none());
    }

    #[test]
    fn prop_base64_roundtrip() {
        testkit::property(200, |rng| {
            let data = rng.bytes(300);
            let enc = base64_encode(&data);
            assert_eq!(base64_decode(&enc).unwrap(), data);
        });
    }

    #[test]
    fn append_matches_full_reencode() {
        // The delta invariant: base ⊕ fragment == full-state document.
        for codec in [TokenCodec::JsonInts, TokenCodec::BinaryU16] {
            let base = StoredContext::Tokens(vec![1, 2, 3]).to_kv(1, codec);
            let frag = StoredContext::Tokens(vec![4, 5]).to_fragment(codec);
            let merged = append_to_doc(&base, &frag, 2).unwrap();
            let full = StoredContext::Tokens(vec![1, 2, 3, 4, 5]).to_kv(2, codec);
            assert_eq!(merged, full, "codec {codec:?}");
        }
        let base = StoredContext::Text("ab".into()).to_kv(1, TokenCodec::JsonInts);
        let frag = StoredContext::Text("cd".into()).to_fragment(TokenCodec::JsonInts);
        assert_eq!(
            append_to_doc(&base, &frag, 2).unwrap(),
            StoredContext::Text("abcd".into()).to_kv(2, TokenCodec::JsonInts)
        );
    }

    #[test]
    fn append_keeps_base_codec() {
        // A tokb replica receiving a tok-framed fragment stays tokb.
        let base = StoredContext::Tokens(vec![7]).to_kv(1, TokenCodec::BinaryU16);
        let frag = StoredContext::Tokens(vec![8]).to_fragment(TokenCodec::JsonInts);
        let merged = append_to_doc(&base, &frag, 2).unwrap();
        assert_eq!(
            merged,
            StoredContext::Tokens(vec![7, 8]).to_kv(2, TokenCodec::BinaryU16)
        );
    }

    #[test]
    fn append_rejects_mode_mismatch() {
        let base = StoredContext::Text("ab".into()).to_kv(1, TokenCodec::JsonInts);
        let frag = StoredContext::Tokens(vec![1]).to_fragment(TokenCodec::JsonInts);
        assert!(append_to_doc(&base, &frag, 2).is_err());
        assert!(append_to_doc("not json", &frag, 2).is_err());
    }

    #[test]
    fn fragments_coalesce() {
        let a = StoredContext::Tokens(vec![1, 2]).to_fragment(TokenCodec::BinaryU16);
        let b = StoredContext::Tokens(vec![3]).to_fragment(TokenCodec::BinaryU16);
        let ab = concat_fragment_docs(&a, &b).unwrap();
        assert_eq!(
            StoredContext::from_fragment(&ab).unwrap(),
            StoredContext::Tokens(vec![1, 2, 3])
        );
        // Coalesced fragment applies exactly like the two separate ones.
        let base = StoredContext::Tokens(vec![0]).to_kv(1, TokenCodec::BinaryU16);
        let step = append_to_doc(&append_to_doc(&base, &a, 2).unwrap(), &b, 3).unwrap();
        assert_eq!(append_to_doc(&base, &ab, 3).unwrap(), step);
        let t = StoredContext::Text("x".into()).to_fragment(TokenCodec::JsonInts);
        assert!(concat_fragment_docs(&a, &t).is_err());
    }

    #[test]
    fn rejects_malformed_docs() {
        assert!(StoredContext::from_kv("{}").is_err());
        assert!(StoredContext::from_kv(r#"{"fmt":"tok","turns":1}"#).is_err());
        assert!(StoredContext::from_kv(r#"{"fmt":"zzz","turns":1}"#).is_err());
        assert!(StoredContext::from_kv(r#"{"fmt":"tokb","turns":1,"ids":"!!"}"#).is_err());
        assert!(StoredContext::from_kv("not json").is_err());
    }
}
