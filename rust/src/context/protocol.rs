//! Wire protocol of the extended `/completion` API (paper §3.4, §4.1).
//!
//! Clients use the same request format as a centralized LLM service plus
//! the DisCEdge extensions: `user_id` / `session_id` (assigned by the
//! Context Manager on first contact), the client-maintained `turn`
//! counter, and the context `mode`. In `client_side` mode the request
//! additionally carries the full message history — the linear-growth
//! payload that Fig 7 measures.

use crate::config::{ConsistencyPolicy, ContextMode};
use crate::json::{self, Value};
use crate::llm::Message;
use crate::{Error, Result};

/// A `/completion` request.
#[derive(Debug, Clone)]
pub struct CompletionRequest {
    /// Target model (selects the KV keygroup and the engine).
    pub model: String,
    /// The new user prompt.
    pub prompt: String,
    /// User identifier (None on first contact; CM assigns).
    pub user_id: Option<String>,
    /// Session identifier (None on first contact; CM assigns).
    pub session_id: Option<String>,
    /// Client-driven turn counter, 1-based.
    pub turn: u64,
    /// Context storage mode.
    pub mode: ContextMode,
    /// Full history (client-side mode only).
    pub messages: Vec<Message>,
    /// Max new tokens (None = server default).
    pub max_tokens: Option<usize>,
    /// Per-request consistency override.
    pub consistency: Option<ConsistencyPolicy>,
}

impl CompletionRequest {
    /// Minimal request for a given mode.
    pub fn new(model: &str, prompt: &str, turn: u64, mode: ContextMode) -> CompletionRequest {
        CompletionRequest {
            model: model.into(),
            prompt: prompt.into(),
            user_id: None,
            session_id: None,
            turn,
            mode,
            messages: Vec::new(),
            max_tokens: None,
            consistency: None,
        }
    }

    /// Serialize to the JSON body.
    pub fn to_json(&self) -> String {
        let mut v = Value::obj()
            .set("model", self.model.as_str())
            .set("prompt", self.prompt.as_str())
            .set("turn", self.turn)
            .set("mode", self.mode.as_str());
        if let Some(u) = &self.user_id {
            v = v.set("user_id", u.as_str());
        }
        if let Some(s) = &self.session_id {
            v = v.set("session_id", s.as_str());
        }
        if let Some(m) = self.max_tokens {
            v = v.set("max_tokens", m);
        }
        if let Some(c) = self.consistency {
            v = v.set(
                "consistency",
                match c {
                    ConsistencyPolicy::Strict => "strict",
                    ConsistencyPolicy::Available => "available",
                },
            );
        }
        if !self.messages.is_empty() {
            let msgs: Vec<Value> = self
                .messages
                .iter()
                .map(|m| {
                    Value::obj()
                        .set("role", m.role.as_str())
                        .set("content", m.content.as_str())
                })
                .collect();
            v = v.set("messages", msgs);
        }
        v.to_json()
    }

    /// Parse from the JSON body.
    pub fn from_json(body: &str) -> Result<CompletionRequest> {
        let v = json::parse(body)?;
        let model = v.req_str("model")?;
        let prompt = v.req_str("prompt")?;
        let turn = v.req_u64("turn")?;
        if turn == 0 {
            return Err(Error::BadRequest("turn counter must be >= 1".into()));
        }
        let mode = ContextMode::parse(&v.req_str("mode")?)?;
        let messages = match v.get("messages").and_then(|m| m.as_array()) {
            Some(arr) => arr
                .iter()
                .map(|m| {
                    Ok(Message {
                        role: m.req_str("role")?,
                        content: m.req_str("content")?,
                    })
                })
                .collect::<Result<Vec<Message>>>()?,
            None => Vec::new(),
        };
        Ok(CompletionRequest {
            model,
            prompt,
            user_id: v.get("user_id").and_then(|x| x.as_str()).map(String::from),
            session_id: v
                .get("session_id")
                .and_then(|x| x.as_str())
                .map(String::from),
            turn,
            mode,
            messages,
            max_tokens: v
                .get("max_tokens")
                .and_then(|x| x.as_u64())
                .map(|x| x as usize),
            consistency: match v.get("consistency").and_then(|x| x.as_str()) {
                Some(s) => Some(ConsistencyPolicy::parse(s)?),
                None => None,
            },
        })
    }
}

/// Server-side timing breakdown returned with each response (drives the
/// paper's TPS and latency decomposition).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Timings {
    /// Seconds tokenizing on the request path.
    pub tokenize_s: f64,
    /// Seconds in engine prefill.
    pub prefill_s: f64,
    /// Seconds in engine decode.
    pub decode_s: f64,
    /// Seconds fetching context from the KV replica (incl. retries).
    pub fetch_s: f64,
    /// Stale-context re-reads performed.
    pub retries: u64,
    /// Total server-side handling time.
    pub total_s: f64,
}

/// A `/completion` response.
#[derive(Debug, Clone)]
pub struct CompletionResponse {
    /// Generated text.
    pub text: String,
    /// Assigned/echoed user id.
    pub user_id: String,
    /// Assigned/echoed session id.
    pub session_id: String,
    /// Echoed turn counter.
    pub turn: u64,
    /// Number of generated tokens.
    pub tokens_generated: usize,
    /// Context tokens processed in prefill.
    pub prefill_tokens: usize,
    /// Name of the serving node.
    pub node: String,
    /// Timing breakdown.
    pub timings: Timings,
}

impl CompletionResponse {
    /// Serialize to the JSON body.
    pub fn to_json(&self) -> String {
        let timings = Value::obj()
            .set("tokenize_s", self.timings.tokenize_s)
            .set("prefill_s", self.timings.prefill_s)
            .set("decode_s", self.timings.decode_s)
            .set("fetch_s", self.timings.fetch_s)
            .set("retries", self.timings.retries)
            .set("total_s", self.timings.total_s);
        Value::obj()
            .set("text", self.text.as_str())
            .set("user_id", self.user_id.as_str())
            .set("session_id", self.session_id.as_str())
            .set("turn", self.turn)
            .set("tokens_generated", self.tokens_generated)
            .set("prefill_tokens", self.prefill_tokens)
            .set("node", self.node.as_str())
            .set("timings", timings)
            .to_json()
    }

    /// Parse from the JSON body.
    pub fn from_json(body: &str) -> Result<CompletionResponse> {
        let v = json::parse(body)?;
        let t = v
            .get("timings")
            .cloned()
            .unwrap_or_else(Value::obj);
        let f = |k: &str| t.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
        Ok(CompletionResponse {
            text: v.req_str("text")?,
            user_id: v.req_str("user_id")?,
            session_id: v.req_str("session_id")?,
            turn: v.req_u64("turn")?,
            tokens_generated: v.req_u64("tokens_generated")? as usize,
            prefill_tokens: v.req_u64("prefill_tokens")? as usize,
            node: v.req_str("node")?,
            timings: Timings {
                tokenize_s: f("tokenize_s"),
                prefill_s: f("prefill_s"),
                decode_s: f("decode_s"),
                fetch_s: f("fetch_s"),
                retries: t.get("retries").and_then(|x| x.as_u64()).unwrap_or(0),
                total_s: f("total_s"),
            },
        })
    }
}

/// Marker for where the generated text begins inside the serialized
/// body. The octet run cannot occur earlier inside a field value:
/// quotes in values are escaped to `\"` by the serializer.
const TEXT_MARK: &str = "\"text\":\"";

/// Incremental body framing for a streamed `/completion` response.
///
/// Contract (pinned by `tests/batching.rs`): concatenating every frame
/// yields byte-for-byte the buffered [`CompletionResponse::to_json`]
/// body, so a client that reassembles the chunked stream parses the
/// exact JSON it would have received unstreamed. Object keys serialize
/// in sorted order, which places `"text"` mid-object; every field that
/// sorts before it (`node`, `prefill_tokens`, `session_id`) is final
/// once prefill has run — before the first token exists. The framer
/// therefore emits:
///
/// 1. [`StreamFraming::begin`] — the serialized head up to and
///    including `"text":"`, sliced from a probe serialization with
///    empty text, sent when the first token arrives;
/// 2. [`StreamFraming::fragment`] — each decoded text fragment escaped
///    with the serializer's own rules (escaping is per character, so
///    fragment-wise escaping concatenates exactly);
/// 3. [`StreamFraming::finish`] — everything past the already-emitted
///    bytes of the final serialization: any unsent text tail, the
///    closing quote, and the fields sorted after `text` (timings and
///    counters, which only exist once generation completes).
///
/// Invariants the caller upholds: the `head` passed to `begin` carries
/// the same `node`, `prefill_tokens`, and `session_id` as the response
/// passed to `finish`, and the concatenated fragment texts form a
/// prefix of that response's `text`.
pub struct StreamFraming {
    /// Bytes of the final serialization already handed out.
    emitted: usize,
}

impl StreamFraming {
    /// Start a stream: returns the framer and the body head, emitted
    /// when the first token arrives. `head`'s text, timings, and
    /// token counters are ignored — only fields sorted before `text`
    /// reach the wire here.
    pub fn begin(head: &CompletionResponse) -> (StreamFraming, String) {
        let probe = CompletionResponse {
            text: String::new(),
            ..head.clone()
        };
        let full = probe.to_json();
        let cut = full
            .find(TEXT_MARK)
            .expect("serialized completion response has a text field")
            + TEXT_MARK.len();
        (StreamFraming { emitted: cut }, full[..cut].to_string())
    }

    /// Frame one decoded text fragment.
    pub fn fragment(&mut self, text: &str) -> String {
        let mut out = String::with_capacity(text.len() + 8);
        json::escape_fragment(text, &mut out);
        self.emitted += out.len();
        out
    }

    /// Close the stream: the remainder of the final body past the
    /// bytes already emitted.
    pub fn finish(self, resp: &CompletionResponse) -> String {
        let full = resp.to_json();
        debug_assert!(full.is_char_boundary(self.emitted));
        full[self.emitted..].to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_minimal() {
        let r = CompletionRequest::new("m", "hello", 1, ContextMode::Tokenized);
        let back = CompletionRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(back.model, "m");
        assert_eq!(back.prompt, "hello");
        assert_eq!(back.turn, 1);
        assert_eq!(back.mode, ContextMode::Tokenized);
        assert!(back.user_id.is_none());
    }

    #[test]
    fn request_roundtrip_full() {
        let mut r = CompletionRequest::new("m", "p", 3, ContextMode::ClientSide);
        r.user_id = Some("u1".into());
        r.session_id = Some("s1".into());
        r.max_tokens = Some(64);
        r.consistency = Some(ConsistencyPolicy::Available);
        r.messages = vec![
            Message::new("user", "hi"),
            Message::new("assistant", "hello!"),
        ];
        let back = CompletionRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(back.user_id.as_deref(), Some("u1"));
        assert_eq!(back.messages.len(), 2);
        assert_eq!(back.messages[1].content, "hello!");
        assert_eq!(back.max_tokens, Some(64));
        assert_eq!(back.consistency, Some(ConsistencyPolicy::Available));
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(CompletionRequest::from_json("{}").is_err());
        assert!(CompletionRequest::from_json(
            r#"{"model":"m","prompt":"p","turn":0,"mode":"raw"}"#
        )
        .is_err());
        assert!(CompletionRequest::from_json(
            r#"{"model":"m","prompt":"p","turn":1,"mode":"warp"}"#
        )
        .is_err());
    }

    #[test]
    fn response_roundtrip() {
        let resp = CompletionResponse {
            text: "hi there".into(),
            user_id: "u".into(),
            session_id: "s".into(),
            turn: 2,
            tokens_generated: 42,
            prefill_tokens: 310,
            node: "edge-m2".into(),
            timings: Timings {
                tokenize_s: 0.001,
                prefill_s: 0.2,
                decode_s: 1.5,
                fetch_s: 0.0001,
                retries: 1,
                total_s: 1.71,
            },
        };
        let back = CompletionResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(back.text, "hi there");
        assert_eq!(back.timings, resp.timings);
        assert_eq!(back.prefill_tokens, 310);
    }

    fn sample_response(text: &str) -> CompletionResponse {
        CompletionResponse {
            text: text.into(),
            user_id: "u".into(),
            session_id: "s-1".into(),
            turn: 3,
            tokens_generated: 7,
            prefill_tokens: 12,
            node: "edge-n1".into(),
            timings: Timings {
                tokenize_s: 0.001,
                prefill_s: 0.05,
                decode_s: 0.4,
                fetch_s: 0.0002,
                retries: 0,
                total_s: 0.46,
            },
        }
    }

    #[test]
    fn stream_framing_reassembles_to_the_buffered_body() {
        // Fragments with every escape class: quote, backslash, newline,
        // control char, multi-byte unicode.
        let frags = ["hel", "lo \"wor", "ld\"\\", "\n\u{1} caf\u{e9} ≈", " done"];
        let resp = sample_response(&frags.concat());
        let head = CompletionResponse {
            text: String::new(),
            ..resp.clone()
        };
        let (mut framing, mut body) = StreamFraming::begin(&head);
        assert!(body.ends_with(TEXT_MARK));
        for f in frags {
            body.push_str(&framing.fragment(f));
        }
        body.push_str(&framing.finish(&resp));
        assert_eq!(body, resp.to_json());
        let back = CompletionResponse::from_json(&body).unwrap();
        assert_eq!(back.text, resp.text);
    }

    #[test]
    fn stream_framing_finish_carries_the_unsent_tail() {
        // Only a prefix of the text was streamed (e.g. the tail decoded
        // after the last step); finish must still complete the body.
        let resp = sample_response("alpha beta");
        let head = CompletionResponse {
            text: String::new(),
            ..resp.clone()
        };
        let (mut framing, mut body) = StreamFraming::begin(&head);
        body.push_str(&framing.fragment("alpha "));
        body.push_str(&framing.finish(&resp));
        assert_eq!(body, resp.to_json());
    }

    #[test]
    fn stream_framing_head_survives_hostile_ids() {
        // A session id containing the text marker must not confuse the
        // head slice: quotes inside values are escaped on the wire.
        let mut resp = sample_response("ok");
        resp.session_id = "evil\"text\":\"x".into();
        let head = CompletionResponse {
            text: String::new(),
            ..resp.clone()
        };
        let (mut framing, mut body) = StreamFraming::begin(&head);
        body.push_str(&framing.fragment("ok"));
        body.push_str(&framing.finish(&resp));
        assert_eq!(body, resp.to_json());
    }

    #[test]
    fn client_side_request_grows_with_history() {
        // Fig 7's mechanism: client-side payload grows linearly.
        let mut small = CompletionRequest::new("m", "p", 3, ContextMode::ClientSide);
        small.messages = vec![Message::new("user", "hi")];
        let mut big = small.clone();
        for i in 0..20 {
            big.messages.push(Message::new(
                if i % 2 == 0 { "assistant" } else { "user" },
                &"long answer text ".repeat(30),
            ));
        }
        assert!(big.to_json().len() > small.to_json().len() + 8000);
    }
}
