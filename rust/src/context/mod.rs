//! The Context Manager (paper §3.1) — DisCEdge's core contribution.
//!
//! An intelligent middleware between the client and the LLM Service on
//! each edge node. Responsibilities, mirroring the paper:
//!
//! - assign `user_id` / `session_id` on first contact;
//! - enforce the **client-driven turn-counter consistency protocol** on
//!   top of the KV store's eventual consistency: the local replica must
//!   hold the session at version `turn - 1`; if stale, re-read with
//!   bounded backoff (default 3 × 10 ms), then fail (`Strict`, default) or
//!   proceed with stale context (`Available`);
//! - maintain session context **pre-tokenized** so each turn only
//!   tokenizes the new prompt (tokenized mode), or as raw text that is
//!   re-tokenized wholesale every turn (raw baseline), or not at all
//!   (client-side baseline);
//! - after responding, **asynchronously** tokenize the new turn fragment
//!   and append it to the stored context (the paper's async update step,
//!   off the client-observable path);
//! - stamp each KV write with the turn number as its version and the
//!   session TTL.

pub mod codec;
mod protocol;

pub use codec::{base64_decode, base64_encode, StoredContext, TokenCodec};
pub use protocol::{CompletionRequest, CompletionResponse, StreamFraming, Timings};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::{ConsistencyConfig, ConsistencyPolicy, ContextMode, GenerationConfig};
use crate::kvstore::KvNode;
use crate::llm::{ChatTemplate, Engine};
use crate::metrics::Registry;
use crate::profile::NodeProfile;
use crate::testkit::Rng;
use crate::{Error, Result};

/// The per-node context manager.
pub struct ContextManager {
    node: String,
    profile: NodeProfile,
    template: ChatTemplate,
    kv: Arc<KvNode>,
    consistency: ConsistencyConfig,
    generation: GenerationConfig,
    session_ttl: Duration,
    codec: TokenCodec,
    id_gen: Mutex<(Rng, u64)>,
    updates_queued: Arc<AtomicU64>,
    updates_done: Arc<AtomicU64>,
    /// session key -> highest context version queued for async write on
    /// *this* node. Gives read-your-writes to a client that stays on the
    /// same node (its next turn may arrive before the async update has
    /// committed); cross-node staleness still goes through the paper's
    /// retry protocol.
    pending_updates: Arc<Mutex<HashMap<String, u64>>>,
    /// Node metric registry (request counts, retry counts, latencies).
    pub registry: Arc<Registry>,
}

impl ContextManager {
    /// Build a context manager for one edge node.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        node: &str,
        profile: NodeProfile,
        template: ChatTemplate,
        kv: Arc<KvNode>,
        consistency: ConsistencyConfig,
        generation: GenerationConfig,
        session_ttl: Duration,
        codec: TokenCodec,
    ) -> ContextManager {
        ContextManager {
            node: node.to_string(),
            profile,
            template,
            kv,
            consistency,
            generation,
            session_ttl,
            codec,
            id_gen: Mutex::new((Rng::new(fxhash(node)), 0)),
            updates_queued: Arc::new(AtomicU64::new(0)),
            updates_done: Arc::new(AtomicU64::new(0)),
            pending_updates: Arc::new(Mutex::new(HashMap::new())),
            registry: Arc::new(Registry::new()),
        }
    }

    /// The node name.
    pub fn node_name(&self) -> &str {
        &self.node
    }

    /// The chat template in use.
    pub fn template(&self) -> &ChatTemplate {
        &self.template
    }

    /// Handle one `/completion` request against `engine`.
    pub fn handle(&self, req: &CompletionRequest, engine: &dyn Engine) -> Result<CompletionResponse> {
        self.handle_with_sink(req, engine, None)
    }

    /// [`ContextManager::handle`] with optional incremental output: when
    /// `sink` is given, response **body bytes** are pushed to it as the
    /// engine produces tokens, framed by [`StreamFraming`] so the
    /// concatenated frames equal the buffered `to_json` body exactly.
    /// The sink is first called when the first token exists (never for
    /// a zero-token generation — the caller falls back to the buffered
    /// response it gets back from this method), and last called with
    /// the body tail after the context update has been queued, so the
    /// turn-commit ordering matches the buffered path.
    pub fn handle_with_sink(
        &self,
        req: &CompletionRequest,
        engine: &dyn Engine,
        mut sink: Option<&mut dyn FnMut(&str)>,
    ) -> Result<CompletionResponse> {
        let start = Instant::now();
        if req.model != engine.model_name() {
            return Err(Error::BadRequest(format!(
                "model {} not served by this engine",
                req.model
            )));
        }
        let (user_id, session_id) = self.assign_ids(req);
        let key = session_key(&user_id, &session_id);
        self.registry.incr("cm_requests_total", 1);

        let mut timings = Timings::default();
        let max_tokens = req.max_tokens.unwrap_or(self.generation.max_tokens);
        let policy = req.consistency.unwrap_or(self.consistency.policy);

        let (input_ids, history, exact_base) = match req.mode {
            ContextMode::ClientSide => {
                // Stateless: render + tokenize everything, store nothing.
                let text = self.template.render_messages(&req.messages, &req.prompt);
                let t = Instant::now();
                let ids = self
                    .profile
                    .tokenize_emulated(text.len(), || self.template.encode_transcript(&text));
                timings.tokenize_s = t.elapsed().as_secs_f64();
                (ids, None, false)
            }
            ContextMode::Tokenized => {
                let (ctx, fetch, exact) =
                    self.fetch_context(req, &key, policy, ContextMode::Tokenized)?;
                timings.fetch_s = fetch.0;
                timings.retries = fetch.1;
                let history_ids = match ctx {
                    Some(StoredContext::Tokens(ids)) => ids,
                    Some(StoredContext::Text(_)) => {
                        return Err(Error::Context(
                            "session stored as raw text; mode mismatch".into(),
                        ))
                    }
                    // Fresh session: preamble is assembled (tokenized) now.
                    None => {
                        let t = Instant::now();
                        let preamble_len = self.template.preamble_text().len();
                        let ids = self
                            .profile
                            .tokenize_emulated(preamble_len, || self.template.preamble_ids());
                        timings.tokenize_s += t.elapsed().as_secs_f64();
                        ids
                    }
                };
                // Only the *new prompt* is tokenized on the request path —
                // the paper's core optimization.
                let t = Instant::now();
                let turn_text_len = self.template.user_turn_text(&req.prompt).len();
                let new_ids = self
                    .profile
                    .tokenize_emulated(turn_text_len, || self.template.user_turn_ids(&req.prompt));
                timings.tokenize_s += t.elapsed().as_secs_f64();
                let mut input = history_ids.clone();
                input.extend_from_slice(&new_ids);
                (input, Some(StoredContext::Tokens(history_ids)), exact)
            }
            ContextMode::Raw => {
                let (ctx, fetch, exact) =
                    self.fetch_context(req, &key, policy, ContextMode::Raw)?;
                timings.fetch_s = fetch.0;
                timings.retries = fetch.1;
                let history_text = match ctx {
                    Some(StoredContext::Text(t)) => t,
                    Some(StoredContext::Tokens(_)) => {
                        return Err(Error::Context(
                            "session stored tokenized; mode mismatch".into(),
                        ))
                    }
                    None => self.template.preamble_text(),
                };
                // Baseline: the whole transcript is re-tokenized each turn.
                let full_text = format!(
                    "{history_text}{}",
                    self.template.user_turn_text(&req.prompt)
                );
                let t = Instant::now();
                let ids = self
                    .profile
                    .tokenize_emulated(full_text.len(), || {
                        self.template.encode_transcript(&full_text)
                    });
                timings.tokenize_s = t.elapsed().as_secs_f64();
                (ids, Some(StoredContext::Text(history_text)), exact)
            }
        };

        // Context-window guard (paper §2.1.2): drop oldest content, keep
        // the preamble, when the input would overflow the model.
        let budget = engine.max_context().saturating_sub(max_tokens);
        let input_ids = self.truncate_to_budget(input_ids, budget);

        // Inference. The engine reports its CPU cost; the profile extends
        // wall time to the emulated device class and the timings expose
        // the device-perceived cost (what the paper's TPS metric divides
        // by).
        let stop_id = self.template.stop_id();
        let mut framing: Option<StreamFraming> = None;
        let gen = match &mut sink {
            None => engine.generate(&input_ids, max_tokens, stop_id)?,
            Some(sink) => {
                // Streamed inference. Every field of the body head that
                // serializes before `text` is already final here: the
                // ids are assigned and prefill covers exactly the input
                // ids (every engine reports its full input as
                // `prefill_tokens`). Token ids re-decode in full each
                // step and only the stable extension past what was
                // already emitted goes out — a token can end mid-UTF-8
                // sequence, where the lossy decode's trailing
                // replacement chars are provisional, so those are held
                // back until a later token completes them.
                let head = CompletionResponse {
                    text: String::new(),
                    user_id: user_id.clone(),
                    session_id: session_id.clone(),
                    turn: req.turn,
                    tokens_generated: 0,
                    prefill_tokens: input_ids.len(),
                    node: self.node.clone(),
                    timings: Timings::default(),
                };
                let mut ids: Vec<u32> = Vec::new();
                let mut emitted = String::new();
                let mut on_token = |id: u32| {
                    ids.push(id);
                    let framing = framing.get_or_insert_with(|| {
                        let (framing, head_bytes) = StreamFraming::begin(&head);
                        sink(&head_bytes);
                        framing
                    });
                    let text = self.template.decode(&ids);
                    let stable = text.trim_end_matches('\u{fffd}');
                    if let Some(suffix) = stable.strip_prefix(emitted.as_str()) {
                        if !suffix.is_empty() {
                            sink(&framing.fragment(suffix));
                            emitted.push_str(suffix);
                        }
                    }
                };
                engine.generate_streamed(&input_ids, max_tokens, stop_id, &mut on_token)?
            }
        };
        debug_assert!(
            framing.is_none() || gen.prefill_tokens == input_ids.len(),
            "streamed body head fixed prefill_tokens before the engine reported a different count"
        );
        self.profile.extend_inference(gen.prefill_s + gen.decode_s);
        timings.prefill_s = self.profile.scaled_inference_s(gen.prefill_s);
        timings.decode_s = self.profile.scaled_inference_s(gen.decode_s);
        let response_text = self.template.decode(&gen.ids);

        // Asynchronous context update (tokenized + raw modes).
        if let Some(history) = history {
            self.spawn_update(
                req.model.clone(),
                key,
                req.turn,
                history,
                req.prompt.clone(),
                response_text.clone(),
                exact_base,
            );
        }

        timings.total_s = start.elapsed().as_secs_f64();
        self.registry.observe("cm_request_s", timings.total_s);
        self.registry
            .incr("cm_retries_total", timings.retries);
        let resp = CompletionResponse {
            text: response_text,
            user_id,
            session_id,
            turn: req.turn,
            tokens_generated: gen.ids.len(),
            prefill_tokens: gen.prefill_tokens,
            node: self.node.clone(),
            timings,
        };
        // Streamed and at least one token went out: close the body with
        // everything past the emitted bytes (unsent text tail, closing
        // quote, timings and counters).
        if let (Some(framing), Some(sink)) = (framing, &mut sink) {
            sink(&framing.finish(&resp));
        }
        Ok(resp)
    }

    /// Assign user/session ids when absent (paper §3.1).
    fn assign_ids(&self, req: &CompletionRequest) -> (String, String) {
        let mut gen = self.id_gen.lock().unwrap();
        let user = req.user_id.clone().unwrap_or_else(|| {
            gen.1 += 1;
            format!("u-{:08x}-{}", gen.0.next_u64() as u32, gen.1)
        });
        let session = req.session_id.clone().unwrap_or_else(|| {
            gen.1 += 1;
            format!("s-{:08x}-{}", gen.0.next_u64() as u32, gen.1)
        });
        (user, session)
    }

    /// The turn-counter consistency protocol (paper §3.1/§3.3): read the
    /// local replica; expect version `turn - 1`; retry on staleness.
    ///
    /// Returns the context (None for a fresh session),
    /// `(fetch_seconds, retries)`, and whether the context is **exactly**
    /// at version `turn - 1` (false when the `Available` policy served
    /// stale state). The async update must not advertise a delta base it
    /// did not actually extend — a receiver genuinely at `turn - 1` would
    /// splice the fragment onto a *different* history and the replicas
    /// would diverge at equal versions, beyond LWW's reach.
    fn fetch_context(
        &self,
        req: &CompletionRequest,
        key: &str,
        policy: ConsistencyPolicy,
        mode: ContextMode,
    ) -> Result<(Option<StoredContext>, (f64, u64), bool)> {
        let t = Instant::now();
        let expected = req.turn - 1;
        if expected == 0 {
            // New session. A leftover entry (e.g. expired client restart)
            // is superseded; turn 1 always starts fresh.
            return Ok((None, (t.elapsed().as_secs_f64(), 0), true));
        }
        let mut retries = 0u64;
        // Local read-your-writes: if this node itself queued the update
        // the client is waiting on, poll briefly instead of burning
        // protocol retries (bounded in case the update thread died).
        let local_deadline = Instant::now() + Duration::from_millis(250);
        loop {
            // Ring-aware read: on a node outside the session's preference
            // list this fetches from a home replica and read-repairs the
            // entry locally; on a home replica (or without placement) it
            // is a plain local read and staleness is absorbed by the retry
            // loop below, exactly as in the paper. While our own async
            // update for this session is still pending, stay local — the
            // commit we are waiting for is in this process, and remote
            // replicas cannot be ahead of it.
            let entry = if self.has_pending_local_update(key, expected) {
                self.kv.get(&req.model, key)
            } else {
                self.kv.get_or_fetch(&req.model, key, expected)
            };
            match entry {
                Some(entry) if entry.version >= req.turn => {
                    return Err(Error::BadRequest(format!(
                        "turn {} is behind stored version {} (counter reset?)",
                        req.turn, entry.version
                    )));
                }
                Some(entry) if entry.version == expected => {
                    let (ctx, _) = StoredContext::from_kv(&entry.value)?;
                    self.check_mode(&ctx, mode)?;
                    return Ok((Some(ctx), (t.elapsed().as_secs_f64(), retries), true));
                }
                stale => {
                    if self.has_pending_local_update(key, expected)
                        && Instant::now() < local_deadline
                    {
                        std::thread::sleep(Duration::from_micros(500));
                        continue;
                    }
                    // Missing or behind: replication from the previous
                    // node has not landed yet.
                    if retries >= self.consistency.retries as u64 {
                        return match policy {
                            ConsistencyPolicy::Strict => Err(Error::Consistency(format!(
                                "context for {key} stale after {retries} retries \
                                 (have v{}, need v{expected})",
                                stale.map(|e| e.version).unwrap_or(0),
                            ))),
                            ConsistencyPolicy::Available => {
                                self.registry.incr("cm_stale_served_total", 1);
                                let ctx = match stale {
                                    Some(e) => Some(StoredContext::from_kv(&e.value)?.0),
                                    None => None,
                                };
                                // Stale base: the coming write is NOT an
                                // append onto `expected`.
                                Ok((ctx, (t.elapsed().as_secs_f64(), retries), false))
                            }
                        };
                    }
                    retries += 1;
                    std::thread::sleep(self.consistency.backoff);
                }
            }
        }
    }

    /// Whether this node has queued (but not yet committed) an async
    /// update that would satisfy `expected`.
    fn has_pending_local_update(&self, key: &str, expected: u64) -> bool {
        self.pending_updates
            .lock()
            .unwrap()
            .get(key)
            .map_or(false, |&v| v >= expected)
    }

    fn check_mode(&self, ctx: &StoredContext, mode: ContextMode) -> Result<()> {
        match (ctx, mode) {
            (StoredContext::Tokens(_), ContextMode::Tokenized)
            | (StoredContext::Text(_), ContextMode::Raw) => Ok(()),
            _ => Err(Error::Context("stored context mode mismatch".into())),
        }
    }

    /// Keep the tail within `budget` tokens, preserving the preamble.
    fn truncate_to_budget(&self, ids: Vec<u32>, budget: usize) -> Vec<u32> {
        if ids.len() <= budget {
            return ids;
        }
        let preamble_len = self.template.preamble_ids().len().min(budget);
        let tail_budget = budget - preamble_len;
        let mut out = ids[..preamble_len].to_vec();
        out.extend_from_slice(&ids[ids.len() - tail_budget..]);
        self.registry.incr("cm_truncations_total", 1);
        out
    }

    /// Background context update: tokenize the new turn fragment (the
    /// paper's async tokenization step), append, and write to the KV
    /// store with the turn number as version. `exact_base` marks the
    /// write as a true append onto version `turn - 1`; only then may the
    /// KV layer replicate it as a delta.
    #[allow(clippy::too_many_arguments)]
    fn spawn_update(
        &self,
        model: String,
        key: String,
        turn: u64,
        history: StoredContext,
        prompt: String,
        response: String,
        exact_base: bool,
    ) {
        self.updates_queued.fetch_add(1, Ordering::SeqCst);
        {
            let mut pending = self.pending_updates.lock().unwrap();
            let e = pending.entry(key.clone()).or_insert(0);
            *e = (*e).max(turn);
        }
        let kv = self.kv.clone();
        let template = self.template.clone();
        let profile = self.profile.clone();
        let ttl = self.session_ttl;
        let codec = self.codec;
        let done = self.updates_done.clone();
        let pending_map = self.pending_updates.clone();
        let registry = self.registry.clone();
        // Carry the turn's trace context into the update thread so the
        // async write (and its replication push) stitches under the
        // originating /completion trace instead of appearing orphaned.
        let trace = crate::obs::current();
        let _ = std::thread::Builder::new()
            .name("cm-update".into())
            .spawn(move || {
                let _trace = crate::obs::set_current(trace);
                let t = Instant::now();
                // The turn's new content is an append-only fragment on top
                // of the stored history; when this node replicates deltas
                // AND the history really sits at version turn-1, the
                // fragment document is handed to the KV layer alongside
                // the full value so only the fragment goes on the wire.
                // Otherwise skip building it (full-state mode would throw
                // it away; a stale base must never ship as a delta; turn 1
                // always ships full state — nothing to append onto).
                let want_fragment = exact_base && turn > 1 && kv.delta_sync_enabled();
                let (doc, frag_doc) = match history {
                    StoredContext::Tokens(mut ids) => {
                        // Async tokenization of the new fragment only.
                        let fragment = format!(
                            "{}{}",
                            template.user_turn_text(&prompt),
                            template.close_text(&response)
                        );
                        let frag_ids = profile
                            .update_tokenize_emulated(fragment.len(), || {
                                template.encode_transcript(&fragment)
                            });
                        let frag_doc = want_fragment
                            .then(|| StoredContext::Tokens(frag_ids.clone()).to_fragment(codec));
                        ids.extend(frag_ids);
                        (StoredContext::Tokens(ids).to_kv(turn, codec), frag_doc)
                    }
                    StoredContext::Text(mut text) => {
                        // Raw mode: plain string append, no tokenization.
                        let mut fragment = template.user_turn_text(&prompt);
                        fragment.push_str(&template.close_text(&response));
                        text.push_str(&fragment);
                        (
                            StoredContext::Text(text).to_kv(turn, codec),
                            want_fragment
                                .then(|| StoredContext::Text(fragment).to_fragment(codec)),
                        )
                    }
                };
                registry.observe("cm_async_update_s", t.elapsed().as_secs_f64());
                if let Err(e) =
                    kv.put_ttl_append(&model, &key, doc, turn, Some(ttl), frag_doc.as_deref())
                {
                    // Benign when an out-of-order update lost the LWW race.
                    registry.incr("cm_update_conflicts_total", 1);
                    let _ = e;
                }
                {
                    // Clear the read-your-writes marker unless a newer
                    // update for this session has been queued since.
                    let mut pending = pending_map.lock().unwrap();
                    if pending.get(&key) == Some(&turn) {
                        pending.remove(&key);
                    }
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
    }

    /// Wait for queued async updates to be written locally, then for the
    /// KV replicator to drain. Used at turn boundaries in tests/benches.
    pub fn quiesce(&self) {
        while self.updates_done.load(Ordering::SeqCst) < self.updates_queued.load(Ordering::SeqCst)
        {
            std::thread::sleep(Duration::from_micros(200));
        }
        self.kv.quiesce();
    }
}

/// Session KV key.
pub fn session_key(user_id: &str, session_id: &str) -> String {
    format!("{user_id}/{session_id}")
}

fn fxhash(s: &str) -> u64 {
    crate::testkit::fnv1a(s.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::KvConfig;
    use crate::llm::MockEngine;
    use crate::netsim::LinkModel;
    use crate::tokenizer::{train, Tokenizer, TrainConfig};

    const MODEL: &str = "discedge/tiny-chat";

    fn make_cm(kv: Arc<KvNode>) -> ContextManager {
        let corpus = crate::workload::corpus_with_size(1, 30_000);
        let tok = Tokenizer::from_vocab(train(
            &corpus,
            &TrainConfig {
                vocab_size: 512,
                ..TrainConfig::default()
            },
        ));
        let template = ChatTemplate::new(Arc::new(tok)).unwrap();
        ContextManager::new(
            "test-node",
            NodeProfile::m2_native(),
            template,
            kv,
            ConsistencyConfig::default(),
            GenerationConfig::default(),
            Duration::from_secs(60),
            TokenCodec::BinaryU16,
        )
    }

    fn make_kv() -> Arc<KvNode> {
        let kv = KvNode::start(
            "test",
            KvConfig {
                peer_link: LinkModel::ideal(),
                ..KvConfig::default()
            },
        )
        .unwrap();
        kv.create_keygroup(MODEL);
        Arc::new(kv)
    }

    fn engine() -> MockEngine {
        MockEngine::new(MODEL, 512).with_fixed_len(16)
    }

    #[test]
    fn first_turn_assigns_ids() {
        let cm = make_cm(make_kv());
        let req = CompletionRequest::new(MODEL, "hello robot", 1, ContextMode::Tokenized);
        let resp = cm.handle(&req, &engine()).unwrap();
        assert!(resp.user_id.starts_with("u-"));
        assert!(resp.session_id.starts_with("s-"));
        assert_eq!(resp.turn, 1);
        assert_eq!(resp.tokens_generated, 16);
    }

    #[test]
    fn sink_frames_reassemble_to_the_returned_body() {
        let cm = make_cm(make_kv());
        let e = engine();
        let req = CompletionRequest::new(MODEL, "hello robot", 1, ContextMode::Tokenized);

        // Buffered reference: the engine is deterministic in its input
        // ids, so a fresh session with the same prompt generates the
        // same text.
        let buffered = cm.handle(&req, &e).unwrap();

        let mut frames: Vec<String> = Vec::new();
        let mut sink = |f: &str| frames.push(f.to_string());
        let resp = cm
            .handle_with_sink(&req, &e, Some(&mut sink))
            .unwrap();

        // The concatenated frames are the returned body, byte for byte.
        let body: String = frames.concat();
        assert_eq!(body, resp.to_json());
        assert!(
            frames.len() >= 3,
            "expected head + fragments + tail, got {} frames",
            frames.len()
        );
        assert_eq!(resp.text, buffered.text, "streaming must not change the transcript");
        // And the reassembled body parses back to the same response.
        let back = CompletionResponse::from_json(&body).unwrap();
        assert_eq!(back.text, resp.text);
        assert_eq!(back.tokens_generated, resp.tokens_generated);
    }

    #[test]
    fn tokenized_session_grows_context() {
        let kv = make_kv();
        let cm = make_cm(kv.clone());
        let e = engine();
        let mut req = CompletionRequest::new(MODEL, "What is SLAM?", 1, ContextMode::Tokenized);
        let r1 = cm.handle(&req, &e).unwrap();
        cm.quiesce();
        // Stored context now at version 1.
        let key = session_key(&r1.user_id, &r1.session_id);
        let entry = kv.get(MODEL, &key).unwrap();
        assert_eq!(entry.version, 1);

        req.user_id = Some(r1.user_id.clone());
        req.session_id = Some(r1.session_id.clone());
        req.turn = 2;
        req.prompt = "Tell me more".into();
        let r2 = cm.handle(&req, &e).unwrap();
        assert!(
            r2.prefill_tokens > r1.prefill_tokens,
            "turn 2 must see a longer context ({} vs {})",
            r2.prefill_tokens,
            r1.prefill_tokens
        );
    }

    #[test]
    fn tokenized_and_raw_feed_identical_ids_to_engine() {
        // The central correctness property across modes (paper Fig 2):
        // prefill length must be identical turn by turn.
        let kv = make_kv();
        let cm = make_cm(kv);
        let e = engine();
        let prompts = ["What is SLAM?", "Tell me more", "And the challenges?"];

        let run = |mode: ContextMode| -> Vec<usize> {
            let mut out = Vec::new();
            let mut user = None;
            let mut session = None;
            for (i, p) in prompts.iter().enumerate() {
                let mut req = CompletionRequest::new(MODEL, p, (i + 1) as u64, mode);
                req.user_id = user.clone();
                req.session_id = session.clone();
                let r = cm.handle(&req, &e).unwrap();
                cm.quiesce();
                user = Some(r.user_id.clone());
                session = Some(r.session_id.clone());
                out.push(r.prefill_tokens);
            }
            out
        };

        let tok = run(ContextMode::Tokenized);
        let raw = run(ContextMode::Raw);
        assert_eq!(tok, raw, "modes must present identical inputs");
    }

    #[test]
    fn raw_mode_tokenizes_more_each_turn() {
        let cm = make_cm(make_kv());
        let e = engine();
        let mut user = None;
        let mut session = None;
        let mut tok_times = Vec::new();
        for i in 1..=4u64 {
            let mut req = CompletionRequest::new(
                MODEL,
                "Explain the particle filter in detail please",
                i,
                ContextMode::Raw,
            );
            req.user_id = user.clone();
            req.session_id = session.clone();
            let r = cm.handle(&req, &e).unwrap();
            cm.quiesce();
            user = Some(r.user_id.clone());
            session = Some(r.session_id.clone());
            tok_times.push(r.prefill_tokens);
        }
        // Prefill tokens grow strictly: the raw mode re-tokenizes an
        // ever-larger transcript.
        assert!(tok_times.windows(2).all(|w| w[1] > w[0]), "{tok_times:?}");
    }

    #[test]
    fn stale_context_strict_fails_then_available_serves() {
        let kv = make_kv();
        let mut cm = make_cm(kv);
        cm.consistency.retries = 1;
        cm.consistency.backoff = Duration::from_millis(1);
        let e = engine();
        // Claim turn 5 of a session that has no stored context at all.
        let mut req = CompletionRequest::new(MODEL, "hi", 5, ContextMode::Tokenized);
        req.user_id = Some("u1".into());
        req.session_id = Some("s1".into());
        let err = cm.handle(&req, &e).unwrap_err();
        assert!(matches!(err, Error::Consistency(_)), "{err}");
        // Available policy proceeds with a fresh context instead.
        req.consistency = Some(ConsistencyPolicy::Available);
        let resp = cm.handle(&req, &e).unwrap();
        assert_eq!(resp.turn, 5);
        assert_eq!(resp.timings.retries, 1);
    }

    #[test]
    fn retry_succeeds_when_replication_lands_midway() {
        let kv = make_kv();
        let cm = Arc::new(make_cm(kv.clone()));
        let e = engine();
        // Seed a session at version 1 *after* a delay, while the request
        // for turn 2 is already waiting in the retry loop.
        let doc = StoredContext::Tokens(vec![60, 61, 62]).to_kv(1, TokenCodec::BinaryU16);
        let kv2 = kv.clone();
        let writer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(12));
            kv2.put("discedge/tiny-chat", "u1/s1", doc, 1).unwrap();
        });
        let mut req = CompletionRequest::new(MODEL, "go on", 2, ContextMode::Tokenized);
        req.user_id = Some("u1".into());
        req.session_id = Some("s1".into());
        let resp = cm.handle(&req, &e).unwrap();
        writer.join().unwrap();
        assert!(resp.timings.retries >= 1, "must have retried");
        assert!(resp.timings.retries <= 3);
    }

    #[test]
    fn same_node_consecutive_turns_read_own_writes() {
        // Even with ZERO protocol retries, a client that stays on the
        // same node must never see its own async update as staleness.
        let kv = make_kv();
        let mut cm = make_cm(kv);
        cm.consistency.retries = 0;
        let e = engine();
        let mut user = None;
        let mut session = None;
        for i in 1..=5u64 {
            let mut req =
                CompletionRequest::new(MODEL, "keep going", i, ContextMode::Tokenized);
            req.user_id = user.clone();
            req.session_id = session.clone();
            // Deliberately NO quiesce between turns.
            let r = cm.handle(&req, &e).unwrap_or_else(|err| {
                panic!("turn {i} failed despite local pending update: {err}")
            });
            user = Some(r.user_id);
            session = Some(r.session_id);
            assert_eq!(r.timings.retries, 0, "local RYW must not burn retries");
        }
    }

    #[test]
    fn stale_base_update_never_ships_as_delta() {
        // An Available-policy write onto a stale base must replicate as
        // full state: a peer genuinely at `turn - 1` would otherwise
        // splice the fragment onto a *different* history and the replicas
        // would diverge at equal versions, beyond LWW's reach.
        let kv_cfg = KvConfig {
            peer_link: LinkModel::ideal(),
            replication: crate::kvstore::ReplicationConfig {
                delta_sync: true,
                ..Default::default()
            },
            ..KvConfig::default()
        };
        let a = KvNode::start("a", kv_cfg.clone()).unwrap();
        let b = KvNode::start("b", kv_cfg).unwrap();
        a.create_keygroup(MODEL);
        b.create_keygroup(MODEL);
        a.add_peer(MODEL, b.replication_addr());
        let a = Arc::new(a);
        let mut cm = make_cm(a.clone());
        cm.consistency.retries = 0;
        let e = engine();

        // Turn 1 establishes v1 on both replicas.
        let mut req = CompletionRequest::new(MODEL, "hello", 1, ContextMode::Tokenized);
        req.user_id = Some("u1".into());
        req.session_id = Some("s1".into());
        cm.handle(&req, &e).unwrap();
        cm.quiesce();
        let key = session_key("u1", "s1");
        for _ in 0..200 {
            if b.get(MODEL, &key).is_some() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(b.get(MODEL, &key).is_some(), "v1 must replicate first");

        // b alone advances to v2 with a history a never saw.
        let divergent = StoredContext::Tokens(vec![1, 2, 3]).to_kv(2, TokenCodec::BinaryU16);
        b.put(MODEL, &key, divergent, 2).unwrap();

        // a (still at v1) serves turn 3 under Available: stale base.
        req.turn = 3;
        req.prompt = "more".into();
        req.consistency = Some(ConsistencyPolicy::Available);
        cm.handle(&req, &e).unwrap();
        cm.quiesce();

        let av = a.get(MODEL, &key).expect("a stores its own write");
        assert_eq!(av.version, 3);
        let bv = (0..200)
            .find_map(|_| {
                let e = b.get(MODEL, &key).filter(|e| e.version == 3);
                if e.is_none() {
                    std::thread::sleep(Duration::from_millis(5));
                }
                e
            })
            .expect("b must converge to v3");
        assert_eq!(
            bv.value, av.value,
            "stale-base write must replicate as full state, not a delta"
        );
        assert_eq!(b.delta_applies(), 0, "no delta may carry a stale base");
    }

    #[test]
    fn turn_behind_server_rejected() {
        let kv = make_kv();
        let cm = make_cm(kv.clone());
        let doc = StoredContext::Tokens(vec![60]).to_kv(4, TokenCodec::BinaryU16);
        kv.put(MODEL, "u1/s1", doc, 4).unwrap();
        let mut req = CompletionRequest::new(MODEL, "hi", 3, ContextMode::Tokenized);
        req.user_id = Some("u1".into());
        req.session_id = Some("s1".into());
        let err = cm.handle(&req, &engine()).unwrap_err();
        assert!(matches!(err, Error::BadRequest(_)), "{err}");
    }

    #[test]
    fn client_side_mode_stores_nothing() {
        let kv = make_kv();
        let cm = make_cm(kv.clone());
        let mut req = CompletionRequest::new(MODEL, "hi", 1, ContextMode::ClientSide);
        req.messages = vec![crate::llm::Message::new("user", "earlier q")];
        let resp = cm.handle(&req, &engine()).unwrap();
        cm.quiesce();
        assert!(kv.is_empty(), "client-side mode must not persist context");
        assert!(resp.tokens_generated > 0);
    }

    #[test]
    fn mode_mismatch_detected() {
        let kv = make_kv();
        let cm = make_cm(kv.clone());
        let doc = StoredContext::Text("history".into()).to_kv(1, TokenCodec::BinaryU16);
        kv.put(MODEL, "u1/s1", doc, 1).unwrap();
        let mut req = CompletionRequest::new(MODEL, "hi", 2, ContextMode::Tokenized);
        req.user_id = Some("u1".into());
        req.session_id = Some("s1".into());
        assert!(cm.handle(&req, &engine()).is_err());
    }

    #[test]
    fn truncation_respects_budget_and_preamble() {
        let kv = make_kv();
        let cm = make_cm(kv);
        let preamble = cm.template.preamble_ids();
        let mut ids = preamble.clone();
        ids.extend(std::iter::repeat(70u32).take(5000));
        let out = cm.truncate_to_budget(ids, 100);
        assert_eq!(out.len(), 100);
        assert_eq!(&out[..preamble.len()], &preamble[..]);
        assert_eq!(out[99], 70);
    }

    #[test]
    fn wrong_model_rejected() {
        let cm = make_cm(make_kv());
        let req = CompletionRequest::new("other/model", "hi", 1, ContextMode::Tokenized);
        assert!(cm.handle(&req, &engine()).is_err());
    }

    #[test]
    fn async_update_equals_sync_assembly() {
        // After quiesce, the stored tokenized context must equal what the
        // raw transcript would tokenize to — the invariant that lets a
        // *different* node continue the session.
        let kv = make_kv();
        let cm = make_cm(kv.clone());
        let e = engine();
        let req = CompletionRequest::new(MODEL, "What is SLAM?", 1, ContextMode::Tokenized);
        let r = cm.handle(&req, &e).unwrap();
        cm.quiesce();
        let key = session_key(&r.user_id, &r.session_id);
        let entry = kv.get(MODEL, &key).unwrap();
        let (StoredContext::Tokens(stored), _) = StoredContext::from_kv(&entry.value).unwrap()
        else {
            panic!("expected tokens")
        };
        let transcript = format!(
            "{}{}{}",
            cm.template.preamble_text(),
            cm.template.user_turn_text("What is SLAM?"),
            cm.template.close_text(&r.text),
        );
        assert_eq!(stored, cm.template.encode_transcript(&transcript));
    }
}
