//! Runtime lock-order verification ("lockdep") for the crate's named
//! locks.
//!
//! [`OrderedMutex`] and [`OrderedRwLock`] are drop-in wrappers around the
//! std primitives that, **in debug builds only**, maintain a per-thread
//! stack of held locks plus one process-global acquisition-order graph,
//! and panic the moment a thread:
//!
//! - acquires any lock while holding a **terminal** lock (the store
//!   stripes — the crate-wide rule is "a thread holding a shard lock
//!   takes no other lock");
//! - acquires two locks of the same class out of **rank order** (the
//!   multi-stripe readers take stripes in index order only);
//! - closes a **cycle** in the global acquisition graph — the classic
//!   AB/BA inversion, caught even when the two orders happen on
//!   different threads in different tests, long before an actual
//!   deadlock needs the unlucky interleaving.
//!
//! With `debug_assertions` off (the release profile) the wrappers
//! compile down to the bare std lock: no thread-local, no graph, no
//! branches — release binaries and wire bytes are untouched.
//!
//! Every lock class the static analyzer (`crate::analysis`) knows about
//! is predeclared in [`classes`] with its level in the documented lock
//! hierarchy (low level = outermost). The levels are documentation and
//! diagnostics; enforcement is purely observational (graph cycles), so a
//! legitimate new nesting never trips it — only a contradictory pair
//! does.

use std::fmt;
use std::sync::{
    Condvar, LockResult, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

/// Identity of a family of locks for ordering purposes (all 16 store
/// stripes share one class, distinguished by rank).
pub struct LockClass {
    name: &'static str,
    level: u16,
    terminal: bool,
}

impl LockClass {
    /// A non-terminal class at `level` in the documented hierarchy
    /// (lower level = taken first / outermost).
    pub const fn new(name: &'static str, level: u16) -> LockClass {
        LockClass {
            name,
            level,
            terminal: false,
        }
    }

    /// A terminal class: while any lock of this class is held the thread
    /// may take nothing except a higher-rank lock of the same class.
    pub const fn terminal(name: &'static str, level: u16) -> LockClass {
        LockClass {
            name,
            level,
            terminal: true,
        }
    }

    /// Class name as it appears in panics and lint findings.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Position in the documented lock hierarchy (low = outermost).
    pub fn level(&self) -> u16 {
        self.level
    }

    /// Whether this class is terminal (innermost; nothing nests under it).
    pub fn is_terminal(&self) -> bool {
        self.terminal
    }
}

impl fmt::Debug for LockClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LockClass")
            .field("name", &self.name)
            .field("level", &self.level)
            .field("terminal", &self.terminal)
            .finish()
    }
}

/// The crate's named lock classes, one static per family, ordered by
/// level: outermost (taken first) at the top. This is the machine
/// countersignature of the "Concurrency invariants" section in
/// ARCHITECTURE.md.
pub mod classes {
    use super::LockClass;

    /// Membership subscriber list (snapshot-then-invoke; callbacks never
    /// run under it).
    pub static MEMBERSHIP_SUBSCRIBERS: LockClass = LockClass::new("membership.subscribers", 10);
    /// Membership member table (held across ring construction only).
    pub static MEMBERSHIP_MEMBERS: LockClass = LockClass::new("membership.members", 11);
    /// Hinted-handoff per-peer queues (eviction hooks run after release).
    pub static HINT_QUEUES: LockClass = LockClass::new("hints.queues", 20);
    /// Hinted-handoff down-peer set.
    pub static HINT_DOWN: LockClass = LockClass::new("hints.down", 21);
    /// Hinted-handoff restart-forwarding table.
    pub static HINT_FORWARDS: LockClass = LockClass::new("hints.forwards", 22);
    /// Hinted-handoff eviction-hook slot (cloned out before invoking).
    pub static HINT_EVICT: LockClass = LockClass::new("hints.on_evict", 23);
    /// Replicator job queue (the Condvar-coupled sender queue).
    pub static REPL_QUEUE: LockClass = LockClass::new("replicator.queue", 30);
    /// Inference-scheduler admission queue (Condvar-coupled; the batch
    /// loop holds it only to drain admitted jobs — never across prefill
    /// or a decode step).
    pub static SCHED_ADMISSION: LockClass = LockClass::new("scheduler.admission", 35);
    /// Peer-pool idle connection map (never held across connect or IO).
    pub static POOL_IDLE: LockClass = LockClass::new("pool.idle", 40);
    /// Merkle forest tree table (held across the store digest read).
    pub static MERKLE_TREES: LockClass = LockClass::new("merkle.trees", 50);
    /// WAL writer state (the snapshotter holds it across the store dump).
    pub static STORAGE_WAL: LockClass = LockClass::new("storage.wal", 60);
    /// Store stripes — terminal: a thread holding a shard lock takes no
    /// other lock; multi-stripe readers go in index (= rank) order.
    pub static STORE_STRIPE: LockClass = LockClass::terminal("store.stripe", 70);
}

#[cfg(debug_assertions)]
mod lockdep {
    use super::LockClass;
    use std::cell::RefCell;
    use std::collections::{HashMap, HashSet};
    use std::sync::{Mutex, OnceLock};

    thread_local! {
        /// Stack of (class, rank) pairs this thread currently holds.
        static HELD: RefCell<Vec<(&'static LockClass, u32)>> = const { RefCell::new(Vec::new()) };
    }

    /// Process-global acquisition-order graph: an edge `a -> b` means
    /// some thread acquired a `b` lock while holding an `a` lock.
    static EDGES: OnceLock<Mutex<HashMap<&'static str, HashSet<&'static str>>>> = OnceLock::new();

    fn edges() -> &'static Mutex<HashMap<&'static str, HashSet<&'static str>>> {
        EDGES.get_or_init(|| Mutex::new(HashMap::new()))
    }

    fn reaches(
        graph: &HashMap<&'static str, HashSet<&'static str>>,
        from: &'static str,
        to: &'static str,
    ) -> bool {
        let mut stack = vec![from];
        let mut seen: HashSet<&'static str> = HashSet::new();
        while let Some(node) = stack.pop() {
            if node == to {
                return true;
            }
            if seen.insert(node) {
                if let Some(next) = graph.get(node) {
                    stack.extend(next.iter().copied());
                }
            }
        }
        false
    }

    pub fn acquired(class: &'static LockClass, rank: u32) {
        HELD.with(|h| {
            {
                let held = h.borrow();
                for &(held_class, held_rank) in held.iter() {
                    if std::ptr::eq(held_class, class) {
                        assert!(
                            rank > held_rank,
                            "lockdep: same-class locks must be taken in increasing rank \
                             order: acquiring {} rank {rank} while rank {held_rank} is held",
                            class.name(),
                        );
                    } else if held_class.is_terminal() {
                        panic!(
                            "lockdep: {} acquired while terminal lock {} is held — a thread \
                             holding a {} lock takes no other lock",
                            class.name(),
                            held_class.name(),
                            held_class.name(),
                        );
                    } else {
                        let mut graph = edges().lock().unwrap();
                        let inserted = graph
                            .entry(held_class.name())
                            .or_default()
                            .insert(class.name());
                        if inserted && reaches(&graph, class.name(), held_class.name()) {
                            panic!(
                                "lockdep: lock-order inversion: acquiring {} (level {}) while \
                                 holding {} (level {}), but the opposite order was already \
                                 observed",
                                class.name(),
                                class.level(),
                                held_class.name(),
                                held_class.level(),
                            );
                        }
                    }
                }
            }
            h.borrow_mut().push((class, rank));
        });
    }

    pub fn released(class: &'static LockClass, rank: u32) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(pos) = held
                .iter()
                .rposition(|&(c, r)| std::ptr::eq(c, class) && r == rank)
            {
                held.remove(pos);
            }
        });
    }
}

#[cfg(debug_assertions)]
fn note_acquired(class: &'static LockClass, rank: u32) {
    lockdep::acquired(class, rank);
}

#[cfg(not(debug_assertions))]
fn note_acquired(_class: &'static LockClass, _rank: u32) {}

#[cfg(debug_assertions)]
fn note_released(class: &'static LockClass, rank: u32) {
    lockdep::released(class, rank);
}

#[cfg(not(debug_assertions))]
fn note_released(_class: &'static LockClass, _rank: u32) {}

/// [`Mutex`] wrapper that checks lock ordering in debug builds. The
/// order check runs *before* blocking on the inner mutex, so a would-be
/// deadlock panics with both class names instead of hanging.
pub struct OrderedMutex<T> {
    class: &'static LockClass,
    rank: u32,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// Wrap `value` under `class` at rank 0.
    pub const fn new(class: &'static LockClass, value: T) -> OrderedMutex<T> {
        OrderedMutex {
            class,
            rank: 0,
            inner: Mutex::new(value),
        }
    }

    /// Wrap `value` under `class` at `rank` — same-class locks may only
    /// be nested in strictly increasing rank order.
    pub const fn with_rank(class: &'static LockClass, rank: u32, value: T) -> OrderedMutex<T> {
        OrderedMutex {
            class,
            rank,
            inner: Mutex::new(value),
        }
    }

    /// Acquire, recording the hold on this thread's lockdep stack.
    /// Poisoning behaves exactly like [`Mutex::lock`].
    pub fn lock(&self) -> LockResult<OrderedMutexGuard<'_, T>> {
        note_acquired(self.class, self.rank);
        match self.inner.lock() {
            Ok(guard) => Ok(OrderedMutexGuard {
                owner: self,
                inner: Some(guard),
            }),
            Err(poisoned) => Err(PoisonError::new(OrderedMutexGuard {
                owner: self,
                inner: Some(poisoned.into_inner()),
            })),
        }
    }
}

impl<T> fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("class", &self.class.name())
            .finish_non_exhaustive()
    }
}

/// Guard returned by [`OrderedMutex::lock`]; releases the lockdep hold
/// on drop.
pub struct OrderedMutexGuard<'a, T> {
    owner: &'a OrderedMutex<T>,
    /// `None` only transiently inside [`OrderedMutexGuard::wait`].
    inner: Option<MutexGuard<'a, T>>,
}

impl<'a, T> OrderedMutexGuard<'a, T> {
    /// Block on `cvar`, releasing the mutex (and the lockdep hold) for
    /// the duration of the wait and re-acquiring both on wake — the
    /// ordered equivalent of [`Condvar::wait`].
    pub fn wait(mut self, cvar: &Condvar) -> LockResult<OrderedMutexGuard<'a, T>> {
        let owner = self.owner;
        let guard = self.inner.take().expect("guard present outside wait");
        note_released(owner.class, owner.rank);
        match cvar.wait(guard) {
            Ok(guard) => {
                note_acquired(owner.class, owner.rank);
                Ok(OrderedMutexGuard {
                    owner,
                    inner: Some(guard),
                })
            }
            Err(poisoned) => {
                note_acquired(owner.class, owner.rank);
                Err(PoisonError::new(OrderedMutexGuard {
                    owner,
                    inner: Some(poisoned.into_inner()),
                }))
            }
        }
    }
}

impl<T> std::ops::Deref for OrderedMutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard present outside wait")
    }
}

impl<T> std::ops::DerefMut for OrderedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard present outside wait")
    }
}

impl<T> Drop for OrderedMutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            note_released(self.owner.class, self.owner.rank);
        }
    }
}

/// [`RwLock`] wrapper that checks lock ordering in debug builds. Reads
/// and writes count the same for ordering purposes (either holds the
/// stripe against the other side).
pub struct OrderedRwLock<T> {
    class: &'static LockClass,
    rank: u32,
    inner: RwLock<T>,
}

impl<T> OrderedRwLock<T> {
    /// Wrap `value` under `class` at rank 0.
    pub const fn new(class: &'static LockClass, value: T) -> OrderedRwLock<T> {
        OrderedRwLock {
            class,
            rank: 0,
            inner: RwLock::new(value),
        }
    }

    /// Wrap `value` under `class` at `rank` (stripe index for the store
    /// shards — index order is rank order).
    pub const fn with_rank(class: &'static LockClass, rank: u32, value: T) -> OrderedRwLock<T> {
        OrderedRwLock {
            class,
            rank,
            inner: RwLock::new(value),
        }
    }

    /// Acquire shared, recording the hold on this thread's lockdep
    /// stack. Poisoning behaves exactly like [`RwLock::read`].
    pub fn read(&self) -> LockResult<OrderedRwLockReadGuard<'_, T>> {
        note_acquired(self.class, self.rank);
        match self.inner.read() {
            Ok(guard) => Ok(OrderedRwLockReadGuard {
                owner: self,
                inner: guard,
            }),
            Err(poisoned) => Err(PoisonError::new(OrderedRwLockReadGuard {
                owner: self,
                inner: poisoned.into_inner(),
            })),
        }
    }

    /// Acquire exclusive, recording the hold on this thread's lockdep
    /// stack. Poisoning behaves exactly like [`RwLock::write`].
    pub fn write(&self) -> LockResult<OrderedRwLockWriteGuard<'_, T>> {
        note_acquired(self.class, self.rank);
        match self.inner.write() {
            Ok(guard) => Ok(OrderedRwLockWriteGuard {
                owner: self,
                inner: guard,
            }),
            Err(poisoned) => Err(PoisonError::new(OrderedRwLockWriteGuard {
                owner: self,
                inner: poisoned.into_inner(),
            })),
        }
    }
}

impl<T> fmt::Debug for OrderedRwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedRwLock")
            .field("class", &self.class.name())
            .finish_non_exhaustive()
    }
}

/// Shared guard returned by [`OrderedRwLock::read`].
pub struct OrderedRwLockReadGuard<'a, T> {
    owner: &'a OrderedRwLock<T>,
    inner: RwLockReadGuard<'a, T>,
}

impl<T> std::ops::Deref for OrderedRwLockReadGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> Drop for OrderedRwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        note_released(self.owner.class, self.owner.rank);
    }
}

/// Exclusive guard returned by [`OrderedRwLock::write`].
pub struct OrderedRwLockWriteGuard<'a, T> {
    owner: &'a OrderedRwLock<T>,
    inner: RwLockWriteGuard<'a, T>,
}

impl<T> std::ops::Deref for OrderedRwLockWriteGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> std::ops::DerefMut for OrderedRwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for OrderedRwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        note_released(self.owner.class, self.owner.rank);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    // Dedicated classes so these tests cannot contaminate the global
    // graph edges of the production classes (tests share one process).
    static T_OUTER: LockClass = LockClass::new("test.sync.outer", 1);
    static T_INNER: LockClass = LockClass::new("test.sync.inner", 2);
    static T_TERM: LockClass = LockClass::terminal("test.sync.term", 3);
    static T_AFTER_TERM: LockClass = LockClass::new("test.sync.after_term", 4);
    static T_RANKED: LockClass = LockClass::new("test.sync.ranked", 5);
    static T_WAIT: LockClass = LockClass::new("test.sync.wait", 6);

    #[test]
    fn consistent_nesting_is_silent() {
        let a = OrderedMutex::new(&T_OUTER, 1u32);
        let b = OrderedMutex::new(&T_INNER, 2u32);
        for _ in 0..3 {
            let ga = a.lock().unwrap();
            let gb = b.lock().unwrap();
            assert_eq!(*ga + *gb, 3);
        }
    }

    #[test]
    #[should_panic(expected = "lock-order inversion")]
    fn ab_ba_inversion_panics() {
        static A: LockClass = LockClass::new("test.sync.ab_a", 1);
        static B: LockClass = LockClass::new("test.sync.ab_b", 2);
        let a = OrderedMutex::new(&A, ());
        let b = OrderedMutex::new(&B, ());
        {
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
        }
        // The reversed order closes the cycle; lockdep panics before
        // blocking, whether or not the deadlock interleaving ever fires.
        let _gb = b.lock().unwrap();
        let _ga = a.lock().unwrap();
    }

    #[test]
    #[should_panic(expected = "takes no other lock")]
    fn terminal_lock_admits_nothing_under_it() {
        let stripe = OrderedRwLock::new(&T_TERM, ());
        let other = OrderedMutex::new(&T_AFTER_TERM, ());
        let _g = stripe.write().unwrap();
        let _h = other.lock().unwrap();
    }

    #[test]
    fn same_class_in_rank_order_is_allowed() {
        let stripes: Vec<OrderedRwLock<u32>> = (0..4)
            .map(|i| OrderedRwLock::with_rank(&T_RANKED, i, i))
            .collect();
        let guards: Vec<_> = stripes.iter().map(|s| s.read().unwrap()).collect();
        let total: u32 = guards.iter().map(|g| **g).sum();
        assert_eq!(total, 6);
    }

    #[test]
    #[should_panic(expected = "increasing rank order")]
    fn same_class_out_of_rank_order_panics() {
        static RANKED: LockClass = LockClass::new("test.sync.rank_rev", 5);
        let lo = OrderedMutex::with_rank(&RANKED, 0, ());
        let hi = OrderedMutex::with_rank(&RANKED, 1, ());
        let _g_hi = hi.lock().unwrap();
        let _g_lo = lo.lock().unwrap();
    }

    #[test]
    fn guard_wait_releases_and_reacquires() {
        let pair = Arc::new((OrderedMutex::new(&T_WAIT, false), Condvar::new()));
        let signaller = pair.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (lock, cvar) = &*signaller;
            *lock.lock().unwrap() = true;
            cvar.notify_all();
        });
        let (lock, cvar) = &*pair;
        let mut ready = lock.lock().unwrap();
        while !*ready {
            ready = ready.wait(cvar).unwrap();
        }
        assert!(*ready);
        drop(ready);
        t.join().unwrap();
    }
}
