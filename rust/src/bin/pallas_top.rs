//! `pallas_top` — live fleet health table over a running cluster.
//!
//! Usage:
//!
//! ```text
//! cargo run --bin pallas_top -- [NAME=HOST:PORT ...] [options]
//!
//!   --poll-ms N   refresh period (default 1000)
//!   --out PATH    health CSV to append (default results/fleet_health.csv)
//!   --once        poll a single time and exit
//! ```
//!
//! Each positional argument is a node API endpoint, `host:port` or
//! `name=host:port` (the names `discedge cluster` prints at startup).
//! Every refresh polls each node's `GET /status` + `GET /metrics`,
//! renders the fleet table (windowed request rates and percentiles,
//! hint backlog, replication lag, anti-entropy age, wire-byte rates),
//! and appends one CSV row per node to `--out`.

use std::net::SocketAddr;
use std::process::ExitCode;

use discedge::cli::Args;
use discedge::obs::fleet::{FleetAggregator, FleetConfig};

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("pallas-top: bad arguments: {e}");
            return ExitCode::from(2);
        }
    };
    let mut endpoints: Vec<String> = Vec::new();
    if let Some(c) = &args.command {
        endpoints.push(c.clone());
    }
    endpoints.extend(args.positional.iter().cloned());
    if endpoints.is_empty() {
        eprintln!(
            "usage: pallas_top NAME=HOST:PORT [NAME=HOST:PORT ...] \
             [--poll-ms N] [--out CSV] [--once]"
        );
        return ExitCode::from(2);
    }
    let mut targets: Vec<(String, SocketAddr)> = Vec::new();
    for e in &endpoints {
        let (name, addr) = match e.split_once('=') {
            Some((n, a)) => (n.to_string(), a),
            None => (e.clone(), e.as_str()),
        };
        match addr.parse::<SocketAddr>() {
            Ok(a) => targets.push((name, a)),
            Err(_) => {
                eprintln!("pallas-top: bad endpoint {e} (want name=host:port)");
                return ExitCode::from(2);
            }
        }
    }
    let poll_ms = args.opt_parse_or("poll-ms", 1000u64).unwrap_or(1000);
    let cfg = FleetConfig {
        enabled: true,
        poll_ms,
        out: std::path::PathBuf::from(args.opt_or("out", "results/fleet_health.csv")),
    };
    let once = args.flag("once");
    let agg = FleetAggregator::new(&cfg, targets);
    loop {
        match agg.poll_once() {
            Ok(snap) => {
                print!("{}", FleetAggregator::render_table(&snap));
                println!();
            }
            Err(e) => eprintln!("pallas-top: poll failed: {e}"),
        }
        if once {
            return ExitCode::SUCCESS;
        }
        std::thread::sleep(std::time::Duration::from_millis(poll_ms.max(1)));
    }
}
