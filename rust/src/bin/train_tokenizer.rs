//! `train_tokenizer` — trains the production BPE vocabulary on the
//! deterministic corpus and writes `artifacts/tokenizer.json`.
//! Invoked by `make artifacts`.

use discedge::cli::Args;

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let dir = std::path::PathBuf::from(args.opt_or("out-dir", "artifacts"));
    let vocab_size: usize = args.opt_parse_or("vocab-size", 4096).unwrap_or(4096);
    let t = std::time::Instant::now();
    match discedge::server::train_production_tokenizer(&dir, vocab_size) {
        Ok(vocab) => {
            println!(
                "trained tokenizer: {} merges, vocab {}, {:.2}s -> {}",
                vocab.merges().len(),
                vocab.size(),
                t.elapsed().as_secs_f64(),
                dir.join("tokenizer.json").display()
            );
        }
        Err(e) => {
            eprintln!("tokenizer training failed: {e}");
            std::process::exit(1);
        }
    }
}
