//! `pallas_lint` — static concurrency & invariant analysis for this
//! crate (see `discedge::analysis` and `docs/ARCHITECTURE.md`,
//! "Concurrency invariants").
//!
//! Usage:
//!
//! ```text
//! cargo run --bin pallas_lint -- [PATH ...] [--json] [--allow FILE]
//! ```
//!
//! Each PATH is a directory to scan recursively or a single `.rs` file
//! (how the bad fixtures under `src/analysis/fixtures/` are linted).
//! With no PATH, `src` (when run from `rust/`) or `rust/src` (from the
//! repo root) is scanned. Suppressions load from `lint-allow.txt` next
//! to the scanned `src` unless `--allow` overrides. Exit status is 0
//! when no findings survive the allowlist, 1 otherwise, 2 on I/O
//! errors.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use discedge::analysis::{self, Allowlist, Finding};
use discedge::cli::Args;
use discedge::json::Value;

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("pallas-lint: bad arguments: {e}");
            return ExitCode::from(2);
        }
    };

    let mut paths: Vec<String> = Vec::new();
    if let Some(c) = &args.command {
        paths.push(c.clone());
    }
    paths.extend(args.positional.iter().cloned());
    // The tiny cli parser treats `--json PATH` as an option with a
    // value; recover the path and keep --json a pure flag.
    let json_out = args.flag("json") || args.opt("json").is_some();
    if let Some(v) = args.opt("json") {
        paths.push(v.to_string());
    }
    if paths.is_empty() {
        let default = if Path::new("src/lib.rs").exists() {
            "src"
        } else {
            "rust/src"
        };
        paths.push(default.to_string());
    }

    let mut files: Vec<PathBuf> = Vec::new();
    for p in &paths {
        let path = PathBuf::from(p);
        if path.is_dir() {
            files.extend(analysis::collect_rs_files(&path));
        } else {
            files.push(path);
        }
    }
    if files.is_empty() {
        eprintln!("pallas-lint: nothing to scan under {paths:?}");
        return ExitCode::from(2);
    }

    let allow = match args.opt("allow") {
        Some(p) => Allowlist::load(Path::new(p)),
        None => Allowlist::load(&default_allow_path(&paths)),
    };

    let all = match analysis::run_files(&files) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("pallas-lint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };
    let total = all.len();
    let findings = allow.filter(all);
    let suppressed = total - findings.len();

    if json_out {
        println!("{}", render_json(&findings, suppressed));
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        }
        if findings.is_empty() {
            println!("pallas-lint: clean ({} files, {suppressed} suppressed)", files.len());
        } else {
            println!("pallas-lint: {} finding(s), {suppressed} suppressed", findings.len());
        }
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

/// `lint-allow.txt` next to the first scanned `src` directory: the
/// conventional location is `rust/lint-allow.txt`, sibling of
/// `rust/src`.
fn default_allow_path(paths: &[String]) -> PathBuf {
    for p in paths {
        let parent = Path::new(p).parent().unwrap_or_else(|| Path::new("."));
        let candidate = parent.join("lint-allow.txt");
        if candidate.exists() {
            return candidate;
        }
    }
    PathBuf::from("lint-allow.txt")
}

fn render_json(findings: &[Finding], suppressed: usize) -> String {
    let mut arr: Vec<Value> = Vec::new();
    for f in findings {
        let obj = Value::obj()
            .set("rule", f.rule)
            .set("file", f.file.as_str())
            .set("line", f.line)
            .set("message", f.message.as_str());
        arr.push(obj);
    }
    Value::obj()
        .set("findings", Value::Array(arr))
        .set("suppressed", suppressed)
        .to_json()
}
