//! Minimal-but-complete JSON implementation (serde_json substitute).
//!
//! The offline crate registry only vendors the `xla` closure, so wire
//! serialization for the `/completion` API, the KV replication protocol, and
//! config files is built on this module. It implements the full JSON grammar
//! (RFC 8259): objects, arrays, strings with escapes (including `\uXXXX`
//! surrogate pairs), integer and floating-point numbers, booleans, null.
//!
//! Token-id arrays dominate DisCEdge payloads, so [`Value::IntArray`] keeps a
//! dedicated compact representation that serializes identically to a JSON
//! array of integers but avoids boxing every id as a `Value`.

use std::collections::BTreeMap;
use std::fmt;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Integral number (fits in i64).
    Int(i64),
    /// Floating-point number.
    Float(f64),
    /// String.
    Str(String),
    /// Array of values.
    Array(Vec<Value>),
    /// Compact array of integers (token ids). Serializes as a JSON array.
    IntArray(Vec<u32>),
    /// Object with deterministic (sorted) key order.
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Build an empty object.
    pub fn obj() -> Value {
        Value::Object(BTreeMap::new())
    }

    /// Insert into an object value (panics if not an object; builder-style).
    pub fn set(mut self, key: &str, val: impl Into<Value>) -> Value {
        match &mut self {
            Value::Object(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Value::set on non-object"),
        }
        self
    }

    /// Get a field of an object.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer content (also truncates floats that are integral).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    /// u64 convenience accessor.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    /// Float content (ints widen).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// Bool content.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Token-id array view: accepts both `IntArray` and plain arrays of ints.
    pub fn as_int_array(&self) -> Option<Vec<u32>> {
        match self {
            Value::IntArray(v) => Some(v.clone()),
            Value::Array(v) => v
                .iter()
                .map(|x| x.as_i64().and_then(|i| u32::try_from(i).ok()))
                .collect::<Option<Vec<u32>>>(),
            _ => None,
        }
    }

    /// Object map, if this is an object.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Required string field of an object.
    pub fn req_str(&self, key: &str) -> Result<String> {
        self.get(key)
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| Error::Json(format!("missing string field `{key}`")))
    }

    /// Required integer field of an object.
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(|v| v.as_u64())
            .ok_or_else(|| Error::Json(format!("missing integer field `{key}`")))
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(self.estimate_len());
        self.write_json(&mut out);
        out
    }

    fn estimate_len(&self) -> usize {
        match self {
            Value::Null => 4,
            Value::Bool(_) => 5,
            Value::Int(_) => 12,
            Value::Float(_) => 18,
            Value::Str(s) => s.len() + 2,
            Value::Array(v) => 2 + v.iter().map(|x| x.estimate_len() + 1).sum::<usize>(),
            Value::IntArray(v) => 2 + v.len() * 6,
            Value::Object(m) => {
                2 + m
                    .iter()
                    .map(|(k, v)| k.len() + 4 + v.estimate_len())
                    .sum::<usize>()
            }
        }
    }

    fn write_json(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Int(i) => {
                let mut buf = itoa_buf();
                out.push_str(write_i64(*i, &mut buf));
            }
            Value::Float(f) => {
                if f.is_finite() {
                    // Shortest round-trip representation Rust provides.
                    let s = format!("{f}");
                    // Ensure it parses back as a float, not an int ("1" -> "1.0").
                    if s.bytes().all(|b| b.is_ascii_digit() || b == b'-') {
                        out.push_str(&s);
                        out.push_str(".0");
                    } else {
                        out.push_str(&s);
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Array(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_json(out);
                }
                out.push(']');
            }
            Value::IntArray(v) => {
                out.push('[');
                let mut buf = itoa_buf();
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(write_i64(*x as i64, &mut buf));
                }
                out.push(']');
            }
            Value::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_json(out);
                }
                out.push('}');
            }
        }
    }
}

/// Fixed buffer for integer formatting without heap allocation.
fn itoa_buf() -> [u8; 20] {
    [0u8; 20]
}

/// Format an i64 into the buffer, returning the string slice.
fn write_i64(mut v: i64, buf: &mut [u8; 20]) -> &str {
    if v == 0 {
        return "0";
    }
    let neg = v < 0;
    let mut i = buf.len();
    // Work with negative magnitudes to handle i64::MIN.
    if !neg {
        v = -v;
    }
    while v != 0 {
        i -= 1;
        // (v % 10) is <= 0 here
        let digit = (-(v % 10)) as u8;
        buf[i] = b'0' + digit;
        v /= 10;
    }
    if neg {
        i -= 1;
        buf[i] = b'-';
    }
    std::str::from_utf8(&buf[i..]).unwrap()
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    escape_fragment(s, out);
    out.push('"');
}

/// Escape `s` into `out` with the string-literal escaping rules, minus
/// the surrounding quotes. Escaping is context-free per character, so
/// escaping fragments and concatenating equals escaping the
/// concatenation — the property the streamed `/completion` body writer
/// relies on to frame generated text incrementally while staying
/// byte-identical to the buffered [`Value::to_json`] serialization.
pub(crate) fn escape_fragment(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Value {
        Value::Int(v)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Value {
        Value::Int(v as i64)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Value {
        Value::Int(v as i64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Value {
        Value::Int(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::Float(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Value {
        Value::Bool(v)
    }
}
impl From<Vec<u32>> for Value {
    fn from(v: Vec<u32>) -> Value {
        Value::IntArray(v)
    }
}
impl From<&[u32]> for Value {
    fn from(v: &[u32]) -> Value {
        Value::IntArray(v.to_vec())
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Value {
        Value::Array(v)
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_json())
    }
}

/// Parse a JSON document from a string.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Json(format!(
            "trailing garbage at byte {} of {}",
            p.pos,
            p.bytes.len()
        )));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte `{}`", c as char))),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(m)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(Vec::new()));
        }
        // Fast path: arrays of non-negative integers parse into IntArray.
        let mut ints: Option<Vec<u32>> = Some(Vec::new());
        let mut vals: Vec<Value> = Vec::new();
        loop {
            self.skip_ws();
            let v = self.value()?;
            match (&mut ints, &v) {
                (Some(arr), Value::Int(i)) if *i >= 0 && *i <= u32::MAX as i64 => {
                    arr.push(*i as u32);
                }
                (Some(arr), _) => {
                    // Demote accumulated ints into generic values.
                    vals = arr.iter().map(|&x| Value::Int(x as i64)).collect();
                    vals.push(v);
                    ints = None;
                }
                (None, _) => vals.push(v),
            }
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    return Ok(match ints {
                        Some(arr) => Value::IntArray(arr),
                        None => Value::Array(vals),
                    });
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{08}'),
                    b'f' => s.push('\u{0c}'),
                    b'u' => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: must be followed by \uDCxx.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad surrogate pair"))?,
                            );
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    c => return Err(self.err(&format!("bad escape `\\{}`", c as char))),
                },
                c if c < 0x20 => return Err(self.err("raw control char in string")),
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: validate by re-decoding the slice.
                    let start = self.pos - 1;
                    let width = utf8_width(c).ok_or_else(|| self.err("invalid utf-8"))?;
                    let end = start + width;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        if is_float {
            text.parse::<f64>()
                .map(Value::Float)
                .map_err(|_| self.err("bad float"))
        } else {
            match text.parse::<i64>() {
                Ok(i) => Ok(Value::Int(i)),
                // Overflow: fall back to float like other parsers do.
                Err(_) => text
                    .parse::<f64>()
                    .map(Value::Float)
                    .map_err(|_| self.err("bad int")),
            }
        }
    }
}

fn utf8_width(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Int(42));
        assert_eq!(parse("-7").unwrap(), Value::Int(-7));
        assert_eq!(parse("3.5").unwrap(), Value::Float(3.5));
        assert_eq!(parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[0].as_i64().unwrap(), 1);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn int_array_fast_path() {
        let v = parse("[1,2,3,65535]").unwrap();
        assert_eq!(v, Value::IntArray(vec![1, 2, 3, 65535]));
        assert_eq!(v.as_int_array().unwrap(), vec![1, 2, 3, 65535]);
        // Mixed arrays demote.
        let v = parse("[1, \"x\"]").unwrap();
        assert!(matches!(v, Value::Array(_)));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"c\" \\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" \\ A 😀");
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"messages":[{"content":"hi","role":"user"}],"turn":3}"#,
            r#"[0,1,2,3]"#,
            r#"{"a":-1,"b":true,"c":null,"d":"x\ny"}"#,
            "1.5",
            "\"héllo wörld 日本語\"",
        ];
        for c in cases {
            let v = parse(c).unwrap();
            assert_eq!(parse(&v.to_json()).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn serialize_escapes_control() {
        let v = Value::Str("a\u{01}b".into());
        assert_eq!(v.to_json(), "\"a\\u0001b\"");
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "\"", "tru", "{\"a\" 1}", "1 2", "[01x]", "\x01"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn i64_extremes() {
        assert_eq!(parse("9223372036854775807").unwrap(), Value::Int(i64::MAX));
        assert_eq!(parse("-9223372036854775808").unwrap(), Value::Int(i64::MIN));
        let v = Value::Int(i64::MIN);
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn builder() {
        let v = Value::obj()
            .set("prompt", "hello")
            .set("turn", 4u64)
            .set("context", vec![1u32, 2, 3]);
        let j = v.to_json();
        assert_eq!(j, r#"{"context":[1,2,3],"prompt":"hello","turn":4}"#);
    }

    #[test]
    fn float_format_roundtrips_as_float() {
        let v = Value::Float(2.0);
        assert_eq!(v.to_json(), "2.0");
        assert!(matches!(parse("2.0").unwrap(), Value::Float(_)));
    }

    // ---- property tests (testkit harness) -------------------------------
    // This codec carries every replication, anti-entropy, and WAL payload;
    // the generators below hammer the serialize→parse loop with the
    // shapes the hand-written tests cannot enumerate.

    use crate::testkit::{property, Rng};

    /// A string biased toward everything the escaper must handle: the
    /// two-char escapes, raw control chars, DEL, and multi-byte UTF-8.
    fn nasty_string(rng: &mut Rng) -> String {
        let mut s = rng.text(20);
        for _ in 0..rng.range(0, 6) {
            s.push(*rng.pick(&[
                '"', '\\', '/', '\n', '\r', '\t', '\u{08}', '\u{0c}', '\u{01}', '\u{1f}',
                '\u{7f}', 'é', '日', '😀', '\u{fffd}',
            ]));
        }
        s
    }

    /// Random document tree. Arrays always carry a non-integer element so
    /// reparsing cannot re-promote them onto the `IntArray` fast path
    /// (token arrays are generated as `IntArray` directly) — equality
    /// after a round trip is then exact.
    fn gen_value(rng: &mut Rng, depth: usize) -> Value {
        match rng.below(if depth == 0 { 6 } else { 8 }) {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Int(match rng.below(6) {
                0 => i64::MIN,
                1 => i64::MAX,
                2 => 0,
                3 => -1,
                _ => rng.next_u64() as i64,
            }),
            3 => Value::Float(rng.normal() * 1e3),
            4 => Value::Str(nasty_string(rng)),
            // Non-empty: `[]` parses as `Array`, not `IntArray`, by design.
            5 => Value::IntArray(
                (0..rng.range(1, 6)).map(|_| rng.next_u64() as u32).collect(),
            ),
            6 => {
                let mut xs: Vec<Value> = (0..rng.range(0, 4))
                    .map(|_| gen_value(rng, depth - 1))
                    .collect();
                xs.push(Value::Str(nasty_string(rng)));
                Value::Array(xs)
            }
            _ => {
                let mut obj = Value::obj();
                for _ in 0..rng.range(0, 5) {
                    obj = obj.set(&nasty_string(rng), gen_value(rng, depth - 1));
                }
                obj
            }
        }
    }

    #[test]
    fn prop_random_documents_roundtrip() {
        property(300, |rng| {
            let v = gen_value(rng, 3);
            let j = v.to_json();
            let back = parse(&j).unwrap_or_else(|e| panic!("reparse of {j}: {e}"));
            assert_eq!(back, v, "doc {j}");
        });
    }

    #[test]
    fn prop_string_escapes_roundtrip() {
        property(500, |rng| {
            let s = nasty_string(rng);
            let v = Value::Str(s.clone());
            assert_eq!(parse(&v.to_json()).unwrap().as_str().unwrap(), s);
        });
    }

    #[test]
    fn prop_i64_boundaries_roundtrip() {
        property(500, |rng| {
            let v = match rng.below(8) {
                0 => i64::MIN,
                1 => i64::MAX,
                2 => i64::MIN + 1,
                3 => i64::MAX - 1,
                4 => 0,
                5 => -1,
                _ => rng.next_u64() as i64,
            };
            assert_eq!(parse(&Value::Int(v).to_json()).unwrap(), Value::Int(v), "{v}");
        });
    }

    #[test]
    fn prop_deep_nesting_roundtrips() {
        property(20, |rng| {
            let depth = rng.range(30, 80);
            let mut v = gen_value(rng, 1);
            for _ in 0..depth {
                v = if rng.chance(0.5) {
                    Value::Array(vec![v, Value::Bool(true)])
                } else {
                    Value::obj().set("inner", v)
                };
            }
            assert_eq!(parse(&v.to_json()).unwrap(), v);
        });
    }

    #[test]
    fn prop_trailing_garbage_rejected() {
        property(300, |rng| {
            let v = gen_value(rng, 2);
            let j = v.to_json();
            let tail = ["x", "1", "{}", "null", ",", "]"][rng.range(0, 6)];
            let doc = format!("{j} {tail}");
            assert!(parse(&doc).is_err(), "accepted trailing garbage: {doc}");
        });
    }
}
