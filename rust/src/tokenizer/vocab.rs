//! Vocabulary model + JSON (de)serialization for the BPE tokenizer.
//!
//! The on-disk format (`artifacts/tokenizer.json`) stores only the merge
//! list; token byte strings are reconstructed by replaying merges, so the
//! file stays small and canonical.

use std::collections::BTreeMap;
use std::path::Path;

use super::SPECIAL_TOKENS;
use crate::json::{self, Value};
use crate::{Error, Result};

/// A byte-level BPE vocabulary: 256 byte tokens, learned merges, and
/// special tokens pinned to the top ids of the configured size.
#[derive(Debug, Clone, PartialEq)]
pub struct Vocab {
    /// Configured vocabulary size (embedding table size on the model side).
    size: usize,
    /// Merge rules in rank order; rank r creates id `256 + r`.
    merges: Vec<(u32, u32)>,
    /// Byte expansion of every non-special token id.
    token_bytes: Vec<Vec<u8>>,
    /// Special name -> id.
    specials: BTreeMap<String, u32>,
}

impl Vocab {
    /// Build from a merge list. Specials occupy ids
    /// `size - SPECIAL_TOKENS.len() .. size`.
    pub fn from_merges(size: usize, merges: Vec<(u32, u32)>) -> Vocab {
        assert!(256 + merges.len() + SPECIAL_TOKENS.len() <= size);
        let mut token_bytes: Vec<Vec<u8>> = (0u16..256).map(|b| vec![b as u8]).collect();
        for &(a, b) in &merges {
            let mut bytes = token_bytes[a as usize].clone();
            bytes.extend_from_slice(&token_bytes[b as usize]);
            token_bytes.push(bytes);
        }
        let mut specials = BTreeMap::new();
        for (i, name) in SPECIAL_TOKENS.iter().enumerate() {
            specials.insert(
                name.to_string(),
                (size - SPECIAL_TOKENS.len() + i) as u32,
            );
        }
        Vocab {
            size,
            merges,
            token_bytes,
            specials,
        }
    }

    /// Configured vocabulary size.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Merge rules in rank order.
    pub fn merges(&self) -> &[(u32, u32)] {
        &self.merges
    }

    /// Byte expansion of a token id (None for specials / out of range).
    pub fn token_bytes(&self, id: u32) -> Option<&[u8]> {
        self.token_bytes.get(id as usize).map(|v| v.as_slice())
    }

    /// Special token id by name.
    pub fn special(&self, name: &str) -> Option<u32> {
        self.specials.get(name).copied()
    }

    /// Special token name by id.
    pub fn special_name(&self, id: u32) -> Option<&str> {
        self.specials
            .iter()
            .find(|(_, &v)| v == id)
            .map(|(k, _)| k.as_str())
    }

    /// Serialize to canonical JSON.
    pub fn to_json(&self) -> String {
        let merges: Vec<Value> = self
            .merges
            .iter()
            .map(|&(a, b)| Value::IntArray(vec![a, b]))
            .collect();
        Value::obj()
            .set("format", "discedge-bpe-v1")
            .set("vocab_size", self.size)
            .set("merges", merges)
            .to_json()
    }

    /// Parse from JSON produced by [`Vocab::to_json`].
    pub fn from_json(text: &str) -> Result<Vocab> {
        let v = json::parse(text)?;
        let fmt = v.req_str("format")?;
        if fmt != "discedge-bpe-v1" {
            return Err(Error::Tokenizer(format!("unknown vocab format {fmt}")));
        }
        let size = v.req_u64("vocab_size")? as usize;
        let merges_v = v
            .get("merges")
            .and_then(|m| m.as_array())
            .ok_or_else(|| Error::Tokenizer("missing merges".into()))?;
        let mut merges = Vec::with_capacity(merges_v.len());
        for m in merges_v {
            let pair = m
                .as_int_array()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| Error::Tokenizer("bad merge entry".into()))?;
            // Merges may only reference byte tokens or earlier merges.
            let next_id = 256 + merges.len() as u32;
            if pair[0] >= next_id || pair[1] >= next_id {
                return Err(Error::Tokenizer(format!(
                    "merge {} references future id {:?}",
                    merges.len(),
                    pair
                )));
            }
            merges.push((pair[0], pair[1]));
        }
        if 256 + merges.len() + SPECIAL_TOKENS.len() > size {
            return Err(Error::Tokenizer("vocab_size too small for merges".into()));
        }
        Ok(Vocab::from_merges(size, merges))
    }

    /// Write to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Vocab> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Tokenizer(format!("read {}: {e}", path.display())))?;
        Vocab::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_tokens_identity() {
        let v = Vocab::from_merges(300, vec![]);
        for b in 0u32..256 {
            assert_eq!(v.token_bytes(b), Some(&[b as u8][..]));
        }
        assert_eq!(v.token_bytes(999), None);
    }

    #[test]
    fn merge_expansion() {
        // 256 = (h, i), 257 = (256, !)
        let v = Vocab::from_merges(
            300,
            vec![(b'h' as u32, b'i' as u32), (256, b'!' as u32)],
        );
        assert_eq!(v.token_bytes(256), Some(&b"hi"[..]));
        assert_eq!(v.token_bytes(257), Some(&b"hi!"[..]));
    }

    #[test]
    fn specials_pinned_to_top() {
        let v = Vocab::from_merges(1000, vec![]);
        let ids: Vec<u32> = SPECIAL_TOKENS
            .iter()
            .map(|s| v.special(s).unwrap())
            .collect();
        assert_eq!(ids, vec![996, 997, 998, 999]);
        assert_eq!(v.special_name(997), Some("<|im_start|>"));
        assert_eq!(v.special("<nope>"), None);
    }

    #[test]
    fn json_roundtrip() {
        let v = Vocab::from_merges(
            512,
            vec![(b't' as u32, b'h' as u32), (256, b'e' as u32)],
        );
        let v2 = Vocab::from_json(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_forward_references() {
        let bad = r#"{"format":"discedge-bpe-v1","vocab_size":512,"merges":[[300,2]]}"#;
        assert!(Vocab::from_json(bad).is_err());
    }

    #[test]
    fn rejects_undersized_vocab() {
        let bad = r#"{"format":"discedge-bpe-v1","vocab_size":257,"merges":[[1,2]]}"#;
        assert!(Vocab::from_json(bad).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("discedge_vocab_test");
        let path = dir.join("tok.json");
        let v = Vocab::from_merges(400, vec![(b'a' as u32, b'b' as u32)]);
        v.save(&path).unwrap();
        assert_eq!(Vocab::load(&path).unwrap(), v);
        std::fs::remove_dir_all(&dir).ok();
    }
}
