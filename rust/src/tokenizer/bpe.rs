//! BPE trainer: learns merge rules from a corpus by iteratively merging the
//! most frequent adjacent token pair, with incremental pair-count updates
//! (the classic Sennrich et al. algorithm, word-type based).

use std::collections::{BTreeMap, HashMap};

use super::{pre_split, vocab::Vocab, SPECIAL_TOKENS};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Target total vocabulary size (bytes + merges + specials).
    pub vocab_size: usize,
    /// Pairs below this count are never merged.
    pub min_pair_count: usize,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            vocab_size: 4096,
            min_pair_count: 2,
        }
    }
}

/// A word type during training: its current token sequence and corpus count.
struct Word {
    ids: Vec<u32>,
    count: usize,
}

/// Train a byte-level BPE vocabulary on `corpus`.
///
/// The returned [`Vocab`] has `cfg.vocab_size` entries unless the corpus
/// runs out of mergeable pairs first (then it is smaller, which is fine —
/// downstream only needs ids to stay below the *configured* size).
pub fn train(corpus: &str, cfg: &TrainConfig) -> Vocab {
    assert!(
        cfg.vocab_size > 256 + SPECIAL_TOKENS.len(),
        "vocab_size must exceed byte tokens + specials"
    );
    let max_merges = cfg.vocab_size - 256 - SPECIAL_TOKENS.len();

    // Collect word types with counts.
    let mut word_counts: HashMap<&str, usize> = HashMap::new();
    for chunk in pre_split(corpus) {
        *word_counts.entry(chunk).or_insert(0) += 1;
    }
    let mut words: Vec<Word> = word_counts
        .into_iter()
        .map(|(w, count)| Word {
            ids: w.bytes().map(|b| b as u32).collect(),
            count,
        })
        .collect();
    // Deterministic order regardless of hash iteration.
    words.sort_by(|a, b| (b.count, &b.ids).cmp(&(a.count, &a.ids)));

    // pair -> total count; pair -> set of word indices containing it.
    let mut pair_counts: BTreeMap<(u32, u32), i64> = BTreeMap::new();
    let mut pair_words: HashMap<(u32, u32), Vec<usize>> = HashMap::new();
    for (wi, w) in words.iter().enumerate() {
        for p in pairs_of(&w.ids) {
            *pair_counts.entry(p).or_insert(0) += w.count as i64;
            pair_words.entry(p).or_default().push(wi);
        }
    }

    let mut merges: Vec<(u32, u32)> = Vec::with_capacity(max_merges);
    while merges.len() < max_merges {
        // Highest count wins; ties break toward the lexicographically
        // smallest pair (BTreeMap iteration order makes this deterministic).
        let best = pair_counts
            .iter()
            .filter(|&(_, &c)| c >= cfg.min_pair_count as i64)
            .max_by(|a, b| a.1.cmp(b.1).then_with(|| b.0.cmp(a.0)))
            .map(|(&p, _)| p);
        let Some(pair) = best else { break };
        let new_id = 256 + merges.len() as u32;
        merges.push(pair);

        // Rewrite every word containing the pair; update pair counts
        // incrementally.
        let affected = pair_words.remove(&pair).unwrap_or_default();
        pair_counts.remove(&pair);
        for wi in affected {
            let w = &mut words[wi];
            if !contains_pair(&w.ids, pair) {
                continue; // stale index entry
            }
            // Remove old pair contributions of this word.
            for p in pairs_of(&w.ids) {
                if let Some(c) = pair_counts.get_mut(&p) {
                    *c -= w.count as i64;
                    if *c <= 0 {
                        pair_counts.remove(&p);
                    }
                }
            }
            apply_merge(&mut w.ids, pair, new_id);
            // Add new contributions.
            for p in pairs_of(&w.ids) {
                *pair_counts.entry(p).or_insert(0) += w.count as i64;
                pair_words.entry(p).or_default().push(wi);
            }
        }
    }

    Vocab::from_merges(cfg.vocab_size, merges)
}

fn pairs_of(ids: &[u32]) -> impl Iterator<Item = (u32, u32)> + '_ {
    ids.windows(2).map(|w| (w[0], w[1]))
}

fn contains_pair(ids: &[u32], pair: (u32, u32)) -> bool {
    ids.windows(2).any(|w| (w[0], w[1]) == pair)
}

/// Replace all non-overlapping occurrences of `pair` with `new_id`
/// (left-to-right, like encoding does).
fn apply_merge(ids: &mut Vec<u32>, pair: (u32, u32), new_id: u32) {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    *ids = out;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_merge_basic() {
        let mut ids = vec![1, 2, 1, 2, 3, 1];
        apply_merge(&mut ids, (1, 2), 99);
        assert_eq!(ids, vec![99, 99, 3, 1]);
    }

    #[test]
    fn apply_merge_overlapping() {
        // aaa with pair (a,a): left-to-right gives [aa, a].
        let mut ids = vec![5, 5, 5];
        apply_merge(&mut ids, (5, 5), 9);
        assert_eq!(ids, vec![9, 5]);
    }

    #[test]
    fn train_learns_frequent_pairs() {
        let corpus = "ababababab ".repeat(100);
        let cfg = TrainConfig {
            vocab_size: 256 + SPECIAL_TOKENS.len() + 8,
            min_pair_count: 2,
        };
        let v = train(&corpus, &cfg);
        assert!(!v.merges().is_empty());
        // First merge must be (a, b) — by far the most frequent pair.
        assert_eq!(v.merges()[0], (b'a' as u32, b'b' as u32));
    }

    #[test]
    fn train_is_deterministic() {
        let corpus = "the quick brown fox jumps over the lazy dog. ".repeat(50);
        let cfg = TrainConfig {
            vocab_size: 400,
            min_pair_count: 2,
        };
        let v1 = train(&corpus, &cfg);
        let v2 = train(&corpus, &cfg);
        assert_eq!(v1.merges(), v2.merges());
    }

    #[test]
    fn train_stops_when_no_pairs() {
        // Corpus of single chars separated into 1-byte chunks: every word
        // chunk is one letter + punctuation; few mergeable pairs.
        let v = train("a b c d", &TrainConfig::default());
        assert!(v.merges().len() < 10);
    }

    #[test]
    fn merged_tokens_respect_min_count() {
        let corpus = "xyz"; // every pair occurs once < min_pair_count=2
        let cfg = TrainConfig {
            vocab_size: 300,
            min_pair_count: 2,
        };
        let v = train(corpus, &cfg);
        assert!(v.merges().is_empty());
    }
}
