//! Byte-level BPE tokenizer (llama.cpp-tokenizer substitute), implemented
//! from scratch: trainer, encoder, decoder, and vocabulary serialization.
//!
//! DisCEdge's core design choice is to store and replicate session context
//! in *tokenized* form so that only the new prompt must be tokenized per
//! turn. For the reproduction to be honest, tokenization must be real work
//! whose cost grows with input length — this module provides that.
//!
//! Layout mirrors GPT-2/llama byte-level BPE:
//! - ids `0..256` are the 256 raw bytes;
//! - ids `256..` are learned merges, in rank order;
//! - the top of the vocabulary holds special tokens (ChatML markers).
//!
//! Encoding never emits special tokens from user text (the chat template
//! inserts them programmatically), which doubles as prompt-injection
//! hygiene.

mod bpe;
mod vocab;

pub use bpe::{train, TrainConfig};
pub use vocab::Vocab;

use std::collections::HashMap;
use std::sync::Mutex;

use crate::{Error, Result};

/// Special tokens used by the ChatML chat template.
pub const SPECIAL_TOKENS: [&str; 4] = ["<|endoftext|>", "<|im_start|>", "<|im_end|>", "<|pad|>"];

/// A trained byte-level BPE tokenizer.
///
/// Cheap to share behind an `Arc`; `encode` uses an internal word cache
/// guarded by a mutex (hit rate is high on natural text).
pub struct Tokenizer {
    vocab: Vocab,
    /// (left id, right id) -> (rank, merged id)
    merge_map: HashMap<(u32, u32), (u32, u32)>,
    /// word -> encoded ids memo
    cache: Mutex<HashMap<String, Vec<u32>>>,
}

impl std::fmt::Debug for Tokenizer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tokenizer")
            .field("vocab_size", &self.vocab.size())
            .finish()
    }
}

impl Tokenizer {
    /// Build a tokenizer from a vocabulary.
    pub fn from_vocab(vocab: Vocab) -> Tokenizer {
        let mut merge_map = HashMap::with_capacity(vocab.merges().len());
        for (rank, &(a, b)) in vocab.merges().iter().enumerate() {
            let merged = 256 + rank as u32;
            merge_map.insert((a, b), (rank as u32, merged));
        }
        Tokenizer {
            vocab,
            merge_map,
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Load from a vocabulary JSON file (see [`Vocab::load`]).
    pub fn load(path: &std::path::Path) -> Result<Tokenizer> {
        Ok(Tokenizer::from_vocab(Vocab::load(path)?))
    }

    /// Total vocabulary size, including byte tokens and specials.
    pub fn vocab_size(&self) -> usize {
        self.vocab.size()
    }

    /// Id of a special token.
    pub fn special(&self, name: &str) -> Result<u32> {
        self.vocab
            .special(name)
            .ok_or_else(|| Error::Tokenizer(format!("unknown special token {name}")))
    }

    /// Encode text to token ids. Special-token literals in the input are
    /// encoded as plain text, never as their special ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 3 + 4);
        for word in pre_split(text) {
            // Word cache: natural text repeats tokens heavily.
            if word.len() <= 32 {
                if let Some(ids) = self.cache.lock().unwrap().get(word) {
                    out.extend_from_slice(ids);
                    continue;
                }
            }
            let ids = self.encode_word(word.as_bytes());
            if word.len() <= 32 {
                self.cache
                    .lock()
                    .unwrap()
                    .insert(word.to_string(), ids.clone());
            }
            out.extend_from_slice(&ids);
        }
        out
    }

    /// Encode a single pre-split word by iteratively applying the
    /// lowest-rank merge, exactly like GPT-2's BPE.
    fn encode_word(&self, bytes: &[u8]) -> Vec<u32> {
        let mut ids: Vec<u32> = bytes.iter().map(|&b| b as u32).collect();
        if ids.len() < 2 {
            return ids;
        }
        loop {
            // Find the pair with the lowest merge rank.
            let mut best: Option<(u32, usize, u32)> = None; // (rank, index, merged)
            for i in 0..ids.len() - 1 {
                if let Some(&(rank, merged)) = self.merge_map.get(&(ids[i], ids[i + 1])) {
                    if best.map_or(true, |(r, _, _)| rank < r) {
                        best = Some((rank, i, merged));
                    }
                }
            }
            match best {
                Some((_, i, merged)) => {
                    ids[i] = merged;
                    ids.remove(i + 1);
                    if ids.len() < 2 {
                        return ids;
                    }
                }
                None => return ids,
            }
        }
    }

    /// Encode text, mapping special-token literals (e.g. `<|im_start|>`)
    /// to their special ids — the behaviour llama.cpp calls
    /// `parse_special`, used by the raw context mode where the whole
    /// ChatML transcript is stored as text and re-tokenized per turn.
    pub fn encode_with_specials(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 3 + 8);
        let mut rest = text;
        'outer: while !rest.is_empty() {
            // Find the earliest special literal.
            let mut earliest: Option<(usize, &str, u32)> = None;
            for name in SPECIAL_TOKENS {
                if let Some(pos) = rest.find(name) {
                    let id = self.vocab.special(name).expect("special registered");
                    if earliest.map_or(true, |(p, n, _)| pos < p || (pos == p && name.len() > n.len())) {
                        earliest = Some((pos, name, id));
                    }
                }
            }
            match earliest {
                Some((pos, name, id)) => {
                    if pos > 0 {
                        out.extend(self.encode(&rest[..pos]));
                    }
                    out.push(id);
                    rest = &rest[pos + name.len()..];
                }
                None => {
                    out.extend(self.encode(rest));
                    break 'outer;
                }
            }
        }
        out
    }

    /// Decode token ids back to a string. Byte-level BPE guarantees exact
    /// round-trip for valid UTF-8 input; invalid sequences are replaced.
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len() * 3);
        for &id in ids {
            match self.vocab.token_bytes(id) {
                Some(b) => bytes.extend_from_slice(b),
                None => {
                    // Special tokens decode to their literal text.
                    if let Some(name) = self.vocab.special_name(id) {
                        bytes.extend_from_slice(name.as_bytes());
                    }
                }
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Whether an id denotes a special token.
    pub fn is_special(&self, id: u32) -> bool {
        self.vocab.special_name(id).is_some()
    }

    /// Access the vocabulary.
    pub fn vocab(&self) -> &Vocab {
        &self.vocab
    }
}

/// GPT-2-style pre-split: words carry their leading space; digit runs,
/// punctuation runs, and whitespace runs are separate chunks. Merges never
/// cross chunk boundaries, which bounds `encode_word`'s quadratic loop.
pub fn pre_split(text: &str) -> impl Iterator<Item = &str> {
    PreSplit { rest: text }
}

struct PreSplit<'a> {
    rest: &'a str,
}

#[derive(PartialEq, Clone, Copy)]
enum Class {
    Letter,
    Digit,
    Space,
    Other,
}

fn classify(c: char) -> Class {
    if c.is_alphabetic() {
        Class::Letter
    } else if c.is_ascii_digit() {
        Class::Digit
    } else if c == ' ' {
        Class::Space
    } else {
        Class::Other
    }
}

impl<'a> Iterator for PreSplit<'a> {
    type Item = &'a str;

    fn next(&mut self) -> Option<&'a str> {
        if self.rest.is_empty() {
            return None;
        }
        let mut chars = self.rest.char_indices();
        let (_, first) = chars.next().unwrap();
        let mut class = classify(first);
        let mut end = first.len_utf8();
        let mut leading_space = class == Class::Space;
        for (i, c) in chars {
            let k = classify(c);
            // A single leading space attaches to a following letter run.
            if leading_space && i == 1 && k == Class::Letter {
                class = Class::Letter;
                leading_space = false;
                end = i + c.len_utf8();
                continue;
            }
            if k == class && class != Class::Other {
                end = i + c.len_utf8();
            } else if k == class && class == Class::Other {
                // punctuation runs group too
                end = i + c.len_utf8();
            } else {
                break;
            }
        }
        let (chunk, rest) = self.rest.split_at(end);
        self.rest = rest;
        Some(chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn tiny_tokenizer() -> Tokenizer {
        // Train a small vocab on a tiny corpus for test speed.
        let corpus = "the robot moves the sensor reads the controller the robot \
                      turns the wheel the sensor the robot the the"
            .repeat(20);
        let cfg = TrainConfig {
            vocab_size: 320,
            ..TrainConfig::default()
        };
        Tokenizer::from_vocab(train(&corpus, &cfg))
    }

    #[test]
    fn pre_split_words() {
        let chunks: Vec<&str> = pre_split("hello world, x2  ok!").collect();
        assert_eq!(chunks, vec!["hello", " world", ",", " x", "2", "  ", "ok", "!"]);
    }

    #[test]
    fn pre_split_reassembles() {
        let s = "a b\tc\nd  e,f.1.2(x)é 日本語";
        let joined: String = pre_split(s).collect();
        assert_eq!(joined, s);
    }

    #[test]
    fn roundtrip_simple() {
        let t = tiny_tokenizer();
        for s in ["the robot moves", "hello, WORLD 42!", "", " leading", "日本語 ok"] {
            let ids = t.encode(s);
            assert_eq!(t.decode(&ids), s, "roundtrip {s:?}");
        }
    }

    #[test]
    fn compresses_trained_words() {
        let t = tiny_tokenizer();
        // "the" appears constantly in the corpus -> should be few tokens.
        let ids = t.encode("the robot the robot");
        assert!(
            ids.len() < "the robot the robot".len() / 2,
            "expected compression, got {} ids",
            ids.len()
        );
    }

    #[test]
    fn special_ids_at_top() {
        let t = tiny_tokenizer();
        let im_start = t.special("<|im_start|>").unwrap();
        assert!(t.is_special(im_start));
        assert!(im_start as usize >= t.vocab_size() - SPECIAL_TOKENS.len());
        // Specials never come from plain text.
        let ids = t.encode("<|im_start|>system");
        assert!(!ids.iter().any(|&i| t.is_special(i)));
        // But they decode to their literal.
        assert!(t.decode(&[im_start]).contains("<|im_start|>"));
    }

    #[test]
    fn encode_with_specials_maps_literals() {
        let t = tiny_tokenizer();
        let im_start = t.special("<|im_start|>").unwrap();
        let im_end = t.special("<|im_end|>").unwrap();
        let ids = t.encode_with_specials("<|im_start|>user\nhi<|im_end|>\n");
        assert_eq!(ids[0], im_start);
        assert!(ids.contains(&im_end));
        // Round-trips through decode (specials decode to literals).
        assert_eq!(t.decode(&ids), "<|im_start|>user\nhi<|im_end|>\n");
    }

    #[test]
    fn encode_with_specials_equals_programmatic_assembly() {
        // The invariant the raw mode depends on: re-tokenizing the text
        // transcript yields the same ids as assembling specials + content
        // programmatically (as the tokenized mode does).
        let t = tiny_tokenizer();
        let im_start = t.special("<|im_start|>").unwrap();
        let im_end = t.special("<|im_end|>").unwrap();
        let mut assembled = vec![im_start];
        assembled.extend(t.encode("user\nwhat is the robot doing"));
        assembled.push(im_end);
        assembled.extend(t.encode("\n"));
        let text = "<|im_start|>user\nwhat is the robot doing<|im_end|>\n";
        assert_eq!(t.encode_with_specials(text), assembled);
    }

    #[test]
    fn prop_roundtrip_random_text() {
        let t = tiny_tokenizer();
        testkit::property(150, |rng| {
            let s = rng.text(200);
            let ids = t.encode(&s);
            assert_eq!(t.decode(&ids), s, "roundtrip failed for {s:?}");
        });
    }

    #[test]
    fn prop_encode_concat_stable_at_chunk_boundary() {
        // Encoding two texts separately and concatenating ids equals
        // encoding the concatenation, provided the boundary is a chunk
        // boundary (e.g. the second starts with a space + letter or a
        // newline). This is the property DisCEdge relies on to append
        // turns to a tokenized history without re-encoding it.
        let t = tiny_tokenizer();
        testkit::property(100, |rng| {
            // End `a` with a letter so the "\n" starts a fresh chunk.
            let a = format!("{}x", rng.text(80));
            let b = rng.text(80);
            let b = format!("\n{b}");
            let mut sep = t.encode(&a);
            sep.extend(t.encode(&b));
            let joint = t.encode(&format!("{a}{b}"));
            assert_eq!(sep, joint, "concat mismatch for {a:?} + {b:?}");
        });
    }

    #[test]
    fn all_ids_below_vocab_size() {
        let t = tiny_tokenizer();
        testkit::property(50, |rng| {
            let s = rng.text(300);
            for id in t.encode(&s) {
                assert!((id as usize) < t.vocab_size());
            }
        });
    }
}
