//! Measurement utilities: series statistics (median, percentiles, mean,
//! 95 % confidence intervals), latency recorders, byte counters, and
//! table/CSV export used by the benchmark harness.
//!
//! The paper reports per-turn medians with 95 % confidence intervals over
//! three repetitions; [`Series`] reproduces exactly those aggregates.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A sample series (latencies in seconds, byte counts, token rates...).
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    /// Empty series.
    pub fn new() -> Series {
        Series::default()
    }

    /// From raw samples.
    pub fn from(samples: impl IntoIterator<Item = f64>) -> Series {
        Series {
            samples: samples.into_iter().collect(),
        }
    }

    /// Add one sample.
    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Raw samples.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (NaN for < 2 samples).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return f64::NAN;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p / 100.0 * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let w = rank - lo as f64;
            sorted[lo] * (1.0 - w) + sorted[hi] * w
        }
    }

    /// Median.
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Min sample.
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NAN, f64::min)
    }

    /// Max sample.
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NAN, f64::max)
    }

    /// Half-width of the 95 % confidence interval of the mean
    /// (t-distribution critical values for small n, matching the paper's
    /// 3-repetition error bars).
    pub fn ci95(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return f64::NAN;
        }
        let t = t_crit_95(n - 1);
        t * self.stddev() / (n as f64).sqrt()
    }

    /// Merge another series into this one.
    pub fn extend(&mut self, other: &Series) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Two-sided 95 % t-distribution critical value for `df` degrees of freedom.
fn t_crit_95(df: usize) -> f64 {
    // Table for small df (the common case: 3 repetitions -> df = 2),
    // asymptote 1.96 beyond.
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        f64::NAN
    } else if df <= TABLE.len() {
        TABLE[df - 1]
    } else {
        1.96
    }
}

/// Relative change of `new` vs `base` in percent; negative = improvement
/// when lower-is-better.
pub fn pct_change(base: f64, new: f64) -> f64 {
    (new - base) / base * 100.0
}

/// Speedup of `new` vs `base` in percent (paper convention: how much faster
/// the new median is): `(base - new) / base * 100`.
pub fn pct_speedup(base: f64, new: f64) -> f64 {
    (base - new) / base * 100.0
}

/// A wall-clock stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing.
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed duration.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Uniform sample kept per registry series. At 1024 the standard error of
/// a p99 estimate is ~0.3 percentile points — plenty for scrape output —
/// while a node that observes millions of latencies holds 8 KiB per
/// series instead of growing without bound.
pub const RESERVOIR_CAP: usize = 1024;

/// Bounded per-series accumulator: exact streaming count/sum/min/max plus
/// a fixed-size uniform sample (Vitter's Algorithm R) for percentile
/// estimates. Memory is O([`RESERVOIR_CAP`]) no matter how many samples a
/// long-running node records — the fix for `/metrics` growing linearly
/// with uptime.
#[derive(Debug, Clone)]
pub struct Reservoir {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    rng: u64,
}

impl Reservoir {
    /// Empty reservoir. `seed` keeps replacement deterministic per series
    /// (the registry seeds from the series name).
    pub fn new(seed: u64) -> Reservoir {
        Reservoir {
            count: 0,
            sum: 0.0,
            min: f64::NAN,
            max: f64::NAN,
            samples: Vec::new(),
            rng: seed | 1,
        }
    }

    /// LCG step (Numerical Recipes constants): cheap and deterministic,
    /// which is all reservoir replacement needs.
    fn next_u64(&mut self) -> u64 {
        self.rng = self
            .rng
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.rng
    }

    /// Record one sample.
    pub fn push(&mut self, v: f64) {
        self.count += 1;
        if self.count == 1 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.sum += v;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            // Algorithm R: the n-th sample replaces a random slot with
            // probability cap/n, keeping the retained set uniform over
            // everything seen so far.
            let j = (self.next_u64() % self.count) as usize;
            if j < RESERVOIR_CAP {
                self.samples[j] = v;
            }
        }
    }

    /// Exact number of samples observed (not just retained).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact streaming mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Exact minimum observed (NaN when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact maximum observed (NaN when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile estimate from the retained sample (exact while fewer
    /// than [`RESERVOIR_CAP`] samples have been observed).
    pub fn percentile(&self, p: f64) -> f64 {
        self.as_series().percentile(p)
    }

    /// The retained uniform sample as a [`Series`] (the aggregate type
    /// the bench harness consumes).
    pub fn as_series(&self) -> Series {
        Series::from(self.samples.iter().copied())
    }
}

/// Thread-safe monotonically-increasing byte/ops counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Zeroed counter.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Reset to zero, returning the previous value.
    pub fn take(&self) -> u64 {
        self.value.swap(0, Ordering::Relaxed)
    }
}

/// Named metric registry exposed by each edge node at `/metrics`.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    series: Mutex<BTreeMap<String, Reservoir>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Increment a named counter.
    pub fn incr(&self, name: &str, by: u64) {
        let mut m = self.counters.lock().unwrap();
        *m.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record a sample into a named series. Bounded: each series keeps
    /// streaming aggregates plus at most [`RESERVOIR_CAP`] samples.
    pub fn observe(&self, name: &str, v: f64) {
        let mut m = self.series.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Reservoir::new(crate::testkit::fnv1a(name.as_bytes())))
            .push(v);
    }

    /// Read a counter (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Snapshot of a named series: the retained uniform sample (exact
    /// below [`RESERVOIR_CAP`] observations, a representative subsample
    /// beyond it).
    pub fn series(&self, name: &str) -> Series {
        self.series
            .lock()
            .unwrap()
            .get(name)
            .map(Reservoir::as_series)
            .unwrap_or_default()
    }

    /// Flat text dump (Prometheus-ish) for the `/metrics` endpoint.
    /// `count`/`mean` are exact streaming values; the percentiles are
    /// reservoir estimates.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, s) in self.series.lock().unwrap().iter() {
            if s.count() > 0 {
                out.push_str(&format!(
                    "{k}_count {}\n{k}_mean {:.6}\n{k}_p50 {:.6}\n{k}_p99 {:.6}\n{k}_p999 {:.6}\n",
                    s.count(),
                    s.mean(),
                    s.percentile(50.0),
                    s.percentile(99.0),
                    s.percentile(99.9)
                ));
            }
        }
        out
    }
}

/// One row of a result table: label -> per-column values.
#[derive(Debug, Clone)]
pub struct Row {
    /// Row label (e.g. "turn 3" or "tokenized/m2").
    pub label: String,
    /// Column values in `Table::columns` order.
    pub values: Vec<f64>,
}

/// Simple result table with markdown and CSV rendering, used by every bench
/// to print the series the paper's figures plot.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title.
    pub title: String,
    /// Column headers (value columns; the first column is the row label).
    pub columns: Vec<String>,
    /// Rows.
    pub rows: Vec<Row>,
}

impl Table {
    /// New empty table.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, label: &str, values: &[f64]) {
        assert_eq!(values.len(), self.columns.len(), "column arity mismatch");
        self.rows.push(Row {
            label: label.to_string(),
            values: values.to_vec(),
        });
    }

    /// Render as github markdown.
    pub fn markdown(&self) -> String {
        let mut out = format!("### {}\n\n| |", self.title);
        for c in &self.columns {
            out.push_str(&format!(" {c} |"));
        }
        out.push_str("\n|---|");
        for _ in &self.columns {
            out.push_str("---|");
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&format!("| {} |", r.label));
            for v in &r.values {
                out.push_str(&format!(" {} |", fmt_sig(*v)));
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV (header row uses `label` for the first column).
    pub fn csv(&self) -> String {
        let mut out = String::from("label");
        for c in &self.columns {
            out.push(',');
            out.push_str(c);
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.label);
            for v in &r.values {
                out.push_str(&format!(",{v}"));
            }
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the given results dir, creating it if needed.
    pub fn write_csv(&self, dir: &std::path::Path, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(name), self.csv())
    }
}

/// Format with ~4 significant digits for human-readable tables.
fn fmt_sig(v: f64) -> String {
    if v.is_nan() {
        return "-".into();
    }
    let a = v.abs();
    if a == 0.0 {
        "0".into()
    } else if a >= 1000.0 {
        format!("{v:.0}")
    } else if a >= 10.0 {
        format!("{v:.1}")
    } else if a >= 0.1 {
        format!("{v:.3}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let s = Series::from([1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811388).abs() < 1e-6);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(25.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Series::from([0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(75.0), 7.5);
    }

    #[test]
    fn ci95_three_reps() {
        // Paper setup: 3 repetitions -> df=2 -> t = 4.303.
        let s = Series::from([10.0, 12.0, 11.0]);
        let expected = 4.303 * s.stddev() / 3f64.sqrt();
        assert!((s.ci95() - expected).abs() < 1e-9);
    }

    #[test]
    fn empty_series_nan() {
        let s = Series::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
        assert!(s.ci95().is_nan());
    }

    #[test]
    fn speedup_convention() {
        // Paper: raw median 1.0s -> tokenized 0.8554s = 14.46% speedup.
        let v = pct_speedup(1.0, 0.8554);
        assert!((v - 14.46).abs() < 1e-9);
        assert!((pct_change(1.0, 0.85) + 15.0).abs() < 1e-9);
    }

    #[test]
    fn counter_ops() {
        let c = Counter::new();
        c.add(5);
        c.add(7);
        assert_eq!(c.get(), 12);
        assert_eq!(c.take(), 12);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn registry_roundtrip() {
        let r = Registry::new();
        r.incr("requests_total", 1);
        r.incr("requests_total", 2);
        r.observe("latency_s", 0.5);
        r.observe("latency_s", 1.5);
        assert_eq!(r.counter("requests_total"), 3);
        assert_eq!(r.series("latency_s").mean(), 1.0);
        let dump = r.dump();
        assert!(dump.contains("requests_total 3"));
        assert!(dump.contains("latency_s_count 2"));
    }

    #[test]
    fn reservoir_memory_is_bounded_and_aggregates_exact() {
        let mut r = Reservoir::new(7);
        let n = 100_000u64;
        for i in 0..n {
            r.push(i as f64);
        }
        assert_eq!(r.count(), n, "count is streaming, not sampled");
        assert!(
            r.as_series().len() <= RESERVOIR_CAP,
            "retained sample must stay bounded"
        );
        assert_eq!(r.min(), 0.0);
        assert_eq!(r.max(), (n - 1) as f64);
        assert!((r.mean() - (n - 1) as f64 / 2.0).abs() < 1e-6, "mean is exact");
    }

    #[test]
    fn reservoir_exact_below_cap() {
        // Under the cap every sample is retained: percentiles match the
        // full-series computation bit for bit.
        let mut r = Reservoir::new(3);
        let vals: Vec<f64> = (0..500).map(|i| (i * 13 % 500) as f64).collect();
        for &v in &vals {
            r.push(v);
        }
        let full = Series::from(vals);
        for p in [50.0, 99.0, 99.9] {
            assert_eq!(r.percentile(p), full.percentile(p), "p{p}");
        }
    }

    #[test]
    fn reservoir_percentiles_within_tolerance() {
        // 100k uniform samples through a 1024-slot reservoir: estimates
        // must land within a few percent of the true quantiles. The LCG
        // is deterministic, so this pins one fixed draw, not a flaky one.
        let mut r = Reservoir::new(42);
        let n = 100_000;
        for i in 0..n {
            r.push(i as f64);
        }
        let range = n as f64;
        assert!(
            (r.percentile(50.0) - 0.50 * range).abs() < 0.06 * range,
            "p50 estimate {} too far from {}",
            r.percentile(50.0),
            0.50 * range
        );
        assert!(
            (r.percentile(99.0) - 0.99 * range).abs() < 0.02 * range,
            "p99 estimate {} too far from {}",
            r.percentile(99.0),
            0.99 * range
        );
        assert!(
            (r.percentile(99.9) - 0.999 * range).abs() < 0.02 * range,
            "p999 estimate {} too far from {}",
            r.percentile(99.9),
            0.999 * range
        );
    }

    #[test]
    fn registry_series_memory_is_bounded() {
        let r = Registry::new();
        for i in 0..(RESERVOIR_CAP * 10) {
            r.observe("hot_path_s", i as f64);
        }
        assert!(r.series("hot_path_s").len() <= RESERVOIR_CAP);
        let dump = r.dump();
        assert!(
            dump.contains(&format!("hot_path_s_count {}", RESERVOIR_CAP * 10)),
            "dump count stays exact:\n{dump}"
        );
        assert!(dump.contains("hot_path_s_p999 "), "p999 joins the dump");
    }

    #[test]
    fn table_render() {
        let mut t = Table::new("Fig X", &["raw", "tokenized"]);
        t.row("turn 1", &[1.25, 1.0]);
        let md = t.markdown();
        assert!(md.contains("| turn 1 | 1.250 | 1.000 |"));
        let csv = t.csv();
        assert!(csv.starts_with("label,raw,tokenized\n"));
        assert!(csv.contains("turn 1,1.25,1\n"));
    }
}
