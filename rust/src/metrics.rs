//! Measurement utilities: series statistics (median, percentiles, mean,
//! 95 % confidence intervals), latency recorders, byte counters, and
//! table/CSV export used by the benchmark harness.
//!
//! The paper reports per-turn medians with 95 % confidence intervals over
//! three repetitions; [`Series`] reproduces exactly those aggregates.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A sample series (latencies in seconds, byte counts, token rates...).
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    /// Empty series.
    pub fn new() -> Series {
        Series::default()
    }

    /// From raw samples.
    pub fn from(samples: impl IntoIterator<Item = f64>) -> Series {
        Series {
            samples: samples.into_iter().collect(),
        }
    }

    /// Add one sample.
    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Raw samples.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (NaN for < 2 samples).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return f64::NAN;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p / 100.0 * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let w = rank - lo as f64;
            sorted[lo] * (1.0 - w) + sorted[hi] * w
        }
    }

    /// Median.
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Min sample.
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NAN, f64::min)
    }

    /// Max sample.
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NAN, f64::max)
    }

    /// Half-width of the 95 % confidence interval of the mean
    /// (t-distribution critical values for small n, matching the paper's
    /// 3-repetition error bars).
    pub fn ci95(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return f64::NAN;
        }
        let t = t_crit_95(n - 1);
        t * self.stddev() / (n as f64).sqrt()
    }

    /// Merge another series into this one.
    pub fn extend(&mut self, other: &Series) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Two-sided 95 % t-distribution critical value for `df` degrees of freedom.
fn t_crit_95(df: usize) -> f64 {
    // Table for small df (the common case: 3 repetitions -> df = 2),
    // asymptote 1.96 beyond.
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        f64::NAN
    } else if df <= TABLE.len() {
        TABLE[df - 1]
    } else {
        1.96
    }
}

/// Relative change of `new` vs `base` in percent; negative = improvement
/// when lower-is-better.
pub fn pct_change(base: f64, new: f64) -> f64 {
    (new - base) / base * 100.0
}

/// Speedup of `new` vs `base` in percent (paper convention: how much faster
/// the new median is): `(base - new) / base * 100`.
pub fn pct_speedup(base: f64, new: f64) -> f64 {
    (base - new) / base * 100.0
}

/// A wall-clock stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing.
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed duration.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Uniform sample kept per registry series. At 1024 the standard error of
/// a p99 estimate is ~0.3 percentile points — plenty for scrape output —
/// while a node that observes millions of latencies holds 8 KiB per
/// series instead of growing without bound.
pub const RESERVOIR_CAP: usize = 1024;

/// Bounded per-series accumulator: exact streaming count/sum/min/max plus
/// a fixed-size uniform sample (Vitter's Algorithm R) for percentile
/// estimates. Memory is O([`RESERVOIR_CAP`]) no matter how many samples a
/// long-running node records — the fix for `/metrics` growing linearly
/// with uptime.
#[derive(Debug, Clone)]
pub struct Reservoir {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    rng: u64,
}

impl Reservoir {
    /// Empty reservoir. `seed` keeps replacement deterministic per series
    /// (the registry seeds from the series name).
    pub fn new(seed: u64) -> Reservoir {
        Reservoir {
            count: 0,
            sum: 0.0,
            min: f64::NAN,
            max: f64::NAN,
            samples: Vec::new(),
            rng: seed | 1,
        }
    }

    /// LCG step (Numerical Recipes constants): cheap and deterministic,
    /// which is all reservoir replacement needs.
    fn next_u64(&mut self) -> u64 {
        self.rng = self
            .rng
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.rng
    }

    /// Record one sample.
    pub fn push(&mut self, v: f64) {
        self.count += 1;
        if self.count == 1 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.sum += v;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            // Algorithm R: the n-th sample replaces a random slot with
            // probability cap/n, keeping the retained set uniform over
            // everything seen so far.
            let j = (self.next_u64() % self.count) as usize;
            if j < RESERVOIR_CAP {
                self.samples[j] = v;
            }
        }
    }

    /// Exact number of samples observed (not just retained).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact streaming mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Exact minimum observed (NaN when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact maximum observed (NaN when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile estimate from the retained sample (exact while fewer
    /// than [`RESERVOIR_CAP`] samples have been observed).
    pub fn percentile(&self, p: f64) -> f64 {
        self.as_series().percentile(p)
    }

    /// The retained uniform sample as a [`Series`] (the aggregate type
    /// the bench harness consumes).
    pub fn as_series(&self) -> Series {
        Series::from(self.samples.iter().copied())
    }
}

/// Number of fixed-duration windows each ring keeps. With the default
/// 1 s window this covers the last 16 seconds — enough for `_rate10s`
/// plus slack for scrape jitter, small enough that a fleet of nodes
/// holds kilobytes, not megabytes.
pub const WINDOW_SLOTS: usize = 16;

/// Samples retained per window. Windows are short (seconds), so a small
/// uniform sample per window keeps recent-percentile estimates tight
/// without letting a hot path grow the ring.
const WINDOW_SAMPLE_CAP: usize = 256;

/// One fixed-duration window of a [`WindowRing`]: the window index it
/// currently holds data for, exact count/sum, and a bounded uniform
/// sample for percentiles.
#[derive(Debug, Clone, Default)]
struct WindowSlot {
    /// Absolute window index (`now_ms / window_ms`) this slot's data
    /// belongs to. A push with a different index resets the slot first —
    /// lazy expiry, no sweeper thread.
    index: u64,
    /// True once the slot has been claimed for `index` (index 0 is a
    /// valid window, so emptiness needs its own bit).
    live: bool,
    count: u64,
    sum: f64,
    samples: Vec<f64>,
    rng: u64,
}

impl WindowSlot {
    fn reset(&mut self, index: u64, seed: u64) {
        self.index = index;
        self.live = true;
        self.count = 0;
        self.sum = 0.0;
        self.samples.clear();
        self.rng = (seed ^ index) | 1;
    }

    fn next_u64(&mut self) -> u64 {
        self.rng = self
            .rng
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.rng
    }

    /// Record one sample (Algorithm R over this window's observations).
    fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        if self.samples.len() < WINDOW_SAMPLE_CAP {
            self.samples.push(v);
        } else {
            let j = (self.next_u64() % self.count) as usize;
            if j < WINDOW_SAMPLE_CAP {
                self.samples[j] = v;
            }
        }
    }
}

/// Ring of [`WINDOW_SLOTS`] fixed-duration windows over one metric.
///
/// The cumulative [`Reservoir`] answers "what happened since start";
/// this ring answers "what is happening *now*": event rates over the
/// most recent complete windows and percentiles over the samples the
/// ring still holds. Slots are claimed lazily by window index, so an
/// idle series costs nothing and stale windows age out by being
/// overwritten — there is no background expiry.
#[derive(Debug, Clone)]
pub struct WindowRing {
    /// Window duration in milliseconds (fixed at ring creation).
    window_ms: u64,
    seed: u64,
    slots: Vec<WindowSlot>,
}

impl WindowRing {
    /// Empty ring with `window_ms`-wide windows. `seed` keeps per-window
    /// sample replacement deterministic per series.
    pub fn new(window_ms: u64, seed: u64) -> WindowRing {
        WindowRing {
            window_ms: window_ms.max(1),
            seed,
            slots: vec![WindowSlot::default(); WINDOW_SLOTS],
        }
    }

    /// The slot for the window containing `now_ms`, reset if it still
    /// holds an older window's data.
    fn slot_at(&mut self, now_ms: u64) -> &mut WindowSlot {
        let index = now_ms / self.window_ms;
        let seed = self.seed;
        let slot = &mut self.slots[(index % WINDOW_SLOTS as u64) as usize];
        if !slot.live || slot.index != index {
            slot.reset(index, seed);
        }
        slot
    }

    /// Record `by` events at `now_ms` (counter increments).
    pub fn add(&mut self, now_ms: u64, by: u64) {
        self.slot_at(now_ms).count += by;
    }

    /// Record one sample at `now_ms` (series observations).
    pub fn observe(&mut self, now_ms: u64, v: f64) {
        self.slot_at(now_ms).observe(v);
    }

    /// Events per second over the last `span` *complete* windows before
    /// the one containing `now_ms`. The current (partial) window is
    /// excluded so the rate never underestimates mid-window; `span` is
    /// clamped to what the ring can actually hold.
    pub fn rate(&self, now_ms: u64, span: u64) -> f64 {
        let now_index = now_ms / self.window_ms;
        let span = span.clamp(1, WINDOW_SLOTS as u64 - 1);
        let lo = now_index.saturating_sub(span);
        let events: u64 = self
            .slots
            .iter()
            .filter(|s| s.live && s.index >= lo && s.index < now_index)
            .map(|s| s.count)
            .sum();
        events as f64 / (span * self.window_ms) as f64 * 1000.0
    }

    /// All samples the ring still holds for windows at or before
    /// `now_ms` (including the current partial window) — the "recent"
    /// population behind `_p50_w` / `_p99_w`.
    pub fn recent(&self, now_ms: u64) -> Series {
        let now_index = now_ms / self.window_ms;
        let lo = now_index.saturating_sub(WINDOW_SLOTS as u64 - 1);
        let mut out = Series::new();
        for s in &self.slots {
            if s.live && s.index >= lo && s.index <= now_index {
                for &v in &s.samples {
                    out.push(v);
                }
            }
        }
        out
    }

    /// Events counted in windows `[now - span, now)`, complete windows
    /// only (the numerator of [`WindowRing::rate`]).
    pub fn recent_count(&self, now_ms: u64, span: u64) -> u64 {
        let now_index = now_ms / self.window_ms;
        let span = span.clamp(1, WINDOW_SLOTS as u64 - 1);
        let lo = now_index.saturating_sub(span);
        self.slots
            .iter()
            .filter(|s| s.live && s.index >= lo && s.index < now_index)
            .map(|s| s.count)
            .sum()
    }
}

/// Monotonic millisecond clock driving a registry's window rings.
/// Injectable so tests can shift time deterministically instead of
/// sleeping through wall-clock windows.
pub type WindowClock = Arc<dyn Fn() -> u64 + Send + Sync>;

/// Window state of a [`Registry`]: the shared clock plus one ring per
/// counter / series that recorded anything since windows were enabled.
#[derive(Default)]
struct Windows {
    clock: Option<WindowClock>,
    counters: BTreeMap<String, WindowRing>,
    series: BTreeMap<String, WindowRing>,
}

impl std::fmt::Debug for Windows {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Windows")
            .field("counters", &self.counters.len())
            .field("series", &self.series.len())
            .finish_non_exhaustive()
    }
}

/// Thread-safe monotonically-increasing byte/ops counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Zeroed counter.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Reset to zero, returning the previous value.
    pub fn take(&self) -> u64 {
        self.value.swap(0, Ordering::Relaxed)
    }
}

/// Named metric registry exposed by each edge node at `/metrics`.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    series: Mutex<BTreeMap<String, Reservoir>>,
    /// Window duration; 0 (the default) disables the window rings and
    /// keeps the record paths free of any windowing work or lock.
    window_ms: AtomicU64,
    windows: Mutex<Windows>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Enable windowed metrics: every subsequent `incr`/`observe` also
    /// lands in a [`WindowRing`] of `window_ms`-wide windows, and
    /// [`Registry::dump`] gains `_rate1s`/`_rate10s`/`_p50_w`/`_p99_w`
    /// lines. `window_ms == 0` leaves windows off (the default; the dump
    /// stays byte-identical to the unwindowed registry). The clock
    /// starts at enable time.
    pub fn enable_windows(&self, window_ms: u64) {
        let epoch = Instant::now();
        self.enable_windows_with_clock(
            window_ms,
            Arc::new(move || epoch.elapsed().as_millis() as u64),
        );
    }

    /// [`Registry::enable_windows`] with an injected monotonic
    /// millisecond clock, so tests shift time instead of sleeping.
    pub fn enable_windows_with_clock(&self, window_ms: u64, clock: WindowClock) {
        if window_ms == 0 {
            self.window_ms.store(0, Ordering::SeqCst);
            return;
        }
        {
            let mut w = self.windows.lock().unwrap();
            w.clock = Some(clock);
        }
        // Publish the duration last: a concurrent `incr` that sees a
        // nonzero window_ms must find the clock installed.
        self.window_ms.store(window_ms, Ordering::SeqCst);
    }

    /// Whether windowed metrics are being recorded.
    pub fn windows_enabled(&self) -> bool {
        self.window_ms.load(Ordering::SeqCst) > 0
    }

    /// The configured window duration (0 = windows off).
    pub fn window_ms(&self) -> u64 {
        self.window_ms.load(Ordering::SeqCst)
    }

    /// Increment a named counter.
    pub fn incr(&self, name: &str, by: u64) {
        {
            let mut m = self.counters.lock().unwrap();
            *m.entry(name.to_string()).or_insert(0) += by;
        }
        let window_ms = self.window_ms.load(Ordering::SeqCst);
        if window_ms > 0 {
            let mut w = self.windows.lock().unwrap();
            let now_ms = match &w.clock {
                Some(c) => c(),
                None => return,
            };
            w.counters
                .entry(name.to_string())
                .or_insert_with(|| {
                    WindowRing::new(window_ms, crate::testkit::fnv1a(name.as_bytes()))
                })
                .add(now_ms, by);
        }
    }

    /// Record a sample into a named series. Bounded: each series keeps
    /// streaming aggregates plus at most [`RESERVOIR_CAP`] samples.
    pub fn observe(&self, name: &str, v: f64) {
        {
            let mut m = self.series.lock().unwrap();
            m.entry(name.to_string())
                .or_insert_with(|| Reservoir::new(crate::testkit::fnv1a(name.as_bytes())))
                .push(v);
        }
        let window_ms = self.window_ms.load(Ordering::SeqCst);
        if window_ms > 0 {
            let mut w = self.windows.lock().unwrap();
            let now_ms = match &w.clock {
                Some(c) => c(),
                None => return,
            };
            w.series
                .entry(name.to_string())
                .or_insert_with(|| {
                    WindowRing::new(window_ms, crate::testkit::fnv1a(name.as_bytes()))
                })
                .observe(now_ms, v);
        }
    }

    /// Read a counter (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Snapshot of a named series: the retained uniform sample (exact
    /// below [`RESERVOIR_CAP`] observations, a representative subsample
    /// beyond it).
    pub fn series(&self, name: &str) -> Series {
        self.series
            .lock()
            .unwrap()
            .get(name)
            .map(Reservoir::as_series)
            .unwrap_or_default()
    }

    /// Flat text dump (Prometheus-ish) for the `/metrics` endpoint.
    /// `count`/`mean` are exact streaming values; the percentiles are
    /// reservoir estimates. With windows enabled the cumulative block is
    /// followed by the windowed lines — rates over the last complete
    /// second(s) and percentiles over the ring's recent samples — so a
    /// scrape reflects *now*, not the whole run.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, s) in self.series.lock().unwrap().iter() {
            if s.count() > 0 {
                out.push_str(&format!(
                    "{k}_count {}\n{k}_mean {:.6}\n{k}_p50 {:.6}\n{k}_p99 {:.6}\n{k}_p999 {:.6}\n",
                    s.count(),
                    s.mean(),
                    s.percentile(50.0),
                    s.percentile(99.0),
                    s.percentile(99.9)
                ));
            }
        }
        let window_ms = self.window_ms.load(Ordering::SeqCst);
        if window_ms > 0 {
            let w = self.windows.lock().unwrap();
            if let Some(clock) = &w.clock {
                let now_ms = clock();
                let (span1, span10) = rate_spans(window_ms);
                for (k, ring) in w.counters.iter() {
                    out.push_str(&format!(
                        "{k}_rate1s {:.6}\n{k}_rate10s {:.6}\n",
                        ring.rate(now_ms, span1),
                        ring.rate(now_ms, span10)
                    ));
                }
                for (k, ring) in w.series.iter() {
                    out.push_str(&format!(
                        "{k}_rate1s {:.6}\n{k}_rate10s {:.6}\n",
                        ring.rate(now_ms, span1),
                        ring.rate(now_ms, span10)
                    ));
                    let recent = ring.recent(now_ms);
                    if !recent.is_empty() {
                        out.push_str(&format!(
                            "{k}_p50_w {:.6}\n{k}_p99_w {:.6}\n",
                            recent.percentile(50.0),
                            recent.percentile(99.0)
                        ));
                    }
                }
            }
        }
        out
    }

    /// Events per second of `name` (counter or series) over the last
    /// complete ~1 s of windows. NaN when windows are off or the metric
    /// never recorded since enabling.
    pub fn window_rate1s(&self, name: &str) -> f64 {
        self.with_ring(name, |ring, now_ms, window_ms| {
            ring.rate(now_ms, rate_spans(window_ms).0)
        })
    }

    /// Recent-percentile estimate of series `name` over the samples the
    /// window ring still holds. NaN when windows are off, the series
    /// never recorded, or every window already aged out.
    pub fn window_percentile(&self, name: &str, p: f64) -> f64 {
        self.with_ring(name, |ring, now_ms, _| ring.recent(now_ms).percentile(p))
    }

    /// Run `f` over `name`'s window ring (series first, then counters)
    /// with the current clock reading; NaN when unavailable.
    fn with_ring(&self, name: &str, f: impl Fn(&WindowRing, u64, u64) -> f64) -> f64 {
        let window_ms = self.window_ms.load(Ordering::SeqCst);
        if window_ms == 0 {
            return f64::NAN;
        }
        let w = self.windows.lock().unwrap();
        let Some(clock) = &w.clock else {
            return f64::NAN;
        };
        let now_ms = clock();
        match w.series.get(name).or_else(|| w.counters.get(name)) {
            Some(ring) => f(ring, now_ms, window_ms),
            None => f64::NAN,
        }
    }
}

/// Window spans (in windows) approximating 1 s and 10 s for a given
/// window duration, both clamped to what the ring holds.
fn rate_spans(window_ms: u64) -> (u64, u64) {
    let span1 = (1000 / window_ms).clamp(1, WINDOW_SLOTS as u64 - 1);
    let span10 = (10_000 / window_ms).clamp(1, WINDOW_SLOTS as u64 - 1);
    (span1, span10)
}

/// One row of a result table: label -> per-column values.
#[derive(Debug, Clone)]
pub struct Row {
    /// Row label (e.g. "turn 3" or "tokenized/m2").
    pub label: String,
    /// Column values in `Table::columns` order.
    pub values: Vec<f64>,
}

/// Simple result table with markdown and CSV rendering, used by every bench
/// to print the series the paper's figures plot.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title.
    pub title: String,
    /// Column headers (value columns; the first column is the row label).
    pub columns: Vec<String>,
    /// Rows.
    pub rows: Vec<Row>,
}

impl Table {
    /// New empty table.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, label: &str, values: &[f64]) {
        assert_eq!(values.len(), self.columns.len(), "column arity mismatch");
        self.rows.push(Row {
            label: label.to_string(),
            values: values.to_vec(),
        });
    }

    /// Render as github markdown.
    pub fn markdown(&self) -> String {
        let mut out = format!("### {}\n\n| |", self.title);
        for c in &self.columns {
            out.push_str(&format!(" {c} |"));
        }
        out.push_str("\n|---|");
        for _ in &self.columns {
            out.push_str("---|");
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&format!("| {} |", r.label));
            for v in &r.values {
                out.push_str(&format!(" {} |", fmt_sig(*v)));
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV (header row uses `label` for the first column).
    pub fn csv(&self) -> String {
        let mut out = String::from("label");
        for c in &self.columns {
            out.push(',');
            out.push_str(c);
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.label);
            for v in &r.values {
                out.push_str(&format!(",{v}"));
            }
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the given results dir, creating it if needed.
    pub fn write_csv(&self, dir: &std::path::Path, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(name), self.csv())
    }
}

/// Format with ~4 significant digits for human-readable tables.
fn fmt_sig(v: f64) -> String {
    if v.is_nan() {
        return "-".into();
    }
    let a = v.abs();
    if a == 0.0 {
        "0".into()
    } else if a >= 1000.0 {
        format!("{v:.0}")
    } else if a >= 10.0 {
        format!("{v:.1}")
    } else if a >= 0.1 {
        format!("{v:.3}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let s = Series::from([1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811388).abs() < 1e-6);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(25.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Series::from([0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(75.0), 7.5);
    }

    #[test]
    fn ci95_three_reps() {
        // Paper setup: 3 repetitions -> df=2 -> t = 4.303.
        let s = Series::from([10.0, 12.0, 11.0]);
        let expected = 4.303 * s.stddev() / 3f64.sqrt();
        assert!((s.ci95() - expected).abs() < 1e-9);
    }

    #[test]
    fn empty_series_nan() {
        let s = Series::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
        assert!(s.ci95().is_nan());
    }

    #[test]
    fn speedup_convention() {
        // Paper: raw median 1.0s -> tokenized 0.8554s = 14.46% speedup.
        let v = pct_speedup(1.0, 0.8554);
        assert!((v - 14.46).abs() < 1e-9);
        assert!((pct_change(1.0, 0.85) + 15.0).abs() < 1e-9);
    }

    #[test]
    fn counter_ops() {
        let c = Counter::new();
        c.add(5);
        c.add(7);
        assert_eq!(c.get(), 12);
        assert_eq!(c.take(), 12);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn registry_roundtrip() {
        let r = Registry::new();
        r.incr("requests_total", 1);
        r.incr("requests_total", 2);
        r.observe("latency_s", 0.5);
        r.observe("latency_s", 1.5);
        assert_eq!(r.counter("requests_total"), 3);
        assert_eq!(r.series("latency_s").mean(), 1.0);
        let dump = r.dump();
        assert!(dump.contains("requests_total 3"));
        assert!(dump.contains("latency_s_count 2"));
    }

    #[test]
    fn reservoir_memory_is_bounded_and_aggregates_exact() {
        let mut r = Reservoir::new(7);
        let n = 100_000u64;
        for i in 0..n {
            r.push(i as f64);
        }
        assert_eq!(r.count(), n, "count is streaming, not sampled");
        assert!(
            r.as_series().len() <= RESERVOIR_CAP,
            "retained sample must stay bounded"
        );
        assert_eq!(r.min(), 0.0);
        assert_eq!(r.max(), (n - 1) as f64);
        assert!((r.mean() - (n - 1) as f64 / 2.0).abs() < 1e-6, "mean is exact");
    }

    #[test]
    fn reservoir_exact_below_cap() {
        // Under the cap every sample is retained: percentiles match the
        // full-series computation bit for bit.
        let mut r = Reservoir::new(3);
        let vals: Vec<f64> = (0..500).map(|i| (i * 13 % 500) as f64).collect();
        for &v in &vals {
            r.push(v);
        }
        let full = Series::from(vals);
        for p in [50.0, 99.0, 99.9] {
            assert_eq!(r.percentile(p), full.percentile(p), "p{p}");
        }
    }

    #[test]
    fn reservoir_percentiles_within_tolerance() {
        // 100k uniform samples through a 1024-slot reservoir: estimates
        // must land within a few percent of the true quantiles. The LCG
        // is deterministic, so this pins one fixed draw, not a flaky one.
        let mut r = Reservoir::new(42);
        let n = 100_000;
        for i in 0..n {
            r.push(i as f64);
        }
        let range = n as f64;
        assert!(
            (r.percentile(50.0) - 0.50 * range).abs() < 0.06 * range,
            "p50 estimate {} too far from {}",
            r.percentile(50.0),
            0.50 * range
        );
        assert!(
            (r.percentile(99.0) - 0.99 * range).abs() < 0.02 * range,
            "p99 estimate {} too far from {}",
            r.percentile(99.0),
            0.99 * range
        );
        assert!(
            (r.percentile(99.9) - 0.999 * range).abs() < 0.02 * range,
            "p999 estimate {} too far from {}",
            r.percentile(99.9),
            0.999 * range
        );
    }

    #[test]
    fn registry_series_memory_is_bounded() {
        let r = Registry::new();
        for i in 0..(RESERVOIR_CAP * 10) {
            r.observe("hot_path_s", i as f64);
        }
        assert!(r.series("hot_path_s").len() <= RESERVOIR_CAP);
        let dump = r.dump();
        assert!(
            dump.contains(&format!("hot_path_s_count {}", RESERVOIR_CAP * 10)),
            "dump count stays exact:\n{dump}"
        );
        assert!(dump.contains("hot_path_s_p999 "), "p999 joins the dump");
    }

    /// Manually-advanced clock for deterministic window tests.
    fn test_clock() -> (Arc<AtomicU64>, WindowClock) {
        let t = Arc::new(AtomicU64::new(0));
        let c = t.clone();
        (t, Arc::new(move || c.load(Ordering::SeqCst)))
    }

    #[test]
    fn windows_off_keeps_dump_byte_identical() {
        let plain = Registry::new();
        let silent = Registry::new();
        // enable_windows(0) must be a no-op, not a half-enabled state.
        silent.enable_windows(0);
        for r in [&plain, &silent] {
            r.incr("kv_ops_total", 2);
            r.observe("cm_request_s", 0.25);
        }
        assert!(!silent.windows_enabled());
        assert_eq!(plain.dump(), silent.dump());
        assert!(!plain.dump().contains("_rate1s"));
        assert!(plain.window_rate1s("kv_ops_total").is_nan());
        assert!(plain.window_percentile("cm_request_s", 50.0).is_nan());
    }

    #[test]
    fn window_rates_reflect_recent_complete_windows() {
        let (t, clock) = test_clock();
        let r = Registry::new();
        r.enable_windows_with_clock(1000, clock);
        assert!(r.windows_enabled());
        assert_eq!(r.window_ms(), 1000);
        // 5 events in window 0, none afterwards.
        for _ in 0..5 {
            r.incr("kv_ops_total", 1);
        }
        // Mid-window the rate only sees complete windows: nothing yet.
        t.store(500, Ordering::SeqCst);
        assert_eq!(r.window_rate1s("kv_ops_total"), 0.0);
        // One second later window 0 is complete: 5 events/s.
        t.store(1500, Ordering::SeqCst);
        assert_eq!(r.window_rate1s("kv_ops_total"), 5.0);
        let dump = r.dump();
        assert!(dump.contains("kv_ops_total_rate1s 5.000000"), "{dump}");
        assert!(dump.contains("kv_ops_total_rate10s 0.500000"), "{dump}");
        // Twenty seconds later every window has aged out.
        t.store(20_000, Ordering::SeqCst);
        assert_eq!(r.window_rate1s("kv_ops_total"), 0.0);
    }

    #[test]
    fn windowed_percentiles_track_a_shift_the_reservoir_smears() {
        let (t, clock) = test_clock();
        let r = Registry::new();
        r.enable_windows_with_clock(1000, clock);
        // A long fast phase dominates the cumulative reservoir...
        for _ in 0..2000 {
            r.observe("cm_request_s", 0.01);
        }
        // ...then the workload shifts, far enough ahead that the fast
        // phase's windows have all aged out of the ring.
        t.store(100_000, Ordering::SeqCst);
        for _ in 0..50 {
            r.observe("cm_request_s", 1.0);
        }
        let cumulative_p50 = r.series("cm_request_s").percentile(50.0);
        let windowed_p50 = r.window_percentile("cm_request_s", 50.0);
        assert!(cumulative_p50 < 0.05, "reservoir smears: {cumulative_p50}");
        assert_eq!(windowed_p50, 1.0, "window sees only the slow phase");
        let dump = r.dump();
        assert!(dump.contains("cm_request_s_p50_w 1.000000"), "{dump}");
        assert!(dump.contains("cm_request_s_p99_w 1.000000"), "{dump}");
    }

    #[test]
    fn window_ring_slot_reuse_drops_stale_data() {
        let mut ring = WindowRing::new(1000, 7);
        ring.observe(500, 1.0);
        // WINDOW_SLOTS seconds later the same slot holds a new window;
        // the old sample must not leak into the recent population.
        let later = 500 + (WINDOW_SLOTS as u64) * 1000;
        ring.observe(later, 2.0);
        let recent = ring.recent(later);
        assert_eq!(recent.samples(), &[2.0]);
        assert_eq!(ring.recent_count(later + 1000, 1), 1);
    }

    #[test]
    fn window_samples_stay_bounded() {
        let mut ring = WindowRing::new(1000, 9);
        for i in 0..10_000 {
            ring.observe(100, i as f64);
        }
        assert!(ring.recent(100).len() <= WINDOW_SAMPLE_CAP);
        // The count stays exact even though the sample is bounded.
        assert_eq!(ring.recent_count(1100, 1), 10_000);
    }

    #[test]
    fn table_render() {
        let mut t = Table::new("Fig X", &["raw", "tokenized"]);
        t.row("turn 1", &[1.25, 1.0]);
        let md = t.markdown();
        assert!(md.contains("| turn 1 | 1.250 | 1.000 |"));
        let csv = t.csv();
        assert!(csv.starts_with("label,raw,tokenized\n"));
        assert!(csv.contains("turn 1,1.25,1\n"));
    }
}
