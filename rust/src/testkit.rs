//! Deterministic PRNG and a small property-based testing harness
//! (proptest substitute — the offline registry has no proptest).
//!
//! The [`Rng`] here is a SplitMix64/xoshiro-style generator used everywhere
//! the system needs reproducible randomness (model weights derive from the
//! same scheme on the Python side, workload generation, property tests).
//! [`property`] runs a closure over many generated cases and, on failure,
//! re-runs a simple shrink loop to report a minimal failing seed.

/// FNV-1a over a byte slice: the crate's shared deterministic string
/// hash (session-id seeding, ring point placement). Stable across
/// platforms and releases — ring placement depends on that.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic 64-bit PRNG (SplitMix64). Small, fast, seedable, portable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. The same seed yields the same stream on every
    /// platform.
    pub fn new(seed: u64) -> Rng {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Rejection sampling to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Random ASCII-ish string of length in `[0, max_len)`, biased toward
    /// text-like content (letters, spaces, punctuation) plus some unicode.
    pub fn text(&mut self, max_len: usize) -> String {
        let len = self.range(0, max_len.max(1));
        let mut s = String::with_capacity(len);
        for _ in 0..len {
            let roll = self.below(100);
            let c = if roll < 70 {
                // letters and digits
                let alphabet = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789";
                alphabet[self.range(0, alphabet.len())] as char
            } else if roll < 85 {
                ' '
            } else if roll < 95 {
                *self.pick(&['.', ',', '!', '?', ':', ';', '\n', '\t', '"', '\\', '(', ')'])
            } else {
                *self.pick(&['é', 'ü', '日', '本', '語', '😀', 'λ', '∑', 'Ω'])
            };
            s.push(c);
        }
        s
    }

    /// Random byte vector of length in `[0, max_len)`.
    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let len = self.range(0, max_len.max(1));
        (0..len).map(|_| self.next_u64() as u8).collect()
    }

    /// Standard-normal draw (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Crash-injection helper: cut the last `n` bytes off a file, modelling a
/// torn write (a record whose tail never reached the disk). Panics on
/// I/O errors — this is test machinery.
pub fn truncate_file_tail(path: &std::path::Path, n: u64) {
    let len = std::fs::metadata(path)
        .unwrap_or_else(|e| panic!("stat {}: {e}", path.display()))
        .len();
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .unwrap_or_else(|e| panic!("open {}: {e}", path.display()));
    f.set_len(len.saturating_sub(n))
        .unwrap_or_else(|e| panic!("truncate {}: {e}", path.display()));
}

/// Crash-injection helper: flip bits in the last `n` bytes of a file,
/// modelling tail corruption (a misdirected or bit-rotted sector). The
/// length is unchanged, so only a per-record checksum can catch it.
pub fn corrupt_file_tail(path: &std::path::Path, n: u64) {
    use std::io::{Read, Seek, SeekFrom, Write};
    let mut f = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .open(path)
        .unwrap_or_else(|e| panic!("open {}: {e}", path.display()));
    let len = f.metadata().unwrap().len();
    let start = len.saturating_sub(n);
    let mut tail = vec![0u8; (len - start) as usize];
    f.seek(SeekFrom::Start(start)).unwrap();
    f.read_exact(&mut tail).unwrap();
    for b in &mut tail {
        *b ^= 0xA5;
    }
    f.seek(SeekFrom::Start(start)).unwrap();
    f.write_all(&tail).unwrap();
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropertyFailure {
    /// Seed of the failing case.
    pub seed: u64,
    /// Panic/assertion message.
    pub message: String,
}

/// Run `cases` generated property checks. `f` receives a per-case [`Rng`]
/// and should panic (e.g. via `assert!`) on property violation.
///
/// Panics with the failing seed so the case can be replayed with
/// `check_one(seed, f)`.
pub fn property<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u64, f: F) {
    for i in 0..cases {
        let seed = 0xD15CED6E ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = panic_message(&e);
            panic!("property failed on case {i} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single property case by seed.
pub fn check_one<F: FnOnce(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let n = 1 + rng.next_u64() % 1000;
            assert!(rng.below(n) < n);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn property_catches_failures() {
        let r = std::panic::catch_unwind(|| {
            property(100, |rng| {
                // Fails whenever the draw is >= 10.
                assert!(rng.below(100) < 10);
            });
        });
        assert!(r.is_err());
    }

    #[test]
    fn property_passes_valid() {
        property(200, |rng| {
            let v = rng.range(3, 10);
            assert!((3..10).contains(&v));
        });
    }
}
