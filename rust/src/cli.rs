//! Tiny command-line argument parser (clap substitute) for the `discedge`
//! launcher and the benchmark binaries.
//!
//! Supports `command [--flag] [--key value] [positional...]` with typed
//! accessors and generated usage text.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// `--flag` booleans.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("empty flag `--`".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map_or(false, |next| !next.starts_with("--"))
                {
                    let v = iter.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Option value as string.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Option value with default.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// Typed option value.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| Error::Config(format!("invalid value for --{name}: {s}"))),
        }
    }

    /// Typed option with default.
    pub fn opt_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    /// Whether a boolean flag is present.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --port 8080 --profile m2 --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.opt("port"), Some("8080"));
        assert_eq!(a.opt("profile"), Some("m2"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --reps=5 --mode=tokenized");
        assert_eq!(a.opt("reps"), Some("5"));
        assert_eq!(a.opt("mode"), Some("tokenized"));
    }

    #[test]
    fn typed_access() {
        let a = parse("x --n 42");
        assert_eq!(a.opt_parse::<u32>("n").unwrap(), Some(42));
        assert_eq!(a.opt_parse_or("missing", 7u32).unwrap(), 7);
        let bad = parse("x --n nope");
        assert!(bad.opt_parse::<u32>("n").is_err());
    }

    #[test]
    fn positionals() {
        let a = parse("run one two --k v three");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["one", "two", "three"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --verbose");
        assert!(a.flag("verbose"));
    }
}
