//! Benchmark harness (criterion substitute): warmup + timed repetitions,
//! per-repetition series, figure-style result tables, and CSV export to
//! `results/`.
//!
//! Every `cargo bench` target in `rust/benches/` is a `harness = false`
//! binary built on this module; each regenerates one of the paper's
//! figures (see DESIGN.md §6).

use std::path::PathBuf;
use std::time::Instant;

use crate::metrics::{Series, Table};

/// A benchmark run description.
pub struct Bench {
    /// Name used in output and CSV files.
    pub name: String,
    /// Number of measured repetitions (paper: 3).
    pub repetitions: usize,
    /// Number of warmup runs (not recorded).
    pub warmup: usize,
}

impl Bench {
    /// New benchmark with the paper's 3-repetition convention.
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            repetitions: 3,
            warmup: 1,
        }
    }

    /// Override repetition count.
    pub fn repetitions(mut self, n: usize) -> Bench {
        self.repetitions = n;
        self
    }

    /// Override warmup count.
    pub fn warmup(mut self, n: usize) -> Bench {
        self.warmup = n;
        self
    }

    /// Run `f` warmup+repetition times. `f` receives the repetition index
    /// and returns one *series of per-turn samples*; the result collects,
    /// per turn, the repetition samples (matching the paper's per-turn
    /// error bars over 3 runs).
    pub fn run_per_turn(&self, mut f: impl FnMut(usize) -> Vec<f64>) -> PerTurn {
        for w in 0..self.warmup {
            let _ = f(w);
        }
        let mut turns: Vec<Series> = Vec::new();
        for rep in 0..self.repetitions {
            let samples = f(rep);
            if turns.len() < samples.len() {
                turns.resize_with(samples.len(), Series::new);
            }
            for (i, s) in samples.iter().enumerate() {
                turns[i].push(*s);
            }
        }
        PerTurn { turns }
    }

    /// Time a closure `repetitions` times, returning seconds per run.
    pub fn run_timed(&self, mut f: impl FnMut()) -> Series {
        for _ in 0..self.warmup {
            f();
        }
        let mut out = Series::new();
        for _ in 0..self.repetitions {
            let t = Instant::now();
            f();
            out.push(t.elapsed().as_secs_f64());
        }
        out
    }
}

/// Per-turn samples across repetitions.
#[derive(Debug, Clone)]
pub struct PerTurn {
    /// One series per turn; each holds `repetitions` samples.
    pub turns: Vec<Series>,
}

impl PerTurn {
    /// Per-turn means.
    pub fn means(&self) -> Vec<f64> {
        self.turns.iter().map(|s| s.mean()).collect()
    }

    /// Per-turn 95% CI half-widths.
    pub fn ci95s(&self) -> Vec<f64> {
        self.turns.iter().map(|s| s.ci95()).collect()
    }

    /// All samples across turns and repetitions flattened (the paper's
    /// "median response time" aggregates over turns).
    pub fn all(&self) -> Series {
        let mut s = Series::new();
        for t in &self.turns {
            s.extend(t);
        }
        s
    }
}

/// Where CSVs/markdown land (`$DISCEDGE_RESULTS` or `./results`).
pub fn results_dir() -> PathBuf {
    std::env::var_os("DISCEDGE_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Print a table to stdout and save its CSV into the results dir.
pub fn emit(table: &Table, csv_name: &str) {
    println!("\n{}", table.markdown());
    let dir = results_dir();
    match table.write_csv(&dir, csv_name) {
        Ok(()) => println!("[saved {}]", dir.join(csv_name).display()),
        Err(e) => eprintln!("[warn: could not save {csv_name}: {e}]"),
    }
}

/// Build the standard per-turn figure table: turn label, then
/// (mean, ci95) column pairs per variant.
pub fn per_turn_table(
    title: &str,
    variants: &[(&str, &PerTurn)],
) -> Table {
    let mut cols: Vec<String> = Vec::new();
    for (name, _) in variants {
        cols.push(format!("{name}_mean"));
        cols.push(format!("{name}_ci95"));
    }
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &col_refs);
    let n_turns = variants
        .iter()
        .map(|(_, p)| p.turns.len())
        .max()
        .unwrap_or(0);
    for turn in 0..n_turns {
        let mut row = Vec::new();
        for (_, p) in variants {
            let (m, c) = p
                .turns
                .get(turn)
                .map(|s| (s.mean(), s.ci95()))
                .unwrap_or((f64::NAN, f64::NAN));
            row.push(m);
            row.push(c);
        }
        t.row(&format!("turn {}", turn + 1), &row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_turn_collects_by_turn() {
        let b = Bench::new("t").repetitions(3).warmup(0);
        let mut rep_no = 0;
        let pt = b.run_per_turn(|_| {
            rep_no += 1;
            vec![rep_no as f64, 10.0 * rep_no as f64]
        });
        assert_eq!(pt.turns.len(), 2);
        assert_eq!(pt.turns[0].samples(), &[1.0, 2.0, 3.0]);
        assert_eq!(pt.turns[1].samples(), &[10.0, 20.0, 30.0]);
        assert_eq!(pt.means()[0], 2.0);
        assert_eq!(pt.all().len(), 6);
    }

    #[test]
    fn warmup_not_recorded() {
        let b = Bench::new("t").repetitions(2).warmup(3);
        let mut calls = 0;
        let pt = b.run_per_turn(|_| {
            calls += 1;
            vec![1.0]
        });
        assert_eq!(calls, 5);
        assert_eq!(pt.turns[0].len(), 2);
    }

    #[test]
    fn timed_runs() {
        let b = Bench::new("t").repetitions(4).warmup(0);
        let s = b.run_timed(|| std::thread::sleep(std::time::Duration::from_millis(1)));
        assert_eq!(s.len(), 4);
        assert!(s.min() >= 0.001);
    }

    #[test]
    fn figure_table_shape() {
        let a = PerTurn {
            turns: vec![Series::from([1.0, 1.1, 0.9]), Series::from([2.0, 2.1, 1.9])],
        };
        let t = per_turn_table("fig", &[("raw", &a), ("tok", &a)]);
        assert_eq!(t.columns.len(), 4);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0].label, "turn 1");
    }
}
