// Known-bad fixture: an optional subsystem whose Default is on. The
// crate ships every optional subsystem off (seed-equivalence rule);
// pallas_lint must report `default-on`.

impl Default for RepairConfig {
    fn default() -> RepairConfig {
        RepairConfig {
            enabled: true,
            interval_ms: 5_000,
            fanout: 1,
        }
    }
}
