// Known-good fixture: every pattern here is allowed, so pallas_lint
// must exit 0 on this file. Not part of the crate's module tree.

struct Node {
    queue: std::sync::Mutex<Vec<u64>>,
    idle: std::sync::Mutex<Vec<u64>>,
    wal: std::sync::Mutex<Vec<u8>>,
    shards: std::sync::RwLock<Vec<u8>>,
}

impl Node {
    // Consistent order on both paths: queue before idle.
    fn drain(&self) {
        let q = self.queue.lock().unwrap();
        let i = self.idle.lock().unwrap();
        drop(i);
        drop(q);
    }

    fn refill(&self) {
        let q = self.queue.lock().unwrap();
        let i = self.idle.lock().unwrap();
        drop(i);
        drop(q);
    }

    // wal -> stripe matches the hierarchy (stripe is last).
    fn snapshot(&self) {
        let w = self.wal.lock().unwrap();
        let shard = self.shards.read().unwrap();
        drop(shard);
        drop(w);
    }

    // Transient guard: released at the end of the statement, so the
    // opposite-order acquisition below is not a cycle.
    fn sizes(&self) -> usize {
        let n = self.idle.lock().unwrap().len();
        let q = self.queue.lock().unwrap();
        q.len() + n
    }
}

#[cfg(test)]
mod tests {
    // Test code may do what it likes: raw sockets, unwraps, reversed
    // lock orders — all production-path rules are scoped out of here.
    fn hammer(n: &super::Node) {
        let s = TcpStream::connect("127.0.0.1:0");
        let i = n.idle.lock().unwrap();
        let q = n.queue.lock().unwrap();
    }
}
