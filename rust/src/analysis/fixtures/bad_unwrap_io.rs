// Known-bad fixture: unwrap on a network path. The directive below
// opts this file into the rule; pallas_lint must report `unwrap-io`
// for the unwrap and the expect, but not for the lock acquisition.
//
// pallas-lint: io-path

fn fetch(&self) -> Vec<u8> {
    let guard = self.state.lock().unwrap();
    let resp = self.pool.round_trip(peer, req).unwrap();
    let body = decode_frame(resp).expect("peer sent a valid frame");
    body
}
