// Known-bad fixture: a cycle that only exists through the call graph.
// `publish` holds the subscriber list and calls `deliver`, which takes
// the member map; `update` holds the member map and calls `publish`.
// No single function inverts the order, but the composition does —
// pallas_lint must report `lock-cycle` (this is the notify-under-lock
// shape that PR 7 removed from membership.rs).

impl View {
    fn publish(&self) {
        let subs = self.subscribers.lock().unwrap();
        for s in subs.iter() {
            self.deliver(s);
        }
        drop(subs);
    }

    fn deliver(&self, s: &Subscriber) {
        let m = self.members.lock().unwrap();
        s.notice(m.len());
        drop(m);
    }

    fn update(&self) {
        let m = self.members.lock().unwrap();
        self.publish();
        drop(m);
    }
}
