// Known-bad fixture: raw connection construction outside the
// transport layer. pallas_lint must report `conn-outside-transport`
// for both sites.

fn dial_directly(addr: &str) {
    let s = TcpStream::connect(addr);
    let c = Connection::open_timeout(addr, 3, 4, 5);
}
