//! Deliberately bad input for the `metric-name` rule: one camelCase
//! name with an unknown prefix, and a pair of well-formed names one
//! edit apart (typo-duplicate). Not part of the crate's module tree;
//! linted standalone by the regression test in `analysis/mod.rs`.

pub struct Registry;

impl Registry {
    pub fn incr(&self, _name: &str, _by: u64) {}
    pub fn observe(&self, _name: &str, _v: f64) {}
}

pub fn record(r: &Registry) {
    // Unknown prefix + camelCase: not on any dashboard's grep path.
    r.incr("ctxManager_Requests", 1);
    // Edit distance 1: the second name is a typo of the first, so half
    // the samples land under a metric nobody reads.
    r.observe("kv_fetch_s", 0.1);
    r.observe("kv_fetch_z", 0.2);
}
