// Known-bad fixture: raw prints in library code. Diagnostics must go
// through the structured event layer (obs::event) so they carry a
// level, a subsystem, and a counter; pallas_lint must report
// `raw-print` for both macros below.

fn on_replication_failure(&self, peer: SocketAddr) {
    eprintln!("replication to {peer} failed");
    println!("retrying");
}
