// Known-bad fixture: direct AB/BA inversion between the replicator
// queue and the pool idle list. pallas_lint must report `lock-cycle`.

impl Node {
    fn forward(&self) {
        let q = self.queue.lock().unwrap();
        let i = self.idle.lock().unwrap();
        drop(i);
        drop(q);
    }

    fn reclaim(&self) {
        let i = self.idle.lock().unwrap();
        let q = self.queue.lock().unwrap();
        drop(q);
        drop(i);
    }
}
