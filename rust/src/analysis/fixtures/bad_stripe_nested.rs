// Known-bad fixture: the WAL mutex is acquired while a store stripe
// is held. Stripes are terminal in the lock hierarchy, so pallas_lint
// must report `stripe-held`.

impl Store {
    fn persist_under_stripe(&self) {
        let shard = self.shards.read().unwrap();
        let w = self.wal.lock().unwrap();
        drop(w);
        drop(shard);
    }
}
