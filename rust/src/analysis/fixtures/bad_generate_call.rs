//! pallas-lint fixture: raw `Engine::generate` calls outside the
//! `llm/` and `runtime/` layers must trip `generate-outside-scheduler`
//! — they bypass the BatchScheduler's admission queue and batch
//! coalescing when `inference.enabled` is set.
//!
//! Not part of the crate — exercised by the lint regression tests.

fn answer_inline(engine: &dyn Engine, ids: &[u32]) -> Generation {
    // Bad: sidesteps whatever wrapper the server installed.
    engine.generate(ids, 64, 0)
}

fn stream_inline(engine: &dyn Engine, ids: &[u32], cb: &mut dyn FnMut(u32)) {
    // Bad: same, streamed spelling.
    engine.generate_streamed(ids, 64, 0, cb);
}
