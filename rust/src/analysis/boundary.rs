//! Boundary lints: rules about *where* things are allowed to happen.
//!
//! - `conn-outside-transport` — raw socket construction
//!   (`TcpStream::connect*`, `Connection::open*`) belongs to the
//!   transport layer (`transport.rs`, `http.rs`); anything else must go
//!   through [`crate::transport::PeerPool`] so pooling, link modelling,
//!   and timeout policy cannot be bypassed.
//! - `unwrap-io` — `unwrap()`/`expect()` on network/disk code paths
//!   turns an ordinary peer failure into a node panic. Applies to the
//!   known I/O modules plus any file carrying the `io-path` marker
//!   directive (see [`io_marker`]); guard acquisitions
//!   (`.lock().unwrap()` and friends) are exempt — lock poisoning is a
//!   deliberate crash-consistency choice, documented in
//!   `docs/ARCHITECTURE.md`.
//! - `default-on` — every optional subsystem ships default-off (the
//!   crate's byte-for-byte seed-equivalence rule): a `Default` impl
//!   must not set a known opt-in flag to `true`.
//! - `raw-print` — `println!`/`eprintln!` in library code bypasses the
//!   structured event layer ([`crate::obs::Obs::event`]), so the output
//!   has no level, no subsystem, and no counter. CLI surfaces (`bin/`,
//!   `main.rs` via the allowlist) and the bench harness (`benchkit.rs`)
//!   are exempt — stdout *is* their interface.
//! - `generate-outside-scheduler` — `Engine::generate` /
//!   `generate_streamed` calls belong to the engine implementations
//!   (`llm/`) and the batching layer (`runtime/`); anywhere else must
//!   hold the engine handed down by the server, which is the
//!   [`crate::runtime::scheduler::BatchScheduler`] wrapper when
//!   `inference.enabled` is set — a raw engine call there bypasses
//!   admission control and batch coalescing. The context manager is the
//!   sanctioned caller and rides the allowlist.

use super::lexer::TokKind;
use super::model::FileModel;
use super::Finding;

/// File-name suffixes that are always treated as I/O paths.
const IO_FILES: &[&str] = &[
    "transport.rs",
    "http.rs",
    "replication.rs",
    "storage.rs",
    "antientropy.rs",
];

/// Files allowed to construct raw connections.
const TRANSPORT_FILES: &[&str] = &["transport.rs", "http.rs"];

/// Callees whose returned `Result` may be unwrapped even on an I/O
/// path: guard acquisition / condvar wakeup, where the `Err` is lock
/// poisoning, not peer failure.
const UNWRAP_EXEMPT: &[&str] = &[
    "lock",
    "read",
    "write",
    "wait",
    "wait_timeout",
    "wait_while",
    "wait_timeout_while",
];

/// Opt-in subsystem flags that must stay `false` in `Default` impls.
const OPT_FIELDS: &[&str] = &["enabled", "delta_sync", "fsync"];

/// The marker directive that opts a file into the `unwrap-io` rule.
/// Assembled at runtime so this source file does not mark itself.
pub fn io_marker() -> String {
    format!("pallas-lint: {}", "io-path")
}

fn has_suffix(path: &str, suffixes: &[&str]) -> bool {
    suffixes.iter().any(|s| path.ends_with(s))
}

/// Run all boundary lints on one file. `src` is the raw source (for
/// the marker-directive check).
pub fn check_file(model: &FileModel, src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    check_conn_sites(model, &mut findings);
    if has_suffix(&model.path, IO_FILES) || src.contains(&io_marker()) {
        check_unwraps(model, &mut findings);
    }
    check_default_on(model, &mut findings);
    check_raw_prints(model, &mut findings);
    check_generate_sites(model, &mut findings);
    findings
}

/// Layers allowed to call an engine's generate methods directly: the
/// engine implementations and the batch scheduler. Path-component
/// match, not suffix — both directories hold several files.
fn engine_layer(path: &str) -> bool {
    path.contains("/llm/") || path.contains("/runtime/")
}

fn check_generate_sites(model: &FileModel, findings: &mut Vec<Finding>) {
    if engine_layer(&model.path) {
        return;
    }
    let toks = &model.toks;
    for i in 1..toks.len().saturating_sub(1) {
        if model.in_tests(i) {
            continue;
        }
        let m = &toks[i];
        if !(m.is_ident("generate") || m.is_ident("generate_streamed")) {
            continue;
        }
        // A *call* — `x.generate(..)` or `Engine::generate(..)` — not a
        // definition (`fn generate`) or a bare mention.
        let called = (toks[i - 1].is_punct(".") || toks[i - 1].is_punct("::"))
            && toks[i + 1].is_punct("(");
        if !called {
            continue;
        }
        findings.push(Finding {
            rule: "generate-outside-scheduler",
            file: model.path.clone(),
            line: m.line,
            message: format!(
                "{}() on an Engine outside llm/ or runtime/ — use the engine handed \
                 down by the server (the BatchScheduler wrapper when batching is on) \
                 so admission control and batch coalescing apply",
                m.text
            ),
        });
    }
}

/// Files whose job is to print: binaries and the bench harness.
fn print_exempt(path: &str) -> bool {
    path.contains("/bin/") || path.starts_with("bin/") || path.ends_with("benchkit.rs")
}

fn check_raw_prints(model: &FileModel, findings: &mut Vec<Finding>) {
    if print_exempt(&model.path) {
        return;
    }
    let toks = &model.toks;
    for i in 0..toks.len().saturating_sub(1) {
        if model.in_tests(i) || !toks[i + 1].is_punct("!") {
            continue;
        }
        let mac = &toks[i];
        if !(mac.is_ident("println") || mac.is_ident("eprintln")) {
            continue;
        }
        // `x!` only counts as a macro invocation when followed by an
        // opening delimiter — rules out `a != b` never, since `!=` lexes
        // as one punct, but keep the guard for odd token streams.
        let invoked = toks
            .get(i + 2)
            .is_some_and(|t| t.is_punct("(") || t.is_punct("[") || t.is_punct("{"));
        if !invoked {
            continue;
        }
        findings.push(Finding {
            rule: "raw-print",
            file: model.path.clone(),
            line: mac.line,
            message: format!(
                "{}! outside the logging layer — use obs::event (leveled, counted) instead",
                mac.text
            ),
        });
    }
}

fn check_conn_sites(model: &FileModel, findings: &mut Vec<Finding>) {
    if has_suffix(&model.path, TRANSPORT_FILES) {
        return;
    }
    let toks = &model.toks;
    for i in 0..toks.len().saturating_sub(2) {
        if model.in_tests(i) || !toks[i + 1].is_punct("::") {
            continue;
        }
        let (ty, method) = (&toks[i], &toks[i + 2]);
        if ty.kind != TokKind::Ident || method.kind != TokKind::Ident {
            continue;
        }
        let raw = (ty.text == "TcpStream" && method.text.starts_with("connect"))
            || (ty.text == "Connection" && method.text.starts_with("open"));
        if raw {
            let what = format!("{}::{}", ty.text, method.text);
            findings.push(Finding {
                rule: "conn-outside-transport",
                file: model.path.clone(),
                line: ty.line,
                message: format!("{what} outside the transport layer — route through PeerPool"),
            });
        }
    }
}

fn check_unwraps(model: &FileModel, findings: &mut Vec<Finding>) {
    let toks = &model.toks;
    for i in 0..toks.len().saturating_sub(2) {
        if !toks[i].is_punct(".") || !toks[i + 2].is_punct("(") {
            continue;
        }
        let m = &toks[i + 1];
        let is_unwrap = m.is_ident("unwrap");
        let is_expect = m.is_ident("expect");
        if (!is_unwrap && !is_expect) || model.in_tests(i) {
            continue;
        }
        if preceded_by_exempt_call(model, i) {
            continue;
        }
        // For expect, carry the message literal so allowlist entries
        // can target one site by its text.
        let detail = if is_expect {
            match toks.get(i + 3) {
                Some(t) if t.kind == TokKind::Str => format!("expect(\"{}\")", t.text),
                _ => "expect(..)".to_string(),
            }
        } else {
            "unwrap()".to_string()
        };
        findings.push(Finding {
            rule: "unwrap-io",
            file: model.path.clone(),
            line: m.line,
            message: format!("{detail} on an I/O path — propagate or degrade instead"),
        });
    }
}

/// Is the `.` at `dot` preceded by a completed call `callee(...)` with
/// `callee` in the exempt set? Covers `x.lock().unwrap()` and the
/// multiline/chained spellings.
fn preceded_by_exempt_call(model: &FileModel, dot: usize) -> bool {
    let toks = &model.toks;
    let mut j = dot as isize - 1;
    if j < 0 || !toks[j as usize].is_punct(")") {
        return false;
    }
    let mut depth = 1;
    j -= 1;
    while j >= 0 && depth > 0 {
        let t = &toks[j as usize];
        if t.is_punct(")") {
            depth += 1;
        } else if t.is_punct("(") {
            depth -= 1;
        }
        j -= 1;
    }
    j >= 0
        && toks[j as usize].kind == TokKind::Ident
        && UNWRAP_EXEMPT.contains(&toks[j as usize].text.as_str())
}

fn check_default_on(model: &FileModel, findings: &mut Vec<Finding>) {
    let toks = &model.toks;
    let mut spans: Vec<(usize, usize)> = Vec::new();
    // `impl Default for X { .. }` (with optional generics after `impl`).
    let mut i = 0;
    while i < toks.len() {
        if toks[i].is_ident("impl") {
            let mut j = i + 1;
            if j < toks.len() && toks[j].is_punct("<") {
                let mut angle = 1;
                j += 1;
                while j < toks.len() && angle > 0 {
                    if toks[j].is_punct("<") {
                        angle += 1;
                    } else if toks[j].is_punct(">") {
                        angle -= 1;
                    }
                    j += 1;
                }
            }
            if j < toks.len() && toks[j].is_ident("Default") {
                let mut k = j;
                while k < toks.len() && !toks[k].is_punct("{") {
                    k += 1;
                }
                if k < toks.len() {
                    let end = super::model::matching_brace(toks, k);
                    spans.push((k, end));
                    i = k + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    // Standalone `fn default` bodies count too.
    for f in &model.fns {
        if f.name == "default" && !f.in_tests {
            spans.push((f.body_start, f.body_end));
        }
    }
    // `fn default` inside `impl Default` makes the spans overlap — track
    // flagged token indices so each site is reported once.
    let mut flagged: Vec<usize> = Vec::new();
    for &(lo, hi) in &spans {
        for i in lo..hi.min(toks.len().saturating_sub(2)) {
            if model.in_tests(i) || flagged.contains(&i) {
                continue;
            }
            if toks[i].kind == TokKind::Ident
                && OPT_FIELDS.contains(&toks[i].text.as_str())
                && toks[i + 1].is_punct(":")
                && toks[i + 2].is_ident("true")
            {
                flagged.push(i);
                let field = &toks[i].text;
                let message =
                    format!("`{field}: true` in a Default impl — optional subsystems ship off");
                findings.push(Finding {
                    rule: "default-on",
                    file: model.path.clone(),
                    line: toks[i].line,
                    message,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(path: &str, src: &str) -> Vec<Finding> {
        check_file(&FileModel::build(path, src), src)
    }

    #[test]
    fn raw_connect_flagged_outside_transport() {
        let src = "fn f() { let s = TcpStream::connect(addr); }";
        let f = check("src/cluster/mod.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "conn-outside-transport");
        assert!(check("src/transport.rs", src).is_empty());
        assert!(check("src/http.rs", src).is_empty());
    }

    #[test]
    fn connection_open_flagged_outside_transport() {
        let src = "fn f() { let c = Connection::open_timeout(addr, m, l, t); }";
        assert_eq!(check("src/server/mod.rs", src).len(), 1);
    }

    #[test]
    fn raw_connect_in_tests_is_fine() {
        let src = r#"
            #[cfg(test)]
            mod tests {
                fn f() { let s = TcpStream::connect(addr); }
            }
        "#;
        assert!(check("src/cluster/mod.rs", src).is_empty());
    }

    #[test]
    fn unwrap_on_io_file_flagged() {
        let src = "fn f() { let v = peer_response().unwrap(); }";
        let f = check("src/kvstore/replication.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "unwrap-io");
        // Same code on a non-I/O file: no finding.
        assert!(check("src/metrics.rs", src).is_empty());
    }

    #[test]
    fn marker_directive_opts_a_file_in() {
        let src = format!("// {}\nfn f() {{ let v = resp().unwrap(); }}", io_marker());
        assert_eq!(check("src/anywhere.rs", &src).len(), 1);
    }

    #[test]
    fn lock_unwrap_is_exempt() {
        let src = r#"
            fn f(&self) {
                let g = self.queue.lock().unwrap();
                let r = self.map.read().unwrap();
                let (mut fl, _) = self.cvar.wait_timeout_while(fl, t, |k| !*k).unwrap();
            }
        "#;
        assert!(check("src/kvstore/replication.rs", src).is_empty());
    }

    #[test]
    fn expect_message_lands_in_finding() {
        let src = r#"fn f() { spawn_thread().expect("spawn replicator"); }"#;
        let f = check("src/kvstore/replication.rs", src);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("spawn replicator"), "{}", f[0].message);
    }

    #[test]
    fn default_on_flag_is_caught() {
        let src = r#"
            impl Default for RepairConfig {
                fn default() -> RepairConfig {
                    RepairConfig { enabled: true, interval: 10 }
                }
            }
        "#;
        let f = check("src/kvstore/antientropy.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "default-on");
    }

    #[test]
    fn default_off_and_non_default_literals_pass() {
        let src = r#"
            impl Default for RepairConfig {
                fn default() -> RepairConfig {
                    RepairConfig { enabled: false }
                }
            }
            fn make_test_cfg() -> RepairConfig {
                RepairConfig { enabled: true }
            }
        "#;
        assert!(check("src/kvstore/antientropy.rs", src).is_empty());
    }

    #[test]
    fn raw_print_flagged_in_library_code() {
        let src = r#"fn f() { eprintln!("peer {p} lost"); println!("ok"); }"#;
        let f = check("src/kvstore/replication.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "raw-print"));
        assert!(f[0].message.contains("outside the logging layer"));
    }

    #[test]
    fn raw_print_exempt_in_bins_benchkit_and_tests() {
        let src = r#"fn f() { println!("report"); }"#;
        assert!(check("src/bin/discedge.rs", src).is_empty());
        assert!(check("src/benchkit.rs", src).is_empty());
        let in_tests = r#"
            #[cfg(test)]
            mod tests {
                fn f() { eprintln!("debugging a test"); }
            }
        "#;
        assert!(check("src/kvstore/mod.rs", in_tests).is_empty());
    }

    #[test]
    fn negation_is_not_a_print() {
        let src = "fn f(println: bool) -> bool { !println }";
        assert!(check("src/server/mod.rs", src).is_empty());
    }

    #[test]
    fn generate_call_flagged_outside_engine_layer() {
        let src = "fn f(e: &dyn Engine) { let g = e.generate(&ids, 64, 0); }";
        let f = check("src/server/mod.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "generate-outside-scheduler");
        assert!(f[0].message.contains("generate()"), "{}", f[0].message);
        // The engine and scheduler layers are exempt.
        assert!(check("src/llm/mock.rs", src).is_empty());
        assert!(check("src/runtime/scheduler.rs", src).is_empty());
    }

    #[test]
    fn generate_streamed_and_path_form_are_flagged() {
        let src = r#"
            fn f(e: &dyn Engine) {
                e.generate_streamed(&ids, 64, 0, &mut cb);
                let g = Engine::generate(e, &ids, 64, 0);
            }
        "#;
        let f = check("src/cluster/mod.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f[0].message.contains("generate_streamed()"));
    }

    #[test]
    fn generate_definitions_and_tests_are_not_calls() {
        let defs = "impl Engine for MockEngine { fn generate(&self, ids: &[u32]) -> G { todo!() } }";
        assert!(check("src/server/mod.rs", defs).is_empty());
        let in_tests = r#"
            #[cfg(test)]
            mod tests {
                fn t(e: &dyn Engine) { e.generate(&[1], 4, 0); }
            }
        "#;
        assert!(check("src/server/mod.rs", in_tests).is_empty());
    }

    #[test]
    fn generic_impl_default_is_handled() {
        let src = r#"
            impl<T: Clone> Default for Wrapper<T> {
                fn default() -> Wrapper<T> {
                    Wrapper { enabled: true, inner: None }
                }
            }
        "#;
        assert_eq!(check("src/config.rs", src).len(), 1);
    }
}
