//! `pallas-lint`: zero-dependency static analysis for this crate's
//! concurrency and boundary invariants.
//!
//! The crate documents its locking hierarchy and layering rules in
//! `docs/ARCHITECTURE.md` ("Concurrency invariants"), and the runtime
//! lockdep wrapper in [`crate::sync`] enforces the lock-order part
//! under `debug_assertions` — but only on paths a test actually
//! executes. This module is the static half: a token-level analysis
//! over `rust/src` that checks every path, run in CI as a blocking
//! job and locally via `cargo run --bin pallas_lint -- src`.
//!
//! Rules:
//!
//! - `lock-cycle` / `stripe-held` — lock-order analysis over an
//!   approximate call graph ([`lockorder`]).
//! - `conn-outside-transport`, `unwrap-io`, `default-on`, `raw-print`,
//!   `generate-outside-scheduler` — layering and robustness lints
//!   ([`boundary`]).
//! - `metric-name` — metric literals passed to the registry must be
//!   snake_case with a known subsystem prefix; distance-1 near-miss
//!   pairs are typo-duplicates ([`metricname`]).
//!
//! Deliberate violations are suppressed through an allowlist file
//! (`rust/lint-allow.txt`) with one `rule file-suffix
//! message-substring` entry per line — suppressions are reviewable
//! diffs, not inline attributes scattered through the tree.
//!
//! Known-bad inputs for every rule live under `src/analysis/fixtures/`;
//! they are not part of the crate's module tree and are excluded from
//! directory scans, but each one is covered by a regression test here
//! asserting its rule still fires.

pub mod boundary;
pub mod lexer;
pub mod lockorder;
pub mod metricname;
pub mod model;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Stable rule identifier (`lock-cycle`, `unwrap-io`, ...).
    pub rule: &'static str,
    /// Path of the offending file, as handed to the scanner.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// Human-readable description of the violation.
    pub message: String,
}

/// Reviewable suppressions for deliberate violations.
///
/// File format: one entry per line, `rule file-suffix
/// message-substring`; blank lines and `#` comments are skipped. An
/// entry matches a finding when the rule is equal, the finding's file
/// path ends with the suffix, and its message contains the substring.
#[derive(Debug, Default)]
pub struct Allowlist {
    entries: Vec<(String, String, String)>,
}

impl Allowlist {
    /// Parse allowlist text.
    pub fn parse(text: &str) -> Allowlist {
        let mut entries = Vec::new();
        for raw in text.lines() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(3, ' ');
            let rule = parts.next();
            let file = parts.next();
            let msg = parts.next();
            if let (Some(rule), Some(file), Some(msg)) = (rule, file, msg) {
                entries.push((rule.to_string(), file.to_string(), msg.trim().to_string()));
            }
        }
        Allowlist { entries }
    }

    /// Load an allowlist file; a missing file is an empty allowlist.
    pub fn load(path: &Path) -> Allowlist {
        match fs::read_to_string(path) {
            Ok(text) => Allowlist::parse(&text),
            Err(_) => Allowlist::default(),
        }
    }

    /// Does any entry suppress this finding?
    pub fn allows(&self, f: &Finding) -> bool {
        self.entries.iter().any(|(rule, file, msg)| {
            f.rule == rule.as_str()
                && f.file.ends_with(file.as_str())
                && f.message.contains(msg.as_str())
        })
    }

    /// Drop every finding the allowlist suppresses.
    pub fn filter(&self, findings: Vec<Finding>) -> Vec<Finding> {
        findings.into_iter().filter(|f| !self.allows(f)).collect()
    }

    /// Number of entries (for reporting).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the allowlist has no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// All `.rs` files under `root`, sorted for deterministic output.
/// Anything under a `fixtures` path component is skipped — those are
/// the deliberately bad lint regression inputs.
pub fn collect_rs_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    walk(root, &mut out);
    out.sort();
    out
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.components().any(|c| c.as_os_str() == "fixtures") {
            continue;
        }
        if path.is_dir() {
            walk(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Lint an explicit set of `.rs` files: per-file boundary rules, plus
/// the lock-order analysis run across all of them as one call graph.
pub fn run_files(paths: &[PathBuf]) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    let mut table = lockorder::FnTable::new();
    for path in paths {
        let src = fs::read_to_string(path)?;
        let display = path.display().to_string();
        let model = model::FileModel::build(&display, &src);
        findings.extend(boundary::check_file(&model, &src));
        findings.extend(metricname::check_file(&model));
        table.add_file(&model);
    }
    findings.extend(table.analyze());
    Ok(findings)
}

/// Lint every `.rs` file under `root`.
pub fn run(root: &Path) -> io::Result<Vec<Finding>> {
    run_files(&collect_rs_files(root))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("src/analysis/fixtures").join(name)
    }

    fn lint_fixture(name: &str) -> Vec<Finding> {
        run_files(&[fixture(name)]).expect("fixture readable")
    }

    fn finding(rule: &'static str, file: &str, message: &str) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line: 1,
            message: message.to_string(),
        }
    }

    #[test]
    fn shipped_tree_is_clean_under_the_shipped_allowlist() {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        let allow = Allowlist::load(&manifest.join("lint-allow.txt"));
        assert!(!allow.is_empty(), "shipped allowlist should parse");
        let findings = allow.filter(run(&manifest.join("src")).expect("scan src"));
        assert!(findings.is_empty(), "{findings:#?}");
    }

    #[test]
    fn good_fixture_is_clean() {
        let findings = lint_fixture("good_clean.rs");
        assert!(findings.is_empty(), "{findings:#?}");
    }

    #[test]
    fn bad_fixtures_each_trip_their_rule() {
        let cases = [
            ("bad_cycle.rs", "lock-cycle"),
            ("bad_stripe_nested.rs", "stripe-held"),
            ("bad_callback_cycle.rs", "lock-cycle"),
            ("bad_boundary_connect.rs", "conn-outside-transport"),
            ("bad_unwrap_io.rs", "unwrap-io"),
            ("bad_default_on.rs", "default-on"),
            ("bad_print.rs", "raw-print"),
            ("bad_metric_name.rs", "metric-name"),
            ("bad_generate_call.rs", "generate-outside-scheduler"),
        ];
        for (name, rule) in cases {
            let findings = lint_fixture(name);
            let hit = findings.iter().any(|f| f.rule == rule);
            assert!(hit, "{name} should trip {rule}: {findings:?}");
        }
    }

    #[test]
    fn allowlist_matches_rule_suffix_and_substring() {
        let allow = Allowlist::parse("unwrap-io replication.rs spawn replicator");
        let hit = finding("unwrap-io", "src/kvstore/replication.rs", "spawn replicator here");
        assert!(allow.allows(&hit));
        let wrong_rule = finding("lock-cycle", "src/kvstore/replication.rs", "spawn replicator");
        assert!(!allow.allows(&wrong_rule));
        let wrong_file = finding("unwrap-io", "src/kvstore/storage.rs", "spawn replicator");
        assert!(!allow.allows(&wrong_file));
        let wrong_msg = finding("unwrap-io", "src/kvstore/replication.rs", "other thing");
        assert!(!allow.allows(&wrong_msg));
    }

    #[test]
    fn allowlist_skips_comments_and_blanks() {
        let allow = Allowlist::parse("# a comment\n\n   \n");
        assert!(allow.is_empty());
        assert!(!allow.allows(&finding("unwrap-io", "x.rs", "m")));
    }

    #[test]
    fn collect_skips_fixture_dirs() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let files = collect_rs_files(&src);
        assert!(!files.is_empty());
        let clean = files.iter().all(|p| !p.to_string_lossy().contains("fixtures"));
        assert!(clean);
    }
}
