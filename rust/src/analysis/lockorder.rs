//! Lock-order analysis: per-function lock acquisitions propagated over
//! an approximate call graph.
//!
//! The crate names every shared lock (see `crate::sync::classes`), and
//! the receiver identifiers at acquisition sites are stable
//! (`self.shards[i].read()`, `queue.lock()`, `self.wal.lock()`, ...),
//! so a token-level pass can map `<receiver>.lock()/.read()/.write()`
//! to a lock class without type information. Each function body is
//! walked with a small held-guard state machine; acquisitions made
//! while another class is held become edges in a global acquisition
//! graph, and calls made under a held guard pull in the callee's
//! transitive acquisitions. Two rules fire on the result:
//!
//! - `lock-cycle` — the acquisition graph has a cycle.
//! - `stripe-held` — any lock is acquired (directly or via a call)
//!   while a store stripe is held; stripes are terminal in the crate
//!   hierarchy (`docs/ARCHITECTURE.md`, "Concurrency invariants").
//!
//! The pass is deliberately conservative in both directions: callee
//! resolution is by bare name with a deny-list of ubiquitous std
//! method names (`insert`, `len`, `clone`, ...) that would otherwise
//! alias crate functions, and guard lifetimes are over-approximated to
//! the enclosing block for scrutinee positions (matching Rust's
//! temporary-lifetime extension in `if let`/`match`).

use std::collections::{BTreeMap, BTreeSet, HashMap};

use super::lexer::{Tok, TokKind};
use super::model::FileModel;
use super::Finding;

/// The terminal lock class: nothing may be acquired while it is held.
const TERMINAL: &str = "store.stripe";

/// Map an acquisition receiver identifier to its lock class.
fn class_for(recv: &str) -> Option<&'static str> {
    match recv {
        "subscribers" => Some("membership.subscribers"),
        "members" => Some("membership.members"),
        "queues" => Some("hints.queues"),
        "down" => Some("hints.down"),
        "forwards" => Some("hints.forwards"),
        "on_evict" => Some("hints.on_evict"),
        "queue" => Some("replicator.queue"),
        "admission" => Some("scheduler.admission"),
        "idle" => Some("pool.idle"),
        "forest" => Some("merkle.forest"),
        "trees" => Some("merkle.trees"),
        "wal" => Some("storage.wal"),
        "shard" | "shards" | "stripe" => Some(TERMINAL),
        _ => None,
    }
}

/// Callee names that are never resolved to crate functions: ubiquitous
/// std container/guard method names whose bare-name union with crate
/// items (`Store::len`, `Replicator::drop`, `MembershipView::join`,
/// dozens of `fn new`s) would manufacture false call edges. Kept as one
/// string literal so rustfmt cannot reflow it.
const DENY: &str = "clone contains drop entry extend find flush get get_mut insert is_empty \
    iter join len lock map new next open pop push read remove retain set take unwrap expect \
    wait write";

/// Keywords and value constructors that look like calls token-wise.
const NOT_CALLS: &str = "if while for match return loop let mut ref move in as fn impl pub \
    use mod where unsafe else break continue struct enum trait type const static crate self \
    Self super dyn box async await Some None Ok Err";

fn in_list(list: &str, name: &str) -> bool {
    list.split_whitespace().any(|w| w == name)
}

/// How long an acquired guard is considered held.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum HeldKind {
    /// Transient chain (`x.lock().unwrap().len()`): until the `;` at
    /// the acquisition depth, or the end of the enclosing block for a
    /// tail expression.
    Stmt,
    /// Scrutinee position (`if let`/`match`): until the block that
    /// opened at the acquisition depth closes.
    Brace,
    /// `let g = x.lock().unwrap();`: until the enclosing block closes
    /// or an explicit `drop(g)`.
    Binding,
}

#[derive(Debug, Clone)]
struct Held {
    class: &'static str,
    kind: HeldKind,
    depth: i32,
    name: Option<String>,
}

/// One observed "B acquired while A held" pair with an example site.
#[derive(Debug, Clone)]
struct RawEdge {
    from: &'static str,
    to: &'static str,
    file: String,
    line: u32,
    note: String,
}

/// A call made while locks were held; resolved after the whole table
/// is built, using the callee's transitive acquisitions.
#[derive(Debug, Clone)]
struct HeldCall {
    held: Vec<&'static str>,
    callee: String,
    file: String,
    line: u32,
    in_fn: String,
}

#[derive(Debug, Default)]
struct FnData {
    acquires: BTreeSet<&'static str>,
    calls: BTreeSet<String>,
}

/// Cross-file function table; feed it every `FileModel`, then call
/// [`FnTable::analyze`] once.
#[derive(Debug, Default)]
pub struct FnTable {
    fns: HashMap<String, FnData>,
    edges: Vec<RawEdge>,
    held_calls: Vec<HeldCall>,
}

/// Walk backward from the `.` of `<recv>.lock()` to the receiver
/// identifier, skipping balanced `(...)`/`[...]` groups and `.N` tuple
/// indices.
fn walk_back(toks: &[Tok], dot: usize) -> Option<String> {
    let mut j = dot as isize - 1;
    while j >= 0 {
        let t = &toks[j as usize];
        if t.is_punct(")") || t.is_punct("]") {
            let (open, close) = if t.text == ")" { ("(", ")") } else { ("[", "]") };
            let mut depth = 1;
            j -= 1;
            while j >= 0 && depth > 0 {
                let u = &toks[j as usize];
                if u.is_punct(close) {
                    depth += 1;
                } else if u.is_punct(open) {
                    depth -= 1;
                }
                j -= 1;
            }
        } else if t.kind == TokKind::Num {
            j -= 1;
            if j >= 0 && toks[j as usize].is_punct(".") {
                j -= 1;
            }
        } else if t.kind == TokKind::Ident {
            return Some(t.text.clone());
        } else {
            return None;
        }
    }
    None
}

fn is_acquire_method(t: &Tok) -> bool {
    t.is_ident("lock") || t.is_ident("read") || t.is_ident("write")
}

/// Does the chain after the acquisition's `)` end as exactly
/// `.unwrap()`/`.expect(..)` followed by `;`? (That is the shape of a
/// guard binding; anything longer is a transient.)
fn chain_ends_at_statement(toks: &[Tok], after: usize) -> bool {
    if after + 2 >= toks.len() || !toks[after].is_punct(".") {
        return false;
    }
    let m = &toks[after + 1];
    if !(m.is_ident("unwrap") || m.is_ident("expect")) || !toks[after + 2].is_punct("(") {
        return false;
    }
    let mut depth = 1;
    let mut k = after + 3;
    while k < toks.len() && depth > 0 {
        if toks[k].is_punct("(") {
            depth += 1;
        } else if toks[k].is_punct(")") {
            depth -= 1;
        }
        k += 1;
    }
    k < toks.len() && toks[k].is_punct(";")
}

impl FnTable {
    /// Empty table.
    pub fn new() -> FnTable {
        FnTable::default()
    }

    /// Scan one file's functions into the table. Test-module functions
    /// are skipped entirely.
    pub fn add_file(&mut self, model: &FileModel) {
        for f in &model.fns {
            if f.in_tests {
                continue;
            }
            self.scan_fn(model, &f.name, f.body_start, f.body_end);
        }
    }

    fn scan_fn(&mut self, model: &FileModel, fn_name: &str, start: usize, end: usize) {
        let toks = &model.toks;
        let data = self.fns.entry(fn_name.to_string()).or_default();
        let mut held: Vec<Held> = Vec::new();
        let mut depth = 0i32;
        let mut pending_bind: Option<(String, i32)> = None;
        let mut i = start;
        while i <= end && i < toks.len() {
            let t = &toks[i];
            // Explicit guard drop: `drop(g)` releases the binding g.
            if t.is_ident("drop")
                && i + 3 < toks.len()
                && toks[i + 1].is_punct("(")
                && toks[i + 2].kind == TokKind::Ident
                && toks[i + 3].is_punct(")")
            {
                let g = toks[i + 2].text.clone();
                held.retain(|h| {
                    !(h.kind == HeldKind::Binding && h.name.as_deref() == Some(g.as_str()))
                });
                i += 4;
                continue;
            }
            if t.is_punct("{") {
                for h in held.iter_mut() {
                    if h.kind == HeldKind::Stmt && h.depth == depth {
                        h.kind = HeldKind::Brace;
                    }
                }
                depth += 1;
                i += 1;
                continue;
            }
            if t.is_punct("}") {
                depth -= 1;
                held.retain(|h| match h.kind {
                    HeldKind::Brace => depth > h.depth,
                    // Tail-expression transients (`{ x.lock().unwrap().f() }`
                    // with no `;`) die with their block too.
                    HeldKind::Binding | HeldKind::Stmt => depth >= h.depth,
                });
                i += 1;
                continue;
            }
            if t.is_punct(";") {
                held.retain(|h| !(h.kind == HeldKind::Stmt && h.depth == depth));
                pending_bind = None;
                i += 1;
                continue;
            }
            if t.is_ident("let") {
                let mut j = i + 1;
                if j < toks.len() && toks[j].is_ident("mut") {
                    j += 1;
                }
                if j + 1 < toks.len() && toks[j].kind == TokKind::Ident && toks[j + 1].is_punct("=")
                {
                    pending_bind = Some((toks[j].text.clone(), depth));
                }
                i += 1;
                continue;
            }
            // Acquisition: `.` lock|read|write `(` `)` (zero-arg only).
            if t.is_punct(".")
                && i + 3 < toks.len()
                && is_acquire_method(&toks[i + 1])
                && toks[i + 2].is_punct("(")
                && toks[i + 3].is_punct(")")
            {
                if let Some(class) = walk_back(toks, i).as_deref().and_then(class_for) {
                    let line = toks[i + 1].line;
                    for h in &held {
                        if h.class != class {
                            self.edges.push(RawEdge {
                                from: h.class,
                                to: class,
                                file: model.path.clone(),
                                line,
                                note: format!("in fn {fn_name}"),
                            });
                        }
                    }
                    data.acquires.insert(class);
                    let mut kind = HeldKind::Stmt;
                    let mut name = None;
                    if let Some((n, d)) = &pending_bind {
                        if *d == depth && chain_ends_at_statement(toks, i + 4) {
                            kind = HeldKind::Binding;
                            name = Some(n.clone());
                        }
                    }
                    held.push(Held {
                        class,
                        kind,
                        depth,
                        name,
                    });
                }
                i += 4;
                continue;
            }
            // Call: Ident `(` — not a macro, keyword, or denied name.
            if t.kind == TokKind::Ident
                && i + 1 < toks.len()
                && toks[i + 1].is_punct("(")
                && !in_list(NOT_CALLS, &t.text)
                && !in_list(DENY, &t.text)
                && !(i > 0 && toks[i - 1].is_ident("fn"))
            {
                data.calls.insert(t.text.clone());
                if !held.is_empty() {
                    self.held_calls.push(HeldCall {
                        held: held.iter().map(|h| h.class).collect(),
                        callee: t.text.clone(),
                        file: model.path.clone(),
                        line: t.line,
                        in_fn: fn_name.to_string(),
                    });
                }
                i += 1;
                continue;
            }
            i += 1;
        }
    }

    /// Compute transitive acquisitions, materialize the acquisition
    /// graph, and report cycle / stripe-held findings.
    pub fn analyze(&self) -> Vec<Finding> {
        // Fixpoint: acquires(f) = direct(f) ∪ acquires(every callee).
        let mut trans: HashMap<&str, BTreeSet<&'static str>> = HashMap::new();
        for (name, data) in &self.fns {
            trans.insert(name.as_str(), data.acquires.clone());
        }
        loop {
            let mut changed = false;
            for (name, data) in &self.fns {
                let mut acc = trans[name.as_str()].clone();
                for callee in &data.calls {
                    if let Some(sub) = trans.get(callee.as_str()) {
                        for &c in sub {
                            acc.insert(c);
                        }
                    }
                }
                if acc.len() > trans[name.as_str()].len() {
                    trans.insert(name.as_str(), acc);
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }

        // Edge set: direct nested acquisitions plus calls under guards.
        let mut raw: Vec<RawEdge> = self.edges.clone();
        for hc in &self.held_calls {
            if let Some(sub) = trans.get(hc.callee.as_str()) {
                for &to in sub {
                    for &from in &hc.held {
                        if from != to {
                            raw.push(RawEdge {
                                from,
                                to,
                                file: hc.file.clone(),
                                line: hc.line,
                                note: format!("in fn {} via call to {}", hc.in_fn, hc.callee),
                            });
                        }
                    }
                }
            }
        }
        let mut adj: BTreeMap<&'static str, BTreeSet<&'static str>> = BTreeMap::new();
        let mut example: BTreeMap<(&'static str, &'static str), &RawEdge> = BTreeMap::new();
        for e in &raw {
            adj.entry(e.from).or_default().insert(e.to);
            example.entry((e.from, e.to)).or_insert(e);
        }

        let mut findings = Vec::new();
        // Rule: nothing is acquired while a terminal (stripe) lock is
        // held.
        for (&(from, _to), e) in &example {
            if from == TERMINAL {
                findings.push(Finding {
                    rule: "stripe-held",
                    file: e.file.clone(),
                    line: e.line,
                    message: format!("{} acquired while {} held ({})", e.to, e.from, e.note),
                });
            }
        }
        // Rule: the acquisition graph is acyclic.
        for cycle in find_cycles(&adj) {
            let mut path = cycle.clone();
            path.push(cycle[0]);
            let from = cycle[0];
            let to = path[1];
            let (file, line, note) = match example.get(&(from, to)) {
                Some(e) => (e.file.clone(), e.line, e.note.clone()),
                None => (String::from("<unknown>"), 0, String::new()),
            };
            findings.push(Finding {
                rule: "lock-cycle",
                file,
                line,
                message: format!("lock acquisition cycle: {} ({note})", path.join(" -> ")),
            });
        }
        findings
    }
}

/// Cycles in the acquisition graph, deduplicated by node set (one
/// report per strongly connected loop, not one per rotation).
fn find_cycles(adj: &BTreeMap<&'static str, BTreeSet<&'static str>>) -> Vec<Vec<&'static str>> {
    fn dfs(
        node: &'static str,
        adj: &BTreeMap<&'static str, BTreeSet<&'static str>>,
        path: &mut Vec<&'static str>,
        visited: &mut BTreeSet<&'static str>,
        cycles: &mut Vec<Vec<&'static str>>,
        seen: &mut BTreeSet<String>,
    ) {
        if let Some(pos) = path.iter().position(|&n| n == node) {
            let cycle = path[pos..].to_vec();
            let mut key = cycle.clone();
            key.sort_unstable();
            if seen.insert(key.join(">")) {
                cycles.push(cycle);
            }
            return;
        }
        if !visited.insert(node) {
            return;
        }
        path.push(node);
        if let Some(nexts) = adj.get(node) {
            for &n in nexts {
                dfs(n, adj, path, visited, cycles, seen);
            }
        }
        path.pop();
    }
    let mut cycles = Vec::new();
    let mut visited = BTreeSet::new();
    let mut seen = BTreeSet::new();
    for &start in adj.keys() {
        let mut path = Vec::new();
        dfs(start, adj, &mut path, &mut visited, &mut cycles, &mut seen);
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;

    fn analyze_src(src: &str) -> Vec<Finding> {
        let model = FileModel::build("test.rs", src);
        let mut table = FnTable::new();
        table.add_file(&model);
        table.analyze()
    }

    #[test]
    fn consistent_order_is_clean() {
        let findings = analyze_src(
            r#"
            fn a(&self) {
                let q = self.queue.lock().unwrap();
                let i = self.idle.lock().unwrap();
                drop(i);
                drop(q);
            }
            fn b(&self) {
                let q = self.queue.lock().unwrap();
                let i = self.idle.lock().unwrap();
            }
            "#,
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn direct_ab_ba_cycle_is_reported() {
        let findings = analyze_src(
            r#"
            fn a(&self) {
                let q = self.queue.lock().unwrap();
                let i = self.idle.lock().unwrap();
            }
            fn b(&self) {
                let i = self.idle.lock().unwrap();
                let q = self.queue.lock().unwrap();
            }
            "#,
        );
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, "lock-cycle");
    }

    #[test]
    fn transitive_cycle_through_calls_is_reported() {
        let findings = analyze_src(
            r#"
            fn grab_idle(&self) { let i = self.idle.lock().unwrap(); }
            fn grab_queue(&self) { let q = self.queue.lock().unwrap(); }
            fn a(&self) {
                let q = self.queue.lock().unwrap();
                self.grab_idle();
            }
            fn b(&self) {
                let i = self.idle.lock().unwrap();
                self.grab_queue();
            }
            "#,
        );
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, "lock-cycle");
        assert!(findings[0].message.contains("via call to"));
    }

    #[test]
    fn stripe_is_terminal() {
        let findings = analyze_src(
            r#"
            fn bad(&self) {
                let shard = self.shards.read().unwrap();
                let w = self.wal.lock().unwrap();
            }
            "#,
        );
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, "stripe-held");
    }

    #[test]
    fn wal_then_stripe_is_allowed() {
        let findings = analyze_src(
            r#"
            fn snapshot(&self) {
                let w = self.wal.lock().unwrap();
                let shard = self.shards.read().unwrap();
            }
            "#,
        );
        // wal -> stripe matches the hierarchy: no cycle, and the stripe
        // is the target of the edge, not the source.
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn transient_guard_released_at_statement_end() {
        let findings = analyze_src(
            r#"
            fn a(&self) {
                let n = self.queue.lock().unwrap().len();
                let i = self.idle.lock().unwrap();
            }
            fn b(&self) {
                let n = self.idle.lock().unwrap().len();
                let q = self.queue.lock().unwrap();
            }
            "#,
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn explicit_drop_releases_binding() {
        let findings = analyze_src(
            r#"
            fn a(&self) {
                let q = self.queue.lock().unwrap();
                drop(q);
                let i = self.idle.lock().unwrap();
            }
            fn b(&self) {
                let i = self.idle.lock().unwrap();
                drop(i);
                let q = self.queue.lock().unwrap();
            }
            "#,
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn scrutinee_guard_is_held_through_block() {
        let findings = analyze_src(
            r#"
            fn bad(&self) {
                if let Some(v) = self.shards.read().unwrap().front() {
                    let w = self.wal.lock().unwrap();
                }
            }
            "#,
        );
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, "stripe-held");
    }

    #[test]
    fn test_modules_are_ignored() {
        let findings = analyze_src(
            r#"
            #[cfg(test)]
            mod tests {
                fn helper(&self) {
                    let shard = self.shards.read().unwrap();
                    let w = self.wal.lock().unwrap();
                }
            }
            "#,
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn cycle_finder_dedupes_rotations() {
        let mut adj: BTreeMap<&'static str, BTreeSet<&'static str>> = BTreeMap::new();
        adj.entry("a").or_default().insert("b");
        adj.entry("b").or_default().insert("c");
        adj.entry("c").or_default().insert("a");
        let cycles = find_cycles(&adj);
        assert_eq!(cycles.len(), 1);
        assert_eq!(cycles[0].len(), 3);
    }

    #[test]
    fn cycle_finder_clean_dag() {
        let mut adj: BTreeMap<&'static str, BTreeSet<&'static str>> = BTreeMap::new();
        adj.entry("a").or_default().insert("b");
        adj.entry("a").or_default().insert("c");
        adj.entry("b").or_default().insert("c");
        assert!(find_cycles(&adj).is_empty());
    }
}
