//! Structural model of one source file, built from the token stream.
//!
//! The lint passes need just enough shape to reason per-function:
//! where each `fn` body starts and ends (token indices of its braces),
//! and which token ranges live inside `#[cfg(test)] mod ... { }` blocks
//! so test-only code can be exempted from production-path rules.

use super::lexer::{lex, Tok, TokKind};

/// One `fn` item: its name and the token range of its body.
#[derive(Debug, Clone)]
pub struct FnSpan {
    /// Function name as written (no path qualification).
    pub name: String,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Token index of the body's opening `{`.
    pub body_start: usize,
    /// Token index of the body's matching `}`.
    pub body_end: usize,
    /// True when the item sits inside a `#[cfg(test)]` module.
    pub in_tests: bool,
}

/// Lexed file plus the derived function and test-module structure.
#[derive(Debug)]
pub struct FileModel {
    /// Display path used in findings.
    pub path: String,
    /// Full token stream.
    pub toks: Vec<Tok>,
    /// All `fn` items with resolvable bodies.
    pub fns: Vec<FnSpan>,
    /// Token ranges `[start, end]` covered by test modules.
    pub test_spans: Vec<(usize, usize)>,
}

/// Find the matching `}` for the `{` at `open`, or the last token index
/// if the stream is truncated.
pub fn matching_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct("{") {
            depth += 1;
        } else if t.is_punct("}") {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Locate `mod <name> {` items that sit under a `#[cfg(test)]`-style
/// attribute, by scanning a small token window before the `mod` keyword
/// for `cfg` and `test` identifiers.
fn find_test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i + 2 < toks.len() {
        if toks[i].is_ident("mod")
            && toks[i + 1].kind == TokKind::Ident
            && toks[i + 2].is_punct("{")
        {
            let lo = i.saturating_sub(10);
            let window = &toks[lo..i];
            let has_cfg = window.iter().any(|t| t.is_ident("cfg"));
            let has_test = window.iter().any(|t| t.is_ident("test") || t.is_ident("tests"));
            if has_cfg && has_test {
                let end = matching_brace(toks, i + 2);
                spans.push((i, end));
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    spans
}

/// Extract every `fn` item with a body. The body is the first `{` after
/// the name at zero paren/bracket depth (skipping the argument list,
/// generics, return type, and where clause); a `;` at that depth means
/// a bodiless declaration, which is skipped.
fn find_fns(toks: &[Tok], test_spans: &[(usize, usize)]) -> Vec<FnSpan> {
    let mut fns = Vec::new();
    let mut i = 0;
    while i + 1 < toks.len() {
        if toks[i].is_ident("fn") && toks[i + 1].kind == TokKind::Ident {
            let name = toks[i + 1].text.clone();
            let line = toks[i].line;
            let mut paren = 0i32;
            let mut bracket = 0i32;
            let mut j = i + 2;
            let mut body = None;
            while j < toks.len() {
                let t = &toks[j];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "(" => paren += 1,
                        ")" => paren -= 1,
                        "[" => bracket += 1,
                        "]" => bracket -= 1,
                        "{" if paren == 0 && bracket == 0 => {
                            body = Some(j);
                            break;
                        }
                        ";" if paren == 0 && bracket == 0 => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            if let Some(start) = body {
                let end = matching_brace(toks, start);
                let in_tests = test_spans.iter().any(|&(lo, hi)| i >= lo && i <= hi);
                fns.push(FnSpan {
                    name,
                    line,
                    body_start: start,
                    body_end: end,
                    in_tests,
                });
                i = start + 1;
                continue;
            }
        }
        i += 1;
    }
    fns
}

impl FileModel {
    /// Lex and model one file's source text.
    pub fn build(path: &str, src: &str) -> FileModel {
        let toks = lex(src);
        let test_spans = find_test_spans(&toks);
        let fns = find_fns(&toks, &test_spans);
        FileModel {
            path: path.to_string(),
            toks,
            fns,
            test_spans,
        }
    }

    /// Is token index `i` inside a test module?
    pub fn in_tests(&self, i: usize) -> bool {
        self.test_spans.iter().any(|&(lo, hi)| i >= lo && i <= hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_bodies_are_spanned_and_named() {
        let src = r#"
            fn alpha(x: u32) -> u32 { x + 1 }
            pub fn beta<T: Clone>(v: Vec<T>) where T: Send { let _ = v; }
            fn declared_only();
            impl Foo {
                fn gamma(&self) { if true { nested(); } }
            }
        "#;
        let m = FileModel::build("x.rs", src);
        let names: Vec<&str> = m.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["alpha", "beta", "gamma"]);
        for f in &m.fns {
            assert!(m.toks[f.body_start].is_punct("{"));
            assert!(m.toks[f.body_end].is_punct("}"));
            assert!(f.body_end > f.body_start);
        }
    }

    #[test]
    fn braces_in_fn_signature_defaults_do_not_confuse_body_detection() {
        // Array types in the arg list put `[` `]` in play; the const
        // generic braces live inside brackets, so the body is found.
        let src = "fn f(xs: [u8; 4]) -> [u8; 4] { xs }";
        let m = FileModel::build("x.rs", src);
        assert_eq!(m.fns.len(), 1);
    }

    #[test]
    fn cfg_test_modules_are_flagged() {
        let src = r#"
            fn prod() { work(); }
            #[cfg(test)]
            mod tests {
                fn helper() { prod(); }
                #[test]
                fn case() { helper(); }
            }
        "#;
        let m = FileModel::build("x.rs", src);
        let by_name = |n: &str| m.fns.iter().find(|f| f.name == n).expect("fn present");
        assert!(!by_name("prod").in_tests);
        assert!(by_name("helper").in_tests);
        assert!(by_name("case").in_tests);
    }

    #[test]
    fn non_test_module_is_not_a_test_span() {
        let src = "mod inner { fn f() {} }";
        let m = FileModel::build("x.rs", src);
        assert!(m.test_spans.is_empty());
        assert!(!m.fns[0].in_tests);
    }
}
