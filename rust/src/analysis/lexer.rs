//! Minimal hand-rolled Rust lexer for `pallas-lint`.
//!
//! Same zero-dependency style as `crate::json`: a single forward pass
//! over the raw bytes that strips line comments, nested block comments,
//! string/raw-string/byte-string literals, and char literals, and emits
//! a flat token stream with source lines. It is *not* a full Rust lexer
//! — it only has to be sound for the patterns the lint rules match
//! (identifiers, `::` paths, punctuation, brace/paren structure), and it
//! must never mistake comment or string contents for code, which is
//! where naive grep-based invariant checking falls over.

/// Kind of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (including `true`/`false`).
    Ident,
    /// Numeric literal (integer part only; `1.5` lexes as `1`, `.`, `5`).
    Num,
    /// String literal of any flavor; `text` holds the raw contents.
    Str,
    /// Character or byte literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Life,
    /// Punctuation; one character, except `::` which lexes as one token.
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Token class.
    pub kind: TokKind,
    /// Identifier text, punctuation characters, or string contents.
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

impl Tok {
    /// Shorthand: is this an identifier with exactly this text?
    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == TokKind::Ident && self.text == text
    }

    /// Shorthand: is this punctuation with exactly this text?
    pub fn is_punct(&self, text: &str) -> bool {
        self.kind == TokKind::Punct && self.text == text
    }
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Scan an escape-aware `"..."` body starting just past the opening
/// quote; returns (contents, index past the closing quote, newlines).
fn scan_string(b: &[u8], mut i: usize) -> (String, usize, u32) {
    let start = i;
    let mut nl = 0u32;
    while i < b.len() {
        match b[i] {
            b'\\' => i = (i + 2).min(b.len()),
            b'"' => {
                let text = String::from_utf8_lossy(&b[start..i]).into_owned();
                return (text, i + 1, nl);
            }
            b'\n' => {
                nl += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (String::from_utf8_lossy(&b[start..]).into_owned(), b.len(), nl)
}

/// Scan a raw string body starting just past the opening quote, with
/// `hashes` trailing `#`s required to close.
fn scan_raw_string(b: &[u8], mut i: usize, hashes: usize) -> (String, usize, u32) {
    let start = i;
    let mut nl = 0u32;
    while i < b.len() {
        if b[i] == b'\n' {
            nl += 1;
        } else if b[i] == b'"' {
            let end_hashes = b[i + 1..].iter().take_while(|&&c| c == b'#').count();
            if end_hashes >= hashes {
                let text = String::from_utf8_lossy(&b[start..i]).into_owned();
                return (text, i + 1 + hashes, nl);
            }
        }
        i += 1;
    }
    (String::from_utf8_lossy(&b[start..]).into_owned(), b.len(), nl)
}

/// Scan a char/byte literal body starting just past the opening `'`;
/// returns the index past the closing quote.
fn scan_char(b: &[u8], mut i: usize) -> usize {
    while i < b.len() {
        match b[i] {
            b'\\' => i = (i + 2).min(b.len()),
            b'\'' => return i + 1,
            _ => i += 1,
        }
    }
    b.len()
}

/// Lex `src` into a token stream. Unknown bytes (stray non-ASCII outside
/// literals) are skipped rather than reported — the lint rules only need
/// the surviving structure.
pub fn lex(src: &str) -> Vec<Tok> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let push = |toks: &mut Vec<Tok>, kind: TokKind, text: String, line: u32| {
        toks.push(Tok { kind, text, line });
    };
    while i < n {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                while i < n && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let mut depth = 1u32;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                let at = line;
                let (text, j, nl) = scan_string(b, i + 1);
                push(&mut toks, TokKind::Str, text, at);
                line += nl;
                i = j;
            }
            b'\'' => {
                // Char literal vs lifetime: an escape or a closing quote
                // within reach means char; otherwise it is a lifetime.
                let at = line;
                if i + 1 < n && b[i + 1] == b'\\' {
                    push(&mut toks, TokKind::Char, String::new(), at);
                    i = scan_char(b, i + 1);
                } else if i + 2 < n && b[i + 1] != b'\'' && b[i + 1] < 0x80 && b[i + 2] == b'\'' {
                    push(&mut toks, TokKind::Char, String::new(), at);
                    i += 3;
                } else if i + 1 < n && b[i + 1] >= 0x80 {
                    // Multi-byte char literal ('→'): find the close quote
                    // within the next few bytes.
                    push(&mut toks, TokKind::Char, String::new(), at);
                    i = scan_char(b, i + 1);
                } else {
                    let start = i + 1;
                    let mut j = start;
                    while j < n && is_ident_cont(b[j]) {
                        j += 1;
                    }
                    let text = String::from_utf8_lossy(&b[start..j]).into_owned();
                    push(&mut toks, TokKind::Life, text, at);
                    i = j;
                }
            }
            b'r' if i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'#') => {
                let hashes = b[i + 1..].iter().take_while(|&&c| c == b'#').count();
                let q = i + 1 + hashes;
                if q < n && b[q] == b'"' {
                    let at = line;
                    let (text, j, nl) = scan_raw_string(b, q + 1, hashes);
                    push(&mut toks, TokKind::Str, text, at);
                    line += nl;
                    i = j;
                } else {
                    // Raw identifier (`r#type`): lex the name itself.
                    let start = i + 2;
                    let mut j = start;
                    while j < n && is_ident_cont(b[j]) {
                        j += 1;
                    }
                    let text = String::from_utf8_lossy(&b[start..j]).into_owned();
                    push(&mut toks, TokKind::Ident, text, line);
                    i = j.max(i + 1);
                }
            }
            b'b' if i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'\'' || b[i + 1] == b'r') => {
                let at = line;
                if b[i + 1] == b'"' {
                    let (text, j, nl) = scan_string(b, i + 2);
                    push(&mut toks, TokKind::Str, text, at);
                    line += nl;
                    i = j;
                } else if b[i + 1] == b'\'' {
                    push(&mut toks, TokKind::Char, String::new(), at);
                    i = scan_char(b, i + 2);
                } else {
                    // `br"` / `br#...#"` raw byte string — or an ident
                    // that merely starts with `br`.
                    let hashes = b[i + 2..].iter().take_while(|&&c| c == b'#').count();
                    let q = i + 2 + hashes;
                    if q < n && b[q] == b'"' {
                        let (text, j, nl) = scan_raw_string(b, q + 1, hashes);
                        push(&mut toks, TokKind::Str, text, at);
                        line += nl;
                        i = j;
                    } else {
                        let start = i;
                        let mut j = start;
                        while j < n && is_ident_cont(b[j]) {
                            j += 1;
                        }
                        let text = String::from_utf8_lossy(&b[start..j]).into_owned();
                        push(&mut toks, TokKind::Ident, text, line);
                        i = j;
                    }
                }
            }
            c if is_ident_start(c) => {
                let start = i;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
                let text = String::from_utf8_lossy(&b[start..i]).into_owned();
                push(&mut toks, TokKind::Ident, text, line);
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
                let text = String::from_utf8_lossy(&b[start..i]).into_owned();
                push(&mut toks, TokKind::Num, text, line);
            }
            b':' if i + 1 < n && b[i + 1] == b':' => {
                push(&mut toks, TokKind::Punct, "::".to_string(), line);
                i += 2;
            }
            c if c < 0x80 => {
                push(&mut toks, TokKind::Punct, (c as char).to_string(), line);
                i += 1;
            }
            _ => i += 1,
        }
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_and_strings_never_leak_tokens() {
        let src = r##"
            // line comment with fn lock() "quote
            /* block /* nested */ still comment fn */
            let s = "string with // and /* and } braces {";
            let r = r#"raw "quoted" with .lock() inside"#;
            let b = b"byte string with 'x'";
            call();
        "##;
        let toks = lex(src);
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, vec!["let", "s", "let", "r", "let", "b", "call"]);
        // String contents are preserved as Str tokens, not re-lexed.
        let strs: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs.len(), 3);
        assert!(strs[1].contains(".lock()"));
    }

    #[test]
    fn braces_inside_literals_do_not_unbalance() {
        let src = r##"fn f() { let s = "}}}{"; let c = '{'; let r = r#"}"#; }"##;
        let toks = lex(src);
        let opens = toks.iter().filter(|t| t.is_punct("{")).count();
        let closes = toks.iter().filter(|t| t.is_punct("}")).count();
        assert_eq!(opens, 1);
        assert_eq!(closes, 1);
    }

    #[test]
    fn lifetimes_and_chars_are_distinguished() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let e = '\\n'; }");
        let lifes: Vec<_> = toks.iter().filter(|(k, _)| *k == TokKind::Life).collect();
        assert_eq!(lifes.len(), 2, "{toks:?}");
        let chars = toks.iter().filter(|(k, _)| *k == TokKind::Char).count();
        assert_eq!(chars, 2);
    }

    #[test]
    fn generics_lex_as_plain_angle_puncts() {
        let toks = lex("let x: Vec<Arc<Mutex<T>>> = Vec::new();");
        assert!(toks.iter().any(|t| t.is_punct("<")));
        assert!(toks.iter().any(|t| t.is_punct("::")));
        // `>>` is two separate closes, not a shift token.
        assert_eq!(toks.iter().filter(|t| t.is_punct(">")).count(), 3);
    }

    #[test]
    fn tuple_field_access_keeps_dot_structure() {
        let toks = lex("pair.0.lock()");
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["pair", ".", "0", ".", "lock", "(", ")"]);
    }

    #[test]
    fn line_numbers_survive_multiline_literals() {
        let src = "let a = \"one\ntwo\nthree\";\nlet b = 1;";
        let toks = lex(src);
        let b_tok = toks.iter().find(|t| t.is_ident("b")).expect("b token");
        assert_eq!(b_tok.line, 4);
    }

    #[test]
    fn random_ascii_never_panics_or_hangs() {
        // Property sweep with the deterministic testkit generator: the
        // lexer must terminate and stay panic-free on arbitrary input.
        let mut rng = crate::testkit::Rng::new(0xA11CE);
        for _ in 0..200 {
            let len = (rng.next_u64() % 120) as usize;
            let mut src = String::new();
            for _ in 0..len {
                src.push((rng.next_u64() % 96 + 32) as u8 as char);
            }
            let _ = lex(&src);
        }
    }
}
