//! `metric-name` — metric registry naming lint.
//!
//! Every string literal passed to `Registry::incr` / `Registry::observe`
//! becomes a line on the `/metrics` scrape surface, gets matched by
//! exact name in the fleet aggregator's parser, and ends up in dashboards
//! and CSV headers. A typo there fails silently: the counter registers
//! under the wrong name and every consumer reads 0 forever. Two checks
//! keep that from shipping:
//!
//! - each metric literal must be snake_case (`[a-z0-9_]`, no leading /
//!   trailing / doubled underscore) and start with a known subsystem
//!   prefix ([`PREFIXES`]), so the scrape stays greppable by subsystem;
//! - two distinct metric names in one file at edit distance 1 are
//!   flagged as a likely typo-duplicate (`rx`/`tx` counterparts are the
//!   deliberate exception).
//!
//! Test code is exempt — unit tests name throwaway metrics freely.

use super::lexer::TokKind;
use super::model::FileModel;
use super::Finding;

/// Subsystem prefixes a metric name may start with.
pub const PREFIXES: &[&str] = &[
    "cm_", "kv_", "net_", "cluster_", "obs_", "pallas_", "fleet_", "llm_",
];

/// Run the metric-name lint over one file.
pub fn check_file(model: &FileModel) -> Vec<Finding> {
    let mut findings = Vec::new();
    let toks = &model.toks;
    // (name, line) of every metric literal, in file order, for the
    // near-miss pass. Deduplicated: repeated use of one name is normal.
    let mut seen: Vec<(String, u32)> = Vec::new();
    for i in 0..toks.len().saturating_sub(3) {
        if model.in_tests(i) || !toks[i].is_punct(".") {
            continue;
        }
        let m = &toks[i + 1];
        if !(m.is_ident("incr") || m.is_ident("observe")) || !toks[i + 2].is_punct("(") {
            continue;
        }
        let lit = &toks[i + 3];
        if lit.kind != TokKind::Str {
            continue;
        }
        let name = lit.text.clone();
        if !well_formed(&name) {
            findings.push(Finding {
                rule: "metric-name",
                file: model.path.clone(),
                line: lit.line,
                message: format!(
                    "metric name \"{name}\" is not snake_case with a known subsystem \
                     prefix ({})",
                    PREFIXES.join(" ")
                ),
            });
        }
        if !seen.iter().any(|(n, _)| *n == name) {
            seen.push((name, lit.line));
        }
    }
    for (i, (a, _)) in seen.iter().enumerate() {
        for (b, line_b) in seen.iter().skip(i + 1) {
            if edit_distance_one(a, b) && !rx_tx_pair(a, b) {
                findings.push(Finding {
                    rule: "metric-name",
                    file: model.path.clone(),
                    line: *line_b,
                    message: format!(
                        "metric names \"{a}\" and \"{b}\" differ by one character — \
                         likely a typo-duplicate registering under two names"
                    ),
                });
            }
        }
    }
    findings
}

/// snake_case with a known subsystem prefix.
fn well_formed(name: &str) -> bool {
    PREFIXES.iter().any(|p| name.starts_with(p))
        && !name.ends_with('_')
        && !name.contains("__")
        && name
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Exactly one substitution, insertion, or deletion apart.
fn edit_distance_one(a: &str, b: &str) -> bool {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    if a == b {
        return false;
    }
    if a.len() == b.len() {
        return a.iter().zip(b).filter(|(x, y)| x != y).count() == 1;
    }
    let (short, long) = if a.len() < b.len() { (a, b) } else { (b, a) };
    if long.len() - short.len() != 1 {
        return false;
    }
    let mut i = 0;
    while i < short.len() && short[i] == long[i] {
        i += 1;
    }
    short[i..] == long[i + 1..]
}

/// `rx`/`tx` counterparts are the one legitimate distance-1 pair
/// (`kv_sync_rx_bytes` / `kv_sync_tx_bytes` and friends).
fn rx_tx_pair(a: &str, b: &str) -> bool {
    a.replace("rx", "tx") == b || a.replace("tx", "rx") == b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(src: &str) -> Vec<Finding> {
        let model = FileModel::build("src/some/module.rs", src);
        check_file(&model)
    }

    #[test]
    fn well_prefixed_snake_case_is_clean() {
        let src = r#"
            fn record(r: &Registry) {
                r.incr("kv_hints_queued", 1);
                r.observe("cm_request_s", 0.5);
                r.incr("fleet_polls_total", 1);
            }
        "#;
        assert!(check(src).is_empty(), "{:?}", check(src));
    }

    #[test]
    fn bad_case_and_unknown_prefix_are_flagged() {
        let src = r#"
            fn record(r: &Registry) {
                r.incr("ctxManager_Requests", 1);
                r.observe("kv_trailing_", 0.5);
                r.incr("kv__double", 1);
                r.incr("sessions_total", 1);
            }
        "#;
        let f = check(src);
        assert_eq!(f.len(), 4, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "metric-name"));
        assert!(f[0].message.contains("ctxManager_Requests"));
    }

    #[test]
    fn near_miss_pair_is_flagged_once() {
        let src = r#"
            fn record(r: &Registry) {
                r.observe("kv_fetch_s", 0.1);
                r.observe("kv_fetch_z", 0.2);
                r.observe("kv_fetch_z", 0.3);
            }
        "#;
        let f = check(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("differ by one character"));
    }

    #[test]
    fn rx_tx_counterparts_are_exempt() {
        let src = r#"
            fn record(r: &Registry) {
                r.incr("kv_sync_rx_bytes", 1);
                r.incr("kv_sync_tx_bytes", 1);
            }
        "#;
        assert!(check(src).is_empty(), "{:?}", check(src));
    }

    #[test]
    fn test_code_names_metrics_freely() {
        let src = r#"
            #[cfg(test)]
            mod tests {
                #[test]
                fn t() {
                    r.incr("whatever_Name", 1);
                }
            }
        "#;
        assert!(check(src).is_empty(), "{:?}", check(src));
    }

    #[test]
    fn edit_distance_one_cases() {
        assert!(edit_distance_one("kv_a_total", "kv_b_total"));
        assert!(edit_distance_one("kv_total", "kv_totals"));
        assert!(edit_distance_one("kv_totals", "kv_total"));
        assert!(!edit_distance_one("kv_total", "kv_total"));
        assert!(!edit_distance_one("kv_total", "cm_total_s"));
        assert!(!edit_distance_one("kv_requests", "kv_retries"));
    }
}
