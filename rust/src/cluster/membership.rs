//! Heartbeat-based failure detection and the shared membership view.
//!
//! Each node runs a [`FailureDetector`] thread that pings its **ring
//! successors** (via [`crate::kvstore::HashRing::successors`]) on a
//! configurable interval over the crate's own HTTP client. Probe outcomes
//! feed a cluster-wide [`MembershipView`] holding one
//! [`NodeState`] per member:
//!
//! ```text
//!            k consecutive misses              down_after since last ok
//!   Alive ─────────────────────────▶ Suspect ─────────────────────────▶ Down
//!     ▲                                │                                 │
//!     │      successful probe          │       successful probe /        │
//!     └────────────────────────────────┴────────── rejoin ───────────────┘
//! ```
//!
//! `Suspect` is a grace state: the node stays in placement (a transient
//! hiccup must not reshuffle sessions). Only `Alive ⇄ Down` transitions
//! and joins bump the monotonically increasing **epoch** — the version
//! number of the cluster topology, stamped into every rebuilt
//! [`crate::kvstore::Placement`]. Down members keep being probed so a
//! recovered node (same address) is re-admitted by its next successful
//! probe; a *restarted* node (new address) re-admits itself through
//! [`MembershipView::join`].
//!
//! The view's subscribers (see [`super::ClusterCoordinator`]) receive the
//! resulting [`MembershipEvent`]s strictly *after* the view's lock is
//! released, so they are free to read the view and touch KV nodes.
//!
//! Heartbeat traffic uses dedicated ping listeners and meters: with zero
//! failures a membership-enabled fleet produces byte-for-byte the same
//! *replication* wire traffic as one without membership.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::http::{Request, Response, Server};
use crate::json::Value;
use crate::kvstore::HashRing;
use crate::netsim::{LinkModel, TrafficMeter};
use crate::sync::{classes, OrderedMutex};
use crate::transport::PeerPool;
use crate::Result;

/// How many ring successors each node probes per heartbeat tick. Two
/// probers per target tolerate one failed observer without losing
/// coverage; every node has at least one ring predecessor, so every node
/// is probed by someone.
pub const PROBE_FANOUT: usize = 2;

/// Failure-detector liveness state of one member.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Responding to probes; full member of the placement.
    Alive,
    /// Missed `suspect_after` consecutive probes; still placed (grace).
    Suspect,
    /// Unresponsive past `down_after`; removed from placement, writes to
    /// it are parked as hints.
    Down,
}

impl NodeState {
    /// Wire/metrics string.
    pub fn as_str(&self) -> &'static str {
        match self {
            NodeState::Alive => "alive",
            NodeState::Suspect => "suspect",
            NodeState::Down => "down",
        }
    }
}

/// Failure-detector tuning (`membership` config section).
#[derive(Debug, Clone)]
pub struct MembershipConfig {
    /// Master switch. Default **off**: the cluster behaves exactly like
    /// the static seed — placement frozen at launch, no heartbeats.
    pub enabled: bool,
    /// Interval between probe rounds (`heartbeat_ms`).
    pub heartbeat: Duration,
    /// Consecutive missed probes before a member turns `Suspect`.
    pub suspect_after: u32,
    /// Time since the last successful probe before a `Suspect` member is
    /// declared `Down` (`down_after_ms`).
    pub down_after: Duration,
}

impl Default for MembershipConfig {
    fn default() -> MembershipConfig {
        MembershipConfig {
            enabled: false,
            heartbeat: Duration::from_millis(100),
            suspect_after: 3,
            down_after: Duration::from_millis(1000),
        }
    }
}

/// One member as seen by the failure detector.
#[derive(Debug, Clone)]
pub struct MemberInfo {
    /// Node name (placement identity).
    pub name: String,
    /// Current liveness state.
    pub state: NodeState,
    /// Ping listener address (probed by the detectors).
    pub ping_addr: SocketAddr,
    /// KV replication listener address (what placement routes writes to).
    pub kv_addr: SocketAddr,
    /// Models (keygroups) served by the member.
    pub models: Vec<String>,
    /// Consecutive missed probes.
    missed: u32,
    /// Instant of the last successful probe (join time initially).
    last_ok: Instant,
}

/// A state transition worth reacting to. Emitted by [`MembershipView`] to
/// its subscribers after the triggering report/join.
#[derive(Debug, Clone)]
pub enum MembershipEvent {
    /// A brand-new member was admitted (epoch bumped).
    Joined {
        /// Member name.
        name: String,
    },
    /// A member stopped answering probes but is still within its grace
    /// window (no epoch change).
    Suspected {
        /// Member name.
        name: String,
    },
    /// A member was declared down (epoch bumped): remove from placement,
    /// park its writes as hints.
    Down {
        /// Member name.
        name: String,
        /// Its KV replication address (the hint-queue key).
        kv_addr: SocketAddr,
    },
    /// A down member came back (epoch bumped) — either probed alive at
    /// its old address or rejoined at a new one. Hints parked for
    /// `old_kv_addr` replay to `kv_addr`.
    Up {
        /// Member name.
        name: String,
        /// KV address while it was down (where hints were parked).
        old_kv_addr: SocketAddr,
        /// KV address now (equal to `old_kv_addr` unless restarted).
        kv_addr: SocketAddr,
    },
}

/// Membership-event callback. `Arc` (not `Box`) so `notify` can snapshot
/// the list and invoke callbacks with the subscriber lock released.
type Subscriber = Arc<dyn Fn(&[MembershipEvent]) + Send + Sync>;

/// Cluster-wide membership: per-member state, the topology epoch, and the
/// subscriber list notified on every transition.
pub struct MembershipView {
    cfg: MembershipConfig,
    members: OrderedMutex<Vec<MemberInfo>>,
    epoch: AtomicU64,
    subscribers: OrderedMutex<Vec<Subscriber>>,
}

impl MembershipView {
    /// Empty view at epoch 0; every join bumps the epoch.
    pub fn new(cfg: MembershipConfig) -> Arc<MembershipView> {
        Arc::new(MembershipView {
            cfg,
            members: OrderedMutex::new(&classes::MEMBERSHIP_MEMBERS, Vec::new()),
            epoch: AtomicU64::new(0),
            subscribers: OrderedMutex::new(&classes::MEMBERSHIP_SUBSCRIBERS, Vec::new()),
        })
    }

    /// The detector configuration this view was built with.
    pub fn config(&self) -> &MembershipConfig {
        &self.cfg
    }

    /// Current topology epoch (bumps on join and `Alive ⇄ Down`).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Snapshot of all members (any state), in join order.
    pub fn members(&self) -> Vec<MemberInfo> {
        self.members.lock().unwrap().clone()
    }

    /// Members currently counted as live (`Alive` or `Suspect`).
    pub fn alive_count(&self) -> usize {
        self.members
            .lock()
            .unwrap()
            .iter()
            .filter(|m| m.state != NodeState::Down)
            .count()
    }

    /// State of a member by name.
    pub fn state_of(&self, name: &str) -> Option<NodeState> {
        self.members
            .lock()
            .unwrap()
            .iter()
            .find(|m| m.name == name)
            .map(|m| m.state)
    }

    /// Register a callback for future membership events.
    pub fn subscribe(&self, f: Subscriber) {
        self.subscribers.lock().unwrap().push(f);
    }

    /// Admit a member (new node or a restarted one rejoining under its
    /// old name with fresh addresses). Returns the epoch after the join.
    pub fn join(
        &self,
        name: &str,
        ping_addr: SocketAddr,
        kv_addr: SocketAddr,
        models: &[String],
    ) -> u64 {
        let mut events = Vec::new();
        let epoch;
        {
            let mut members = self.members.lock().unwrap();
            match members.iter_mut().find(|m| m.name == name) {
                Some(m) => {
                    let old_kv = m.kv_addr;
                    let was_down = m.state == NodeState::Down;
                    m.ping_addr = ping_addr;
                    m.kv_addr = kv_addr;
                    m.models = models.to_vec();
                    m.missed = 0;
                    m.last_ok = Instant::now();
                    if was_down || old_kv != kv_addr {
                        m.state = NodeState::Alive;
                        self.epoch.fetch_add(1, Ordering::SeqCst);
                        events.push(MembershipEvent::Up {
                            name: name.to_string(),
                            old_kv_addr: old_kv,
                            kv_addr,
                        });
                    }
                }
                None => {
                    members.push(MemberInfo {
                        name: name.to_string(),
                        state: NodeState::Alive,
                        ping_addr,
                        kv_addr,
                        models: models.to_vec(),
                        missed: 0,
                        last_ok: Instant::now(),
                    });
                    self.epoch.fetch_add(1, Ordering::SeqCst);
                    events.push(MembershipEvent::Joined {
                        name: name.to_string(),
                    });
                }
            }
            epoch = self.epoch.load(Ordering::SeqCst);
        }
        self.notify(&events);
        epoch
    }

    /// Record one probe outcome for `name` and advance its state machine.
    pub fn report(&self, name: &str, ok: bool) {
        let mut events = Vec::new();
        {
            let mut members = self.members.lock().unwrap();
            let Some(m) = members.iter_mut().find(|m| m.name == name) else {
                return;
            };
            if ok {
                m.missed = 0;
                m.last_ok = Instant::now();
                match m.state {
                    NodeState::Down => {
                        // Recovered in place: same address, so hints for
                        // it replay to where they were parked.
                        m.state = NodeState::Alive;
                        self.epoch.fetch_add(1, Ordering::SeqCst);
                        events.push(MembershipEvent::Up {
                            name: name.to_string(),
                            old_kv_addr: m.kv_addr,
                            kv_addr: m.kv_addr,
                        });
                    }
                    NodeState::Suspect => m.state = NodeState::Alive,
                    NodeState::Alive => {}
                }
            } else {
                m.missed = m.missed.saturating_add(1);
                match m.state {
                    NodeState::Alive if m.missed >= self.cfg.suspect_after => {
                        m.state = NodeState::Suspect;
                        events.push(MembershipEvent::Suspected {
                            name: name.to_string(),
                        });
                    }
                    NodeState::Suspect if m.last_ok.elapsed() >= self.cfg.down_after => {
                        m.state = NodeState::Down;
                        self.epoch.fetch_add(1, Ordering::SeqCst);
                        events.push(MembershipEvent::Down {
                            name: name.to_string(),
                            kv_addr: m.kv_addr,
                        });
                    }
                    _ => {}
                }
            }
        }
        self.notify(&events);
    }

    /// The members `prober` should ping this round: its `fanout` ring
    /// successors. Down members stay in the ring so a recovery at the old
    /// address is noticed (rejoin-on-probe).
    pub fn probe_targets(&self, prober: &str, fanout: usize) -> Vec<(String, SocketAddr)> {
        let members = self.members.lock().unwrap();
        let names: Vec<&str> = members.iter().map(|m| m.name.as_str()).collect();
        // successors() orders members by their primary ring position
        // only, so one virtual point per member is all this needs —
        // this runs every heartbeat tick under the members lock.
        let ring = HashRing::new(&names, 1);
        ring.successors(prober, fanout)
            .into_iter()
            .filter_map(|succ| {
                members
                    .iter()
                    .find(|m| m.name == succ)
                    .map(|m| (m.name.clone(), m.ping_addr))
            })
            .collect()
    }

    /// Test/benchmark helper: block until `name` reaches `state`.
    pub fn wait_for_state(&self, name: &str, state: NodeState, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.state_of(name) == Some(state) {
                return true;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        false
    }

    /// Test/benchmark helper: block until the epoch reaches `at_least`.
    pub fn wait_for_epoch(&self, at_least: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.epoch() >= at_least {
                return true;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        false
    }

    fn notify(&self, events: &[MembershipEvent]) {
        if events.is_empty() {
            return;
        }
        // Subscribers run outside *both* view locks: they may read the
        // view, swap placements on KV nodes, and (re)subscribe — a
        // callback invoked under the subscriber lock would deadlock on
        // any of those. Snapshot the Arc list, release, then invoke.
        let subs: Vec<Subscriber> = self.subscribers.lock().unwrap().clone();
        for sub in &subs {
            sub(events);
        }
    }
}

impl std::fmt::Debug for MembershipView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MembershipView")
            .field("epoch", &self.epoch())
            .field("members", &self.members())
            .finish()
    }
}

/// Start the per-node ping listener the detectors probe. Dedicated
/// server + meter: heartbeat bytes never pollute replication accounting.
pub fn serve_ping(name: &str) -> Result<Server> {
    let name = name.to_string();
    Server::serve(
        0,
        LinkModel::ideal(),
        Arc::new(move |req: &Request| {
            if req.method == "GET" && req.path == "/ping" {
                Response::json(&Value::obj().set("node", name.as_str()).to_json())
            } else {
                Response::error(404, "not found")
            }
        }),
    )
}

/// One node's probing loop, feeding the shared [`MembershipView`].
pub struct FailureDetector {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl FailureDetector {
    /// Spawn the probe thread for `node`. Interval and thresholds come
    /// from the view's [`MembershipConfig`].
    pub fn start(node: String, view: Arc<MembershipView>) -> FailureDetector {
        let stop = Arc::new(AtomicBool::new(false));
        let t_stop = stop.clone();
        let cfg = view.config().clone();
        let meter = TrafficMeter::new();
        let thread = std::thread::Builder::new()
            .name(format!("membership-{node}"))
            .spawn(move || {
                // Every probe step is hard-bounded by the timeout so a
                // hung peer cannot stall the round (floor keeps very
                // fast test heartbeats from spuriously timing out the
                // handshake). Probes to live peers ride one keep-alive
                // pooled connection per target instead of a connect per
                // tick; the pool's stale-retry is disabled so a wedged
                // peer's dead socket costs one timeout, not a
                // reconnect-and-retry multiple of it — the spuriously
                // missed probe after a peer restart is absorbed by
                // `suspect_after`, and the next tick connects fresh.
                // Heartbeats slower than the pool's 30 s idle expiry
                // degrade gracefully to connect-per-ping: the expired
                // socket is pruned before reuse, never probed stale
                // (the ping listener reaps its half at 60 s).
                let timeout = cfg.heartbeat.max(Duration::from_millis(20));
                let pool = PeerPool::new(meter, LinkModel::ideal())
                    .with_io_timeout(timeout)
                    .without_stale_retry();
                while !t_stop.load(Ordering::SeqCst) {
                    std::thread::sleep(cfg.heartbeat);
                    if t_stop.load(Ordering::SeqCst) {
                        break;
                    }
                    for (target, ping_addr) in view.probe_targets(&node, PROBE_FANOUT) {
                        let ok = probe(&pool, ping_addr);
                        view.report(&target, ok);
                    }
                }
            })
            .expect("spawn failure detector");
        FailureDetector {
            stop,
            thread: Some(thread),
        }
    }

    /// Stop probing and join the thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for FailureDetector {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One `GET /ping` round-trip over the detector's pool, under its hard
/// connect/IO timeout. A live target's connection is kept alive between
/// ticks; a dead target costs one bounded connect attempt.
fn probe(pool: &PeerPool, addr: SocketAddr) -> bool {
    matches!(
        pool.round_trip(addr, &Request::get("/ping")),
        Ok(resp) if resp.status == 200
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn fast_cfg() -> MembershipConfig {
        MembershipConfig {
            enabled: true,
            heartbeat: Duration::from_millis(10),
            suspect_after: 2,
            down_after: Duration::from_millis(50),
        }
    }

    #[test]
    fn joins_bump_the_epoch_and_emit_events() {
        let view = MembershipView::new(fast_cfg());
        let seen = Arc::new(Mutex::new(Vec::<String>::new()));
        let s2 = seen.clone();
        view.subscribe(Arc::new(move |events| {
            for e in events {
                s2.lock().unwrap().push(format!("{e:?}"));
            }
        }));
        assert_eq!(view.epoch(), 0);
        assert_eq!(view.join("a", addr(1), addr(2), &["m".into()]), 1);
        assert_eq!(view.join("b", addr(3), addr(4), &["m".into()]), 2);
        assert_eq!(view.alive_count(), 2);
        // Re-announcing unchanged addresses is idempotent.
        assert_eq!(view.join("a", addr(1), addr(2), &["m".into()]), 2);
        let log = seen.lock().unwrap();
        assert_eq!(log.len(), 2, "{log:?}");
        assert!(log[0].contains("Joined"));
    }

    #[test]
    fn state_machine_alive_suspect_down_and_back() {
        let view = MembershipView::new(fast_cfg());
        view.join("a", addr(1), addr(2), &[]);
        view.join("b", addr(3), addr(4), &[]);
        let e0 = view.epoch();
        // One miss: still alive (suspect_after = 2).
        view.report("b", false);
        assert_eq!(view.state_of("b"), Some(NodeState::Alive));
        view.report("b", false);
        assert_eq!(view.state_of("b"), Some(NodeState::Suspect));
        assert_eq!(view.epoch(), e0, "suspect must not bump the epoch");
        // Down only after down_after has elapsed since the last success.
        view.report("b", false);
        std::thread::sleep(Duration::from_millis(60));
        view.report("b", false);
        assert_eq!(view.state_of("b"), Some(NodeState::Down));
        assert_eq!(view.epoch(), e0 + 1);
        assert_eq!(view.alive_count(), 1);
        // A successful probe re-admits at the same address.
        view.report("b", true);
        assert_eq!(view.state_of("b"), Some(NodeState::Alive));
        assert_eq!(view.epoch(), e0 + 2);
    }

    #[test]
    fn suspect_recovers_without_epoch_change() {
        let view = MembershipView::new(fast_cfg());
        view.join("a", addr(1), addr(2), &[]);
        let e0 = view.epoch();
        view.report("a", false);
        view.report("a", false);
        assert_eq!(view.state_of("a"), Some(NodeState::Suspect));
        view.report("a", true);
        assert_eq!(view.state_of("a"), Some(NodeState::Alive));
        assert_eq!(view.epoch(), e0);
    }

    #[test]
    fn rejoin_with_new_address_reports_old_hint_queue_key() {
        let view = MembershipView::new(fast_cfg());
        view.join("a", addr(1), addr(2), &[]);
        view.join("b", addr(3), addr(4), &[]);
        let events = Arc::new(Mutex::new(Vec::<MembershipEvent>::new()));
        let e2 = events.clone();
        view.subscribe(Arc::new(move |evs| {
            e2.lock().unwrap().extend(evs.iter().cloned());
        }));
        // Take b down, then rejoin at a fresh address.
        view.report("b", false);
        view.report("b", false);
        std::thread::sleep(Duration::from_millis(60));
        view.report("b", false);
        assert_eq!(view.state_of("b"), Some(NodeState::Down));
        view.join("b", addr(13), addr(14), &[]);
        let log = events.lock().unwrap();
        let up = log
            .iter()
            .find_map(|e| match e {
                MembershipEvent::Up {
                    old_kv_addr,
                    kv_addr,
                    ..
                } => Some((*old_kv_addr, *kv_addr)),
                _ => None,
            })
            .expect("rejoin must emit Up");
        assert_eq!(up, (addr(4), addr(14)));
        assert_eq!(view.state_of("b"), Some(NodeState::Alive));
    }

    #[test]
    fn probe_targets_are_ring_successors_excluding_self() {
        let view = MembershipView::new(fast_cfg());
        for (i, n) in ["a", "b", "c", "d"].into_iter().enumerate() {
            view.join(n, addr(10 + i as u16), addr(20 + i as u16), &[]);
        }
        let targets = view.probe_targets("a", PROBE_FANOUT);
        assert_eq!(targets.len(), PROBE_FANOUT);
        assert!(targets.iter().all(|(n, _)| n != "a"));
        // Two-node cluster: each probes the other.
        let small = MembershipView::new(fast_cfg());
        small.join("x", addr(1), addr(2), &[]);
        small.join("y", addr(3), addr(4), &[]);
        assert_eq!(small.probe_targets("x", PROBE_FANOUT).len(), 1);
        assert_eq!(small.probe_targets("x", PROBE_FANOUT)[0].0, "y");
        // Single node: nothing to probe.
        assert!(small.probe_targets("z", PROBE_FANOUT).is_empty());
    }

    #[test]
    fn detector_discovers_death_and_recovery_end_to_end() {
        let view = MembershipView::new(fast_cfg());
        let ping_a = serve_ping("a").unwrap();
        let mut ping_b = serve_ping("b").unwrap();
        view.join("a", ping_a.addr, addr(101), &[]);
        view.join("b", ping_b.addr, addr(102), &[]);
        let mut det_a = FailureDetector::start("a".into(), view.clone());
        // a probes b; kill b's ping server and watch the state machine.
        ping_b.shutdown();
        assert!(
            view.wait_for_state("b", NodeState::Down, Duration::from_secs(5)),
            "detector must declare the dead peer down"
        );
        // Restart b's listener at a new address and rejoin.
        let ping_b2 = serve_ping("b").unwrap();
        view.join("b", ping_b2.addr, addr(102), &[]);
        assert!(view.wait_for_state("b", NodeState::Alive, Duration::from_secs(5)));
        det_a.stop();
    }
}
