//! Cluster membership & failure handling (heartbeats, epoch-versioned
//! placement, hinted handoff).
//!
//! Three pieces, layered on the static seed cluster without changing its
//! default behaviour:
//!
//! - [`membership`] — a heartbeat failure detector maintaining a shared
//!   [`MembershipView`] with per-node `Alive/Suspect/Down` state and a
//!   monotonically increasing **epoch**;
//! - [`hints`] — hinted handoff: updates addressed to a down peer are
//!   parked in a bounded per-peer queue and replayed in order when the
//!   peer returns;
//! - [`ClusterCoordinator`] — the glue that reacts to membership events:
//!   on every epoch change it rebuilds the consistent-hash
//!   [`Placement`] from the live member set, stamps it with the epoch,
//!   and swaps it atomically into every [`KvNode`] via
//!   `set_placement`, so reads and writes skip down replicas instead of
//!   timing out on them.
//!
//! The ordering contract on a `Down` event is: mark the peer down first
//! (new pushes park as hints immediately), *then* swap the placement
//! (new writes stop addressing the peer at all). On an `Up` event the
//! inverse: re-address stale peer entries, clear the down mark and
//! replay hints, then swap the placement back in — so no window exists
//! in which a write to the returning peer could be silently dropped.
//!
//! A restarting node with local storage adds a step *before* any of
//! this: `KvNode::start` replays its snapshot+WAL into the store before
//! the node registers with the cluster at all, so by the time the `Up`
//! event fires, hint replay and the anti-entropy kick only have the
//! outage-window tail to deliver — recovery-from-disk first, then hint
//! replay, then anti-entropy (see `kvstore::storage`).
//!
//! Everything here is **off by default** (`membership.enabled = false`);
//! a fleet in which no node ever fails behaves byte-for-byte like the
//! static cluster, heartbeats included (they ride dedicated listeners
//! and meters).

pub mod hints;
pub mod membership;

pub use hints::{Hint, HintConfig, HintUpdate, HintedHandoff};
pub use membership::{
    FailureDetector, MemberInfo, MembershipConfig, MembershipEvent, MembershipView, NodeState,
    PROBE_FANOUT,
};

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};

use crate::config::ShardingConfig;
use crate::http::Server;
use crate::kvstore::{KvNode, Placement};
use crate::Result;

/// Per-node machinery owned by the coordinator: the ping listener the
/// other detectors probe, and this node's own prober.
struct NodeRuntime {
    /// Ping listener; kept alive for the member's lifetime. Probed by
    /// peers, so it must be dropped (closed) when the node is killed.
    _ping: Server,
    /// This node's failure-detector thread.
    _detector: FailureDetector,
}

/// Reacts to membership events for an in-process fleet: epoch-versioned
/// placement rebuilds, down-peer marking, and hint replay.
pub struct ClusterCoordinator {
    view: Arc<MembershipView>,
    sharding: ShardingConfig,
    /// Live KV replicas to apply placement swaps / peer marks to.
    kvs: Mutex<Vec<(String, Arc<KvNode>)>>,
    runtimes: Mutex<HashMap<String, NodeRuntime>>,
}

impl ClusterCoordinator {
    /// Create the coordinator and subscribe it to the view's events.
    pub fn start(view: Arc<MembershipView>, sharding: ShardingConfig) -> Arc<ClusterCoordinator> {
        let coordinator = Arc::new(ClusterCoordinator {
            view: view.clone(),
            sharding,
            kvs: Mutex::new(Vec::new()),
            runtimes: Mutex::new(HashMap::new()),
        });
        // Weak subscription: the view must not keep the coordinator (and
        // through it every KvNode) alive after the cluster is dropped.
        let weak = Arc::downgrade(&coordinator);
        view.subscribe(Arc::new(move |events| {
            if let Some(c) = weak.upgrade() {
                c.apply_events(events);
            }
        }));
        coordinator
    }

    /// The membership view driven by this coordinator's detectors.
    pub fn view(&self) -> &Arc<MembershipView> {
        &self.view
    }

    /// Bring a node under membership management: start its ping listener
    /// and failure detector, then announce it to the view (which swaps an
    /// updated placement into every registered replica, and replays any
    /// hints parked for a rejoining node).
    pub fn register_node(&self, name: &str, kv: Arc<KvNode>, models: &[String]) -> Result<()> {
        let ping = membership::serve_ping(name)?;
        let ping_addr = ping.addr;
        let kv_addr = kv.replication_addr();
        {
            let mut kvs = self.kvs.lock().unwrap();
            kvs.retain(|(n, _)| n != name);
            kvs.push((name.to_string(), kv));
        }
        let detector = FailureDetector::start(name.to_string(), self.view.clone());
        self.runtimes.lock().unwrap().insert(
            name.to_string(),
            NodeRuntime {
                _ping: ping,
                _detector: detector,
            },
        );
        self.view.join(name, ping_addr, kv_addr, models);
        Ok(())
    }

    /// Stop a node's detector and ping listener and forget its replica
    /// (test kill hook). The view is *not* told: the remaining detectors
    /// must discover the death themselves.
    pub fn remove_node(&self, name: &str) {
        self.kvs.lock().unwrap().retain(|(n, _)| n != name);
        // Take the runtime out before dropping it: the drop joins the
        // detector thread and closes the ping listener (so peers' probes
        // start failing), and must not run under the map lock.
        let runtime = self.runtimes.lock().unwrap().remove(name);
        drop(runtime);
    }

    fn apply_events(&self, events: &[MembershipEvent]) {
        let mut rebuild = false;
        for event in events {
            match event {
                MembershipEvent::Down { name, kv_addr } => {
                    // Two detectors probe each member, so a Down event
                    // can arrive here *after* the Up that superseded it
                    // (state commits under the view lock before events
                    // are delivered). Re-check the live view: marking an
                    // alive peer down would park its traffic forever.
                    if self.view.state_of(name) != Some(NodeState::Down) {
                        rebuild = true;
                        continue;
                    }
                    // Order matters: park-on-arrival first, then the
                    // placement swap stops addressing the peer at all.
                    for (_, kv) in self.kvs.lock().unwrap().iter() {
                        kv.mark_peer_down(*kv_addr);
                    }
                    rebuild = true;
                }
                MembershipEvent::Up {
                    name,
                    old_kv_addr,
                    kv_addr,
                } => {
                    // Mirror guard: a stale Up behind a newer Down must
                    // not clear the down mark; the hints stay parked for
                    // the next genuine recovery.
                    if self.view.state_of(name) == Some(NodeState::Down) {
                        rebuild = true;
                        continue;
                    }
                    for (_, kv) in self.kvs.lock().unwrap().iter() {
                        // Replicate-to-all subscriptions may still point
                        // at the pre-restart address.
                        kv.replace_peer(*old_kv_addr, *kv_addr);
                        kv.mark_peer_alive(*old_kv_addr, *kv_addr);
                    }
                    rebuild = true;
                }
                MembershipEvent::Joined { .. } => rebuild = true,
                // Suspect is a grace state: placement untouched.
                MembershipEvent::Suspected { .. } => {}
            }
        }
        if rebuild {
            self.rebuild_placement();
        }
    }

    /// Rebuild the ring placement over the live member set (`Alive` +
    /// `Suspect`), stamp it with the current epoch, and swap it into
    /// every registered replica. No-op without a replication factor
    /// (replicate-to-all fleets route by peer subscriptions instead; the
    /// down-peer marks above already divert their pushes to hints).
    fn rebuild_placement(&self) {
        let Some(rf) = self.sharding.replication_factor else {
            return;
        };
        let members = self.view.members();
        let live: Vec<&MemberInfo> = members
            .iter()
            .filter(|m| m.state != NodeState::Down)
            .collect();
        let mut models: Vec<&String> = live.iter().flat_map(|m| m.models.iter()).collect();
        models.sort_unstable();
        models.dedup();
        let mut placement = Placement::new(rf);
        placement.set_epoch(self.view.epoch());
        for model in models {
            let serving: Vec<(String, SocketAddr)> = live
                .iter()
                .filter(|m| m.models.contains(model))
                .map(|m| (m.name.clone(), m.kv_addr))
                .collect();
            placement.add_keygroup(model, &serving, self.sharding.virtual_nodes);
        }
        // Anti-entropy listener addresses ride the placement so the
        // digest walks re-address on every swap exactly like writes do.
        // Known only for in-process replicas (an HTTP-joined member's AE
        // listener is not announced; repair simply skips it).
        for (name, kv) in self.kvs.lock().unwrap().iter() {
            if let Some(ae) = kv.ae_addr() {
                placement.set_ae_addr(name, ae);
            }
        }
        let placement = Arc::new(placement);
        for (_, kv) in self.kvs.lock().unwrap().iter() {
            kv.set_placement(placement.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::KvConfig;
    use crate::netsim::LinkModel;
    use std::time::Duration;

    fn kv(name: &str) -> Arc<KvNode> {
        let node = KvNode::start(
            name,
            KvConfig {
                peer_link: LinkModel::ideal(),
                hints: Some(HintConfig::default()),
                ..KvConfig::default()
            },
        )
        .unwrap();
        node.create_keygroup("m");
        Arc::new(node)
    }

    fn fast_view() -> Arc<MembershipView> {
        MembershipView::new(MembershipConfig {
            enabled: true,
            heartbeat: Duration::from_millis(10),
            suspect_after: 2,
            down_after: Duration::from_millis(40),
        })
    }

    #[test]
    fn registration_installs_an_epoch_stamped_placement() {
        let view = fast_view();
        let coordinator = ClusterCoordinator::start(
            view.clone(),
            ShardingConfig {
                replication_factor: Some(2),
                virtual_nodes: 32,
            },
        );
        let (a, b, c) = (kv("a"), kv("b"), kv("c"));
        for (name, node) in [("a", &a), ("b", &b), ("c", &c)] {
            coordinator
                .register_node(name, node.clone(), &["m".to_string()])
                .unwrap();
        }
        assert_eq!(view.epoch(), 3);
        let p = a.placement().expect("placement installed");
        assert_eq!(p.epoch(), 3);
        assert_eq!(p.replicas("m", "u/s").len(), 2);
        // Every replica shares the same swapped-in placement.
        assert_eq!(b.placement().unwrap().epoch(), 3);
        assert_eq!(c.placement().unwrap().epoch(), 3);
    }

    #[test]
    fn down_event_removes_the_member_from_placement_and_marks_peers() {
        let view = fast_view();
        let coordinator = ClusterCoordinator::start(
            view.clone(),
            ShardingConfig {
                replication_factor: Some(2),
                virtual_nodes: 32,
            },
        );
        let (a, b) = (kv("a"), kv("b"));
        coordinator.register_node("a", a.clone(), &["m".to_string()]).unwrap();
        coordinator.register_node("b", b.clone(), &["m".to_string()]).unwrap();
        // Drive b down through the view directly (detector-free test).
        view.report("b", false);
        view.report("b", false);
        std::thread::sleep(Duration::from_millis(50));
        view.report("b", false);
        assert_eq!(view.state_of("b"), Some(NodeState::Down));
        let p = a.placement().unwrap();
        let reps = p.replicas("m", "u/s");
        assert_eq!(reps.len(), 1, "down member must leave the ring");
        assert_eq!(reps[0].0, "a");
        // Writes now target only live replicas: the local apply + push
        // path never addresses b, so nothing is parked and nothing drops.
        a.put("m", "u/s", "v".into(), 1).unwrap();
        a.quiesce();
        assert_eq!(a.hints_queued(), 0);
        assert_eq!(a.repl_dropped_total(), 0);
    }

    #[test]
    fn rejoin_swaps_the_member_back_in() {
        let view = fast_view();
        let coordinator = ClusterCoordinator::start(
            view.clone(),
            ShardingConfig {
                replication_factor: Some(1),
                virtual_nodes: 32,
            },
        );
        let (a, b) = (kv("a"), kv("b"));
        coordinator.register_node("a", a.clone(), &["m".to_string()]).unwrap();
        coordinator.register_node("b", b.clone(), &["m".to_string()]).unwrap();
        view.report("b", false);
        view.report("b", false);
        std::thread::sleep(Duration::from_millis(50));
        view.report("b", false);
        let down_epoch = view.epoch();
        assert!(a
            .placement()
            .unwrap()
            .ring("m")
            .is_some_and(|r| r.len() == 1));
        view.report("b", true);
        assert_eq!(view.epoch(), down_epoch + 1);
        let p = a.placement().unwrap();
        assert_eq!(p.epoch(), down_epoch + 1);
        assert!(p.ring("m").is_some_and(|r| r.len() == 2));
    }
}
