//! Hinted handoff: parked replication updates for unreachable peers.
//!
//! When a push target is `Down` (per the failure detector) — or a push
//! exhausts its retry attempts while hinting is enabled — the
//! [`crate::kvstore::Replicator`] parks the update here instead of
//! dropping it. Each peer gets a bounded FIFO queue of [`Hint`]s keyed by
//! its replication address; when the detector reports the peer up again,
//! the queue is drained back into the replication pipeline **in order**
//! (re-addressed if the peer restarted at a new address).
//!
//! Queues are kept small by the same two tricks the live pipeline uses:
//!
//! - a **full-state** hint supersedes every older queued hint for the
//!   same key (last-writer-wins makes them dead weight);
//! - a **delta** hint whose base continues the newest queued delta for
//!   the key merges into it (fragments concatenated), so an outage
//!   spanning many turns costs one replay per session.
//!
//! Replayed deltas that still miss their base on the receiver fall back
//! to a full-state `/fetch` exactly like the live delta path — replay can
//! therefore never diverge a replica, only catch it up.

use std::collections::{HashMap, HashSet, VecDeque};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::sync::{classes, OrderedMutex};

/// Hinted-handoff tuning (`hints` config section).
#[derive(Debug, Clone)]
pub struct HintConfig {
    /// Maximum parked hints per peer; the oldest hint is evicted (and
    /// counted dropped) when a park would exceed it.
    pub max_per_peer: usize,
}

impl Default for HintConfig {
    fn default() -> HintConfig {
        HintConfig { max_per_peer: 512 }
    }
}

/// The payload of a parked update (mirror of the replicator's job kinds).
#[derive(Debug, Clone, PartialEq)]
pub enum HintUpdate {
    /// Whole-document write.
    Full {
        /// Serialized document.
        value: String,
    },
    /// Append-only fragment on top of `base`.
    Delta {
        /// Version the receiver must hold for the delta to apply.
        base: u64,
        /// Self-describing fragment document (`context::codec`).
        frag: String,
        /// The sender's replication listener, for the receiver's
        /// full-state fallback fetch.
        from: SocketAddr,
    },
}

/// One parked replication update for one peer.
#[derive(Debug, Clone, PartialEq)]
pub struct Hint {
    /// Keygroup of the write.
    pub keygroup: String,
    /// Session key of the write.
    pub key: String,
    /// Full-state or delta payload.
    pub update: HintUpdate,
    /// Version the write produces.
    pub version: u64,
    /// Remaining TTL in milliseconds at park time.
    pub ttl_ms: Option<u64>,
}

/// Callback invoked with every hint the per-peer bound evicts — the
/// record is lost to replay, so the subscriber (anti-entropy repair)
/// takes over responsibility for the divergence it leaves behind.
/// `Arc` (not `Box`) so the hook can be cloned out of its slot and
/// invoked with no handoff lock held.
pub type EvictionHook = Arc<dyn Fn(SocketAddr, &Hint) + Send + Sync>;

/// Per-node hint storage plus the down-peer set the replicator consults
/// before every send.
pub struct HintedHandoff {
    cfg: HintConfig,
    queues: OrderedMutex<HashMap<SocketAddr, VecDeque<Hint>>>,
    down: OrderedMutex<HashSet<SocketAddr>>,
    /// Old address → current address for restarted peers. A push job
    /// that was already in flight to the old listener when the peer
    /// rejoined would otherwise park under a queue key no future replay
    /// ever drains; forwarding parks it where the next replay looks.
    forwards: OrderedMutex<HashMap<SocketAddr, SocketAddr>>,
    /// Observer of bound-evicted hints (anti-entropy damage handoff).
    on_evict: OrderedMutex<Option<EvictionHook>>,
    queued: AtomicU64,
    replayed: AtomicU64,
    dropped: AtomicU64,
}

impl std::fmt::Debug for HintedHandoff {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HintedHandoff")
            .field("queued", &self.queued())
            .field("replayed", &self.replayed())
            .field("dropped", &self.dropped())
            .finish_non_exhaustive()
    }
}

impl HintedHandoff {
    /// Empty handoff store.
    pub fn new(cfg: HintConfig) -> Arc<HintedHandoff> {
        Arc::new(HintedHandoff {
            cfg,
            queues: OrderedMutex::new(&classes::HINT_QUEUES, HashMap::new()),
            down: OrderedMutex::new(&classes::HINT_DOWN, HashSet::new()),
            forwards: OrderedMutex::new(&classes::HINT_FORWARDS, HashMap::new()),
            on_evict: OrderedMutex::new(&classes::HINT_EVICT, None),
            queued: AtomicU64::new(0),
            replayed: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        })
    }

    /// Whether the failure detector currently marks `peer` down.
    pub fn is_down(&self, peer: SocketAddr) -> bool {
        self.down.lock().unwrap().contains(&peer)
    }

    /// Mark `peer` down: subsequent pushes park immediately instead of
    /// burning connect attempts against a dead listener.
    pub fn set_down(&self, peer: SocketAddr) {
        self.down.lock().unwrap().insert(peer);
    }

    /// Clear the down mark (the peer answered a probe or rejoined).
    pub fn set_up(&self, peer: SocketAddr) {
        self.down.lock().unwrap().remove(&peer);
    }

    /// Record that hints addressed to `old` park under `new` from now on
    /// (the peer restarted on a fresh port). Without this, a push that
    /// was already queued for the old listener when the rejoin replay
    /// ran would park under a key nothing ever drains again.
    pub fn set_forward(&self, old: SocketAddr, new: SocketAddr) {
        if old != new {
            self.forwards.lock().unwrap().insert(old, new);
        }
    }

    /// Follow the forwarding chain from `peer` to its current address
    /// (bounded hops: address reuse across restarts could form a cycle).
    /// `peer` itself when no restart forward is recorded. The sender
    /// uses a changed answer as the signal that the peer restarted while
    /// a push was in flight — meaning the rejoin replay already ran and
    /// a fresh park needs its own requeue.
    pub fn resolve_addr(&self, peer: SocketAddr) -> SocketAddr {
        self.resolve(peer)
    }

    fn resolve(&self, peer: SocketAddr) -> SocketAddr {
        let forwards = self.forwards.lock().unwrap();
        let mut addr = peer;
        for _ in 0..8 {
            match forwards.get(&addr) {
                Some(next) if *next != addr => addr = *next,
                _ => break,
            }
        }
        addr
    }

    /// Register the observer called with every bound-evicted hint (used
    /// by anti-entropy repair to take over what replay can no longer
    /// deliver). At most one hook; a second call replaces the first.
    pub fn set_eviction_hook(&self, hook: EvictionHook) {
        *self.on_evict.lock().unwrap() = Some(hook);
    }

    /// Park an update for `peer` (resolved through restart forwards),
    /// coalescing where safe. Evicts the oldest hint (counted in
    /// [`Self::dropped`] and reported to the eviction hook) on overflow.
    pub fn park(&self, peer: SocketAddr, hint: Hint) {
        let peer = self.resolve(peer);
        self.queued.fetch_add(1, Ordering::SeqCst);
        let evicted = {
            let mut queues = self.queues.lock().unwrap();
            let q = queues.entry(peer).or_default();
            match &hint.update {
                // LWW: every older queued hint for this key is dead weight
                // once a newer full-state write is parked behind it.
                HintUpdate::Full { .. } => {
                    q.retain(|h| {
                        h.keygroup != hint.keygroup
                            || h.key != hint.key
                            || h.version > hint.version
                    });
                }
                // Contiguous deltas merge, mirroring the live queue's
                // coalescing: replaying one merged fragment equals
                // replaying the run one by one.
                HintUpdate::Delta { base, frag, .. } => {
                    if let Some(last) = q
                        .iter_mut()
                        .rev()
                        .find(|h| h.keygroup == hint.keygroup && h.key == hint.key)
                    {
                        if let HintUpdate::Delta { frag: qfrag, .. } = &mut last.update {
                            if last.version == *base {
                                if let Ok(merged) =
                                    crate::context::codec::concat_fragment_docs(qfrag, frag)
                                {
                                    *qfrag = merged;
                                    last.version = hint.version;
                                    last.ttl_ms = hint.ttl_ms;
                                    return;
                                }
                            }
                        }
                    }
                }
            }
            let evicted = if q.len() >= self.cfg.max_per_peer {
                self.dropped.fetch_add(1, Ordering::SeqCst);
                q.pop_front()
            } else {
                None
            };
            q.push_back(hint);
            evicted
        };
        // The hook runs with *no* handoff lock held — not the queues lock
        // (released above) and not the on_evict slot either: it marks
        // Merkle buckets dirty and kicks the repair thread, and anything
        // it reaches must stay free to park or re-register concurrently.
        if let Some(hint) = evicted {
            let hook = self.on_evict.lock().unwrap().clone();
            if let Some(hook) = hook {
                hook(peer, &hint);
            }
        }
    }

    /// Drain every hint parked for `peer`, in park order; counts them as
    /// replayed (the caller re-enqueues them for delivery).
    pub fn take(&self, peer: SocketAddr) -> Vec<Hint> {
        let hints: Vec<Hint> = self
            .queues
            .lock()
            .unwrap()
            .remove(&peer)
            .map(Vec::from)
            .unwrap_or_default();
        self.replayed.fetch_add(hints.len() as u64, Ordering::SeqCst);
        hints
    }

    /// Whether any hints are parked for `peer`.
    pub fn has_hints(&self, peer: SocketAddr) -> bool {
        self.queues
            .lock()
            .unwrap()
            .get(&peer)
            .is_some_and(|q| !q.is_empty())
    }

    /// Parked hints currently held for `peer`.
    pub fn len(&self, peer: SocketAddr) -> usize {
        self.queues.lock().unwrap().get(&peer).map_or(0, VecDeque::len)
    }

    /// True when no peer has parked hints.
    pub fn is_empty(&self) -> bool {
        self.queues.lock().unwrap().values().all(VecDeque::is_empty)
    }

    /// Total updates parked (before coalescing/supersede).
    pub fn queued(&self) -> u64 {
        self.queued.load(Ordering::SeqCst)
    }

    /// Total hint records handed back for replay.
    pub fn replayed(&self) -> u64 {
        self.replayed.load(Ordering::SeqCst)
    }

    /// Total hint records evicted by the per-peer bound.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::context::{StoredContext, TokenCodec};

    fn peer(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn full(key: &str, version: u64, value: &str) -> Hint {
        Hint {
            keygroup: "m".into(),
            key: key.into(),
            update: HintUpdate::Full {
                value: value.into(),
            },
            version,
            ttl_ms: None,
        }
    }

    fn delta(key: &str, base: u64, version: u64, ids: Vec<u32>) -> Hint {
        Hint {
            keygroup: "m".into(),
            key: key.into(),
            update: HintUpdate::Delta {
                base,
                frag: StoredContext::Tokens(ids).to_fragment(TokenCodec::BinaryU16),
                from: peer(9),
            },
            version,
            ttl_ms: None,
        }
    }

    #[test]
    fn down_marks_toggle() {
        let h = HintedHandoff::new(HintConfig::default());
        assert!(!h.is_down(peer(1)));
        h.set_down(peer(1));
        assert!(h.is_down(peer(1)));
        h.set_up(peer(1));
        assert!(!h.is_down(peer(1)));
    }

    #[test]
    fn park_and_take_preserve_order() {
        let h = HintedHandoff::new(HintConfig::default());
        h.park(peer(1), full("s1", 1, "a"));
        h.park(peer(1), full("s2", 1, "b"));
        h.park(peer(2), full("s3", 1, "c"));
        assert_eq!(h.len(peer(1)), 2);
        assert_eq!(h.queued(), 3);
        let taken = h.take(peer(1));
        assert_eq!(
            taken.iter().map(|t| t.key.as_str()).collect::<Vec<_>>(),
            vec!["s1", "s2"]
        );
        assert_eq!(h.replayed(), 2);
        assert!(h.len(peer(1)) == 0 && h.len(peer(2)) == 1);
        assert!(h.take(peer(3)).is_empty());
    }

    #[test]
    fn newer_full_state_supersedes_older_hints_for_the_key() {
        let h = HintedHandoff::new(HintConfig::default());
        h.park(peer(1), full("s", 1, "v1"));
        h.park(peer(1), delta("s", 1, 2, vec![5]));
        h.park(peer(1), full("other", 1, "keep"));
        h.park(peer(1), full("s", 3, "v3"));
        let taken = h.take(peer(1));
        assert_eq!(taken.len(), 2, "{taken:?}");
        assert_eq!(taken[0].key, "other");
        assert_eq!(taken[1].version, 3);
        assert!(matches!(&taken[1].update, HintUpdate::Full { value } if value == "v3"));
    }

    #[test]
    fn contiguous_deltas_coalesce_in_the_queue() {
        let h = HintedHandoff::new(HintConfig::default());
        h.park(peer(1), delta("s", 1, 2, vec![10]));
        h.park(peer(1), delta("s", 2, 3, vec![11]));
        assert_eq!(h.len(peer(1)), 1);
        let taken = h.take(peer(1));
        let HintUpdate::Delta { base, frag, .. } = &taken[0].update else {
            panic!("expected delta");
        };
        assert_eq!(*base, 1);
        assert_eq!(taken[0].version, 3);
        assert_eq!(
            StoredContext::from_fragment(frag).unwrap(),
            StoredContext::Tokens(vec![10, 11])
        );
        // A gap must not merge.
        h.park(peer(1), delta("s", 1, 2, vec![20]));
        h.park(peer(1), delta("s", 5, 6, vec![21]));
        assert_eq!(h.len(peer(1)), 2);
    }

    #[test]
    fn parks_after_a_restart_forward_land_under_the_new_address() {
        let h = HintedHandoff::new(HintConfig::default());
        // A stale in-flight job parks against the pre-restart address...
        h.set_forward(peer(1), peer(2));
        h.park(peer(1), full("s", 4, "v4"));
        assert_eq!(h.len(peer(1)), 0, "old key must stay empty");
        assert_eq!(h.len(peer(2)), 1, "park must follow the forward");
        // ...and chains across a second restart, with cycles bounded.
        h.set_forward(peer(2), peer(3));
        h.set_forward(peer(3), peer(2));
        h.park(peer(1), full("s", 5, "v5"));
        assert_eq!(h.len(peer(1)), 0);
        assert!(h.len(peer(2)) + h.len(peer(3)) >= 1);
    }

    #[test]
    fn overflow_evicts_oldest_and_counts() {
        let h = HintedHandoff::new(HintConfig { max_per_peer: 2 });
        h.park(peer(1), full("s1", 1, "a"));
        h.park(peer(1), full("s2", 1, "b"));
        h.park(peer(1), full("s3", 1, "c"));
        assert_eq!(h.dropped(), 1);
        let keys: Vec<String> = h.take(peer(1)).into_iter().map(|t| t.key).collect();
        assert_eq!(keys, vec!["s2", "s3"], "oldest hint must be evicted");
    }
}
