//! `discedge` — launcher CLI for the DisCEdge edge-LLM serving stack.
//!
//! Subcommands:
//! - `cluster [--config cfg.json] [--engine mock|pjrt]` — launch the
//!   (default two-node) cluster in-process and serve until Ctrl-C;
//! - `run-scenario [--mode tokenized|raw|client_side] [--mobility sticky|paper]
//!   [--engine mock|pjrt]` — drive the paper's 9-turn robotics scenario
//!   against a fresh cluster and print per-turn results;
//! - `profiles` — print the simulated hardware profile table (Table 1).

use discedge::cli::Args;
use discedge::client::{Client, MobilityPolicy};
use discedge::config::{ClusterConfig, ContextMode, EngineKind};
use discedge::profile::NodeProfile;
use discedge::server::EdgeCluster;
use discedge::workload::Scenario;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_deref() {
        Some("cluster") => cmd_cluster(&args),
        Some("run-scenario") => cmd_run_scenario(&args),
        Some("profiles") => {
            println!("{}", NodeProfile::table_markdown());
            0
        }
        _ => {
            eprintln!(
                "usage: discedge <cluster|run-scenario|profiles> [options]\n\
                 \n\
                 cluster       --config <file> | defaults to the paper's two-node testbed\n\
                 \u{20}             --engine mock|pjrt (default pjrt)\n\
                 \u{20}             --replication-factor N (default: replicate to all)\n\
                 \u{20}             --virtual-nodes V (ring points per node, default 128)\n\
                 \u{20}             --delta-sync (replicate per-turn deltas, not full state)\n\
                 \u{20}             --membership (heartbeat failure detection + hinted handoff)\n\
                 \u{20}             --heartbeat-ms N / --suspect-after K / --down-after-ms N\n\
                 \u{20}             --hints-max-per-peer N (parked updates per down peer, default 512)\n\
                 \u{20}             --antientropy (Merkle-tree background replica repair)\n\
                 \u{20}             --ae-interval-ms N / --ae-fanout F / --ae-max-keys K\n\
                 \u{20}             --storage (persist the KV replica: WAL + snapshots)\n\
                 \u{20}             --storage-dir D (fleet persistence root, default discedge-data)\n\
                 \u{20}             --snapshot-every N (compact after N WAL appends, default 4096)\n\
                 \u{20}             --fsync (fsync WAL appends and snapshots)\n\
                 \u{20}             --max-server-conns N (503 past this many live conns, default 256)\n\
                 \u{20}             --idle-timeout-ms N (reap idle server conns, default 60000)\n\
                 \u{20}             --pool-max-idle N (idle conns pooled per peer; 0 = no reuse)\n\
                 \u{20}             --trace (per-turn tracing: GET /trace and GET /status)\n\
                 \u{20}             --trace-buffer N (spans kept per node, default 1024)\n\
                 \u{20}             --trace-level L (event filter, e.g. info or warn,ae=debug)\n\
                 \u{20}             --metrics-window-ms N (windowed rates/percentiles on /metrics)\n\
                 \u{20}             --fleet (fleet aggregator: poll nodes, append health CSV)\n\
                 \u{20}             --fleet-poll-ms N (aggregator period, default 1000)\n\
                 \u{20}             --fleet-out P (health CSV path, default results/fleet_health.csv)\n\
                 \u{20}             --batching (continuous batching: admission queue + batch scheduler)\n\
                 \u{20}             --max-batch N (sequences decoded together per step, default 8)\n\
                 \u{20}             --queue-depth N (admission bound, 503 past it, default 64)\n\
                 \u{20}             --stream (chunked /completion: tokens stream as steps complete)\n\
                 run-scenario  --mode tokenized|raw|client_side (default tokenized)\n\
                 \u{20}             --mobility sticky|paper (default sticky)\n\
                 \u{20}             --engine mock|pjrt (default pjrt)\n\
                 \u{20}             --max-tokens N (default 128)\n\
                 \u{20}             --replication-factor N / --virtual-nodes V (as above)\n\
                 \u{20}             --delta-sync / --membership etc. (as above)\n\
                 profiles      print the hardware profile table"
            );
            2
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> Result<ClusterConfig, String> {
    let mut cfg = match args.opt("config") {
        Some(path) => ClusterConfig::load(std::path::Path::new(path)).map_err(|e| e.to_string())?,
        None => ClusterConfig::two_node_testbed(),
    };
    match args.opt("engine") {
        Some("mock") => {
            cfg.engine = EngineKind::Mock {
                prefill_ns_per_token: 2_000,
                decode_ns_per_token: 1_000_000,
            }
        }
        Some("pjrt") | None => {}
        Some(other) => return Err(format!("unknown engine {other}")),
    }
    if let Some(rf) = args
        .opt_parse::<usize>("replication-factor")
        .map_err(|e| e.to_string())?
    {
        cfg.sharding.replication_factor = Some(rf);
    }
    if let Some(vn) = args
        .opt_parse::<usize>("virtual-nodes")
        .map_err(|e| e.to_string())?
    {
        cfg.sharding.virtual_nodes = vn;
    }
    if args.flag("delta-sync") {
        cfg.replication.delta_sync = true;
    }
    if args.flag("membership") {
        cfg.membership.enabled = true;
    }
    if let Some(ms) = args
        .opt_parse::<u64>("heartbeat-ms")
        .map_err(|e| e.to_string())?
    {
        cfg.membership.heartbeat = std::time::Duration::from_millis(ms);
    }
    if let Some(k) = args
        .opt_parse::<u32>("suspect-after")
        .map_err(|e| e.to_string())?
    {
        cfg.membership.suspect_after = k;
    }
    if let Some(ms) = args
        .opt_parse::<u64>("down-after-ms")
        .map_err(|e| e.to_string())?
    {
        cfg.membership.down_after = std::time::Duration::from_millis(ms);
    }
    if let Some(n) = args
        .opt_parse::<usize>("hints-max-per-peer")
        .map_err(|e| e.to_string())?
    {
        cfg.hints.max_per_peer = n;
    }
    if args.flag("antientropy") {
        cfg.antientropy.enabled = true;
    }
    if let Some(ms) = args
        .opt_parse::<u64>("ae-interval-ms")
        .map_err(|e| e.to_string())?
    {
        cfg.antientropy.interval = std::time::Duration::from_millis(ms);
    }
    if let Some(f) = args
        .opt_parse::<usize>("ae-fanout")
        .map_err(|e| e.to_string())?
    {
        cfg.antientropy.fanout = f;
    }
    if let Some(k) = args
        .opt_parse::<usize>("ae-max-keys")
        .map_err(|e| e.to_string())?
    {
        cfg.antientropy.max_keys_per_round = k;
    }
    if args.flag("storage") {
        cfg.storage.enabled = true;
    }
    if let Some(d) = args.opt("storage-dir") {
        cfg.storage.dir = std::path::PathBuf::from(d);
    }
    if let Some(n) = args
        .opt_parse::<u64>("snapshot-every")
        .map_err(|e| e.to_string())?
    {
        cfg.storage.snapshot_every = n;
    }
    if args.flag("fsync") {
        cfg.storage.fsync = true;
    }
    if let Some(n) = args
        .opt_parse::<usize>("max-server-conns")
        .map_err(|e| e.to_string())?
    {
        cfg.transport.max_server_conns = n;
    }
    if let Some(ms) = args
        .opt_parse::<u64>("idle-timeout-ms")
        .map_err(|e| e.to_string())?
    {
        cfg.transport.idle_timeout = std::time::Duration::from_millis(ms);
    }
    if let Some(n) = args
        .opt_parse::<usize>("pool-max-idle")
        .map_err(|e| e.to_string())?
    {
        cfg.transport.max_idle_per_peer = n;
    }
    if args.flag("trace") {
        cfg.observability.enabled = true;
    }
    if let Some(n) = args
        .opt_parse::<usize>("trace-buffer")
        .map_err(|e| e.to_string())?
    {
        cfg.observability.trace_buffer = n;
    }
    if let Some(l) = args.opt("trace-level") {
        cfg.observability.level = l.to_string();
    }
    if let Some(ms) = args
        .opt_parse::<u64>("metrics-window-ms")
        .map_err(|e| e.to_string())?
    {
        cfg.observability.window_ms = ms;
    }
    if args.flag("batching") {
        cfg.inference.enabled = true;
    }
    if let Some(n) = args
        .opt_parse::<usize>("max-batch")
        .map_err(|e| e.to_string())?
    {
        cfg.inference.max_batch = n;
    }
    if let Some(n) = args
        .opt_parse::<usize>("queue-depth")
        .map_err(|e| e.to_string())?
    {
        cfg.inference.queue_depth = n;
    }
    if args.flag("stream") {
        cfg.inference.stream = true;
    }
    if args.flag("fleet") {
        cfg.fleet.enabled = true;
    }
    if let Some(ms) = args
        .opt_parse::<u64>("fleet-poll-ms")
        .map_err(|e| e.to_string())?
    {
        cfg.fleet.poll_ms = ms;
    }
    if let Some(p) = args.opt("fleet-out") {
        cfg.fleet.out = std::path::PathBuf::from(p);
    }
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(cfg)
}

fn cmd_cluster(args: &Args) -> i32 {
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let cluster = match EdgeCluster::launch(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("launch failed: {e}");
            return 1;
        }
    };
    println!("DisCEdge cluster up:");
    for (name, addr) in cluster.endpoints() {
        println!("  {name}  http://{addr}  (POST /completion, GET /health, GET /metrics)");
    }
    println!("serving; Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_run_scenario(args: &Args) -> i32 {
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let mode = match ContextMode::parse(args.opt_or("mode", "tokenized")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mobility = match args.opt_or("mobility", "sticky") {
        "sticky" => MobilityPolicy::Sticky(0),
        "paper" => MobilityPolicy::paper_alternate(),
        other => {
            eprintln!("unknown mobility {other}");
            return 2;
        }
    };
    let max_tokens = args.opt_parse_or("max-tokens", 128usize).unwrap_or(128);

    let scenario = Scenario::robotics_9turn();
    let model = cfg.nodes[0].models[0].clone();
    let client_link = cfg.client_link.clone();
    eprintln!("launching cluster ({} nodes)...", cfg.nodes.len());
    let cluster = match EdgeCluster::launch(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("launch failed: {e}");
            return 1;
        }
    };
    let mut client = Client::connect(cluster.endpoints(), mobility)
        .with_mode(mode)
        .with_model(&model)
        .with_link(client_link)
        .with_max_tokens(max_tokens);

    println!("turn | node      | e2e_s   | tok_s   | infer_s | req_B  | gen");
    for turn in scenario.turns() {
        match client.chat(&turn.prompt) {
            Ok(r) => println!(
                "{:>4} | {:<9} | {:>7.3} | {:>7.4} | {:>7.3} | {:>6} | {}",
                turn.number,
                r.node,
                r.e2e_s,
                r.response.timings.tokenize_s,
                r.response.timings.prefill_s + r.response.timings.decode_s,
                r.request_bytes,
                r.response.tokens_generated,
            ),
            Err(e) => {
                eprintln!("turn {} failed: {e}", turn.number);
                return 1;
            }
        }
    }
    cluster.quiesce();
    for node in &cluster.nodes {
        println!(
            "node {}: sync_bytes={} requests={} push_targets={} read_repairs={}",
            node.name,
            node.sync_bytes(),
            node.cm.registry.counter("cm_requests_total"),
            node.kv.push_targets(),
            node.kv.read_repairs(),
        );
    }
    0
}
