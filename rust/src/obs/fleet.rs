//! Fleet aggregator: one live view over every node's `/status` and
//! `/metrics`.
//!
//! A single [`FleetAggregator`] polls each node's API endpoint over a
//! pooled keep-alive client ([`PeerPool`]), parses the scrape text and
//! the status JSON into one [`NodeHealth`] per node, and rolls the fleet
//! up into a [`FleetSnapshot`]: fleet-wide windowed p50/p99, total
//! request rate, total hint backlog, the worst replication lag, and the
//! oldest anti-entropy round. Each poll appends one CSV row per node to
//! `fleet.out` (default `results/fleet_health.csv`) so a bench run
//! leaves a health timeline next to its figures, and
//! [`FleetAggregator::render_table`] formats the same snapshot as a
//! one-screen operator table (the `pallas_top` binary's refresh loop).
//!
//! Default off (`fleet.enabled = false`). When enabled,
//! [`EdgeCluster::launch_with`](crate::server::EdgeCluster::launch_with)
//! starts the poll thread and stops it when the cluster drops. The
//! aggregator is a pure *client* of the observability plane: it rides
//! the API port, so replication / fetch / anti-entropy wire bytes are
//! untouched whether or not it runs.

use std::collections::HashMap;
use std::io::Write;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::http::Request;
use crate::json;
use crate::netsim::{LinkModel, TrafficMeter};
use crate::transport::PeerPool;
use crate::Result;

/// Fleet aggregator configuration (config file section `fleet`).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Run the aggregator poll thread alongside the cluster.
    pub enabled: bool,
    /// Poll period in milliseconds.
    pub poll_ms: u64,
    /// CSV output path; one row per node per poll is appended.
    pub out: PathBuf,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            enabled: false,
            poll_ms: 1000,
            out: PathBuf::from("results/fleet_health.csv"),
        }
    }
}

/// One node's health, parsed from a single `/status` + `/metrics` poll.
#[derive(Debug, Clone)]
pub struct NodeHealth {
    /// Node name (config order).
    pub node: String,
    /// Completed requests per second over the last windowed second
    /// (`cm_requests_total_rate1s`; 0 when windows are off).
    pub rate1s: f64,
    /// Windowed p50 request latency in seconds (`cm_request_s_p50_w`).
    pub p50_w_s: Option<f64>,
    /// Windowed p99 request latency in seconds (`cm_request_s_p99_w`).
    pub p99_w_s: Option<f64>,
    /// Hinted-handoff backlog (`kv_hints_queued`).
    pub hints_queued: u64,
    /// Worst replication version gap (`kv_repl_max_lag_versions`).
    pub max_lag_versions: u64,
    /// Keys behind on at least one peer (`kv_repl_lag_keys`).
    pub lag_keys: u64,
    /// Age of the oldest unacknowledged update, ms (`None` when clean
    /// or lag tracking is off).
    pub staleness_ms: Option<u64>,
    /// Ms since the last anti-entropy round (`None` when AE is off or
    /// has not run).
    pub ae_round_age_ms: Option<u64>,
    /// Cumulative replication-port bytes, both directions
    /// (`kv_sync_bytes`).
    pub wire_bytes: u64,
    /// Replication-port byte rate since the previous poll (0 on the
    /// first sample).
    pub wire_rate_bps: f64,
}

/// One poll of the whole fleet, with rollups.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    /// Ms since the aggregator started.
    pub elapsed_ms: u64,
    /// Per-node health, in target order. Unreachable nodes are skipped.
    pub nodes: Vec<NodeHealth>,
    /// Targets that did not answer this poll.
    pub unreachable: u64,
    /// Sum of per-node request rates.
    pub total_rate1s: f64,
    /// Worst windowed p50 across the fleet.
    pub fleet_p50_w_s: Option<f64>,
    /// Worst windowed p99 across the fleet.
    pub fleet_p99_w_s: Option<f64>,
    /// Total hinted-handoff backlog.
    pub total_hints_queued: u64,
    /// Worst replication version gap anywhere.
    pub max_lag_versions: u64,
    /// Oldest anti-entropy round age across the fleet.
    pub max_ae_round_age_ms: Option<u64>,
}

/// CSV header written once per output file (see `docs/ARCHITECTURE.md`,
/// "Fleet observability", for the column semantics).
pub const CSV_HEADER: &str = "elapsed_ms,node,rate1s,p50_w_s,p99_w_s,hints_queued,\
max_lag_versions,lag_keys,staleness_ms,ae_round_age_ms,wire_bytes,wire_rate_bps";

/// Polls every node's `/status` + `/metrics`, rolls the fleet up, and
/// appends health rows to the configured CSV.
pub struct FleetAggregator {
    targets: Vec<(String, SocketAddr)>,
    out: PathBuf,
    epoch: Instant,
    pool: PeerPool,
    /// node → (wire_bytes, elapsed_ms) at the previous poll, for rate
    /// computation.
    prev: Mutex<HashMap<String, (u64, u64)>>,
}

impl FleetAggregator {
    /// Build an aggregator over named API endpoints (cluster node order).
    pub fn new(cfg: &FleetConfig, targets: Vec<(String, SocketAddr)>) -> Arc<FleetAggregator> {
        Arc::new(FleetAggregator {
            targets,
            out: cfg.out.clone(),
            epoch: Instant::now(),
            pool: PeerPool::new(TrafficMeter::new(), LinkModel::ideal()),
            prev: Mutex::new(HashMap::new()),
        })
    }

    /// Poll every target once, append one CSV row per reachable node,
    /// and return the snapshot. Unreachable nodes are counted, not
    /// fatal; only the CSV write can fail.
    pub fn poll_once(&self) -> Result<FleetSnapshot> {
        let elapsed_ms = self.epoch.elapsed().as_millis() as u64;
        let mut nodes = Vec::with_capacity(self.targets.len());
        let mut unreachable = 0u64;
        for (name, addr) in &self.targets {
            match self.poll_node(name, *addr, elapsed_ms) {
                Some(h) => nodes.push(h),
                None => unreachable += 1,
            }
        }
        let snap = rollup(elapsed_ms, nodes, unreachable);
        self.append_csv(&snap)?;
        Ok(snap)
    }

    /// Where the CSV rows go.
    pub fn out_path(&self) -> &std::path::Path {
        &self.out
    }

    fn poll_node(&self, name: &str, addr: SocketAddr, elapsed_ms: u64) -> Option<NodeHealth> {
        let status = self.pool.round_trip(addr, &Request::get("/status")).ok()?;
        let metrics = self.pool.round_trip(addr, &Request::get("/metrics")).ok()?;
        if status.status != 200 || metrics.status != 200 {
            return None;
        }
        let status = json::parse(status.body_str().ok()?).ok()?;
        let text = metrics.body_str().ok()?;
        let wire_bytes = metric(text, "kv_sync_bytes").unwrap_or(0.0) as u64;
        let wire_rate_bps = {
            let mut prev = self.prev.lock().unwrap();
            let rate = prev.get(name).map_or(0.0, |(bytes, at)| {
                let dt_ms = elapsed_ms.saturating_sub(*at);
                if dt_ms == 0 {
                    0.0
                } else {
                    wire_bytes.saturating_sub(*bytes) as f64 * 1000.0 / dt_ms as f64
                }
            });
            prev.insert(name.to_string(), (wire_bytes, elapsed_ms));
            rate
        };
        let opt_u64 = |section: &str, field: &str| {
            status
                .get(section)
                .and_then(|s| s.get(field))
                .and_then(|v| v.as_u64())
        };
        Some(NodeHealth {
            node: name.to_string(),
            rate1s: metric(text, "cm_requests_total_rate1s").unwrap_or(0.0),
            p50_w_s: metric(text, "cm_request_s_p50_w"),
            p99_w_s: metric(text, "cm_request_s_p99_w"),
            hints_queued: metric(text, "kv_hints_queued").unwrap_or(0.0) as u64,
            max_lag_versions: metric(text, "kv_repl_max_lag_versions").unwrap_or(0.0) as u64,
            lag_keys: metric(text, "kv_repl_lag_keys").unwrap_or(0.0) as u64,
            staleness_ms: opt_u64("replication", "staleness_ms"),
            ae_round_age_ms: opt_u64("ae", "last_round_age_ms"),
            wire_bytes,
            wire_rate_bps,
        })
    }

    fn append_csv(&self, snap: &FleetSnapshot) -> Result<()> {
        if let Some(parent) = self.out.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let fresh = match std::fs::metadata(&self.out) {
            Ok(m) => m.len() == 0,
            Err(_) => true,
        };
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.out)?;
        let mut buf = String::new();
        if fresh {
            buf.push_str(CSV_HEADER);
            buf.push('\n');
        }
        for n in &snap.nodes {
            buf.push_str(&csv_row(snap.elapsed_ms, n));
            buf.push('\n');
        }
        f.write_all(buf.as_bytes())?;
        Ok(())
    }

    /// Render a snapshot as a one-screen operator table: one row per
    /// node plus a fleet rollup row.
    pub fn render_table(snap: &FleetSnapshot) -> String {
        let fmt_opt_s = |v: Option<f64>| v.map_or("-".to_string(), |s| format!("{s:.4}"));
        let fmt_opt_ms = |v: Option<u64>| v.map_or("-".to_string(), |ms| ms.to_string());
        let mut out = format!(
            "fleet health @ {} ms ({} node(s), {} unreachable)\n",
            snap.elapsed_ms,
            snap.nodes.len(),
            snap.unreachable
        );
        out.push_str(&format!(
            "{:<12} {:>8} {:>9} {:>9} {:>6} {:>6} {:>6} {:>10} {:>11} {:>12}\n",
            "node",
            "req/s",
            "p50_w(s)",
            "p99_w(s)",
            "hints",
            "lag_v",
            "lag_k",
            "stale(ms)",
            "ae_age(ms)",
            "wire(B/s)"
        ));
        for n in &snap.nodes {
            out.push_str(&format!(
                "{:<12} {:>8.1} {:>9} {:>9} {:>6} {:>6} {:>6} {:>10} {:>11} {:>12.0}\n",
                n.node,
                n.rate1s,
                fmt_opt_s(n.p50_w_s),
                fmt_opt_s(n.p99_w_s),
                n.hints_queued,
                n.max_lag_versions,
                n.lag_keys,
                fmt_opt_ms(n.staleness_ms),
                fmt_opt_ms(n.ae_round_age_ms),
                n.wire_rate_bps,
            ));
        }
        out.push_str(&format!(
            "{:<12} {:>8.1} {:>9} {:>9} {:>6} {:>6} {:>6}\n",
            "fleet",
            snap.total_rate1s,
            fmt_opt_s(snap.fleet_p50_w_s),
            fmt_opt_s(snap.fleet_p99_w_s),
            snap.total_hints_queued,
            snap.max_lag_versions,
            snap.nodes.iter().map(|n| n.lag_keys).sum::<u64>(),
        ));
        out
    }

    /// Start the background poll loop. The returned handle stops and
    /// joins the thread on drop.
    pub fn start(cfg: &FleetConfig, targets: Vec<(String, SocketAddr)>) -> FleetHandle {
        let agg = FleetAggregator::new(cfg, targets);
        let stop = Arc::new(AtomicBool::new(false));
        let t_agg = agg.clone();
        let t_stop = stop.clone();
        let poll_ms = cfg.poll_ms.max(1);
        let thread = std::thread::Builder::new()
            .name("fleet-aggregator".into())
            .spawn(move || {
                while !t_stop.load(Ordering::Relaxed) {
                    // Sleep in short slices so drop never waits a full
                    // poll period for the join.
                    let deadline = Instant::now() + Duration::from_millis(poll_ms);
                    while Instant::now() < deadline {
                        if t_stop.load(Ordering::Relaxed) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    // Unreachable nodes and CSV errors must not kill the
                    // loop mid-run; the next poll retries both.
                    let _ = t_agg.poll_once();
                }
            })
            .expect("spawn fleet-aggregator thread");
        FleetHandle {
            agg,
            stop,
            thread: Some(thread),
        }
    }
}

/// Running aggregator poll thread; stops and joins on drop.
pub struct FleetHandle {
    agg: Arc<FleetAggregator>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl FleetHandle {
    /// The aggregator behind the thread (for on-demand polls in tests
    /// and benches).
    pub fn aggregator(&self) -> &Arc<FleetAggregator> {
        &self.agg
    }
}

impl Drop for FleetHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        // One final poll, so a run shorter than a poll period still
        // leaves health rows behind (the cluster drops this handle
        // before severing the node listeners).
        let _ = self.agg.poll_once();
    }
}

/// Extract one value from `/metrics` scrape text (`name value` lines).
fn metric(text: &str, name: &str) -> Option<f64> {
    text.lines().find_map(|line| {
        let (k, v) = line.split_once(' ')?;
        if k == name {
            v.trim().parse().ok()
        } else {
            None
        }
    })
}

/// Roll per-node health up into a fleet snapshot.
fn rollup(elapsed_ms: u64, nodes: Vec<NodeHealth>, unreachable: u64) -> FleetSnapshot {
    let max_opt = |pick: fn(&NodeHealth) -> Option<f64>| {
        nodes.iter().filter_map(pick).max_by(|a, b| a.total_cmp(b))
    };
    FleetSnapshot {
        elapsed_ms,
        unreachable,
        total_rate1s: nodes.iter().map(|n| n.rate1s).sum(),
        fleet_p50_w_s: max_opt(|n| n.p50_w_s),
        fleet_p99_w_s: max_opt(|n| n.p99_w_s),
        total_hints_queued: nodes.iter().map(|n| n.hints_queued).sum(),
        max_lag_versions: nodes.iter().map(|n| n.max_lag_versions).max().unwrap_or(0),
        max_ae_round_age_ms: nodes.iter().filter_map(|n| n.ae_round_age_ms).max(),
        nodes,
    }
}

/// One CSV row (no trailing newline). Optional columns render empty.
fn csv_row(elapsed_ms: u64, n: &NodeHealth) -> String {
    let opt_s = |v: Option<f64>| v.map_or(String::new(), |x| format!("{x:.6}"));
    let opt_ms = |v: Option<u64>| v.map_or(String::new(), |x| x.to_string());
    format!(
        "{},{},{:.3},{},{},{},{},{},{},{},{},{:.1}",
        elapsed_ms,
        n.node,
        n.rate1s,
        opt_s(n.p50_w_s),
        opt_s(n.p99_w_s),
        n.hints_queued,
        n.max_lag_versions,
        n.lag_keys,
        opt_ms(n.staleness_ms),
        opt_ms(n.ae_round_age_ms),
        n.wire_bytes,
        n.wire_rate_bps,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn health(node: &str) -> NodeHealth {
        NodeHealth {
            node: node.into(),
            rate1s: 2.0,
            p50_w_s: Some(0.010),
            p99_w_s: Some(0.030),
            hints_queued: 1,
            max_lag_versions: 0,
            lag_keys: 0,
            staleness_ms: None,
            ae_round_age_ms: Some(40),
            wire_bytes: 1000,
            wire_rate_bps: 500.0,
        }
    }

    #[test]
    fn metric_parses_exact_names_only() {
        let text = "kv_hints_queued 3\nkv_hints_queued_total 9\ncm_request_s_p99_w 0.125000\n";
        assert_eq!(metric(text, "kv_hints_queued"), Some(3.0));
        assert_eq!(metric(text, "cm_request_s_p99_w"), Some(0.125));
        assert_eq!(metric(text, "kv_hints"), None, "prefixes must not match");
        assert_eq!(metric(text, "absent"), None);
    }

    #[test]
    fn rollup_sums_and_maxes_across_nodes() {
        let mut a = health("a");
        let mut b = health("b");
        a.max_lag_versions = 2;
        a.p99_w_s = Some(0.5);
        b.hints_queued = 4;
        b.ae_round_age_ms = Some(90);
        let snap = rollup(7, vec![a, b], 1);
        assert_eq!(snap.elapsed_ms, 7);
        assert_eq!(snap.unreachable, 1);
        assert_eq!(snap.total_rate1s, 4.0);
        assert_eq!(snap.total_hints_queued, 5);
        assert_eq!(snap.max_lag_versions, 2);
        assert_eq!(snap.fleet_p99_w_s, Some(0.5));
        assert_eq!(snap.max_ae_round_age_ms, Some(90));
    }

    #[test]
    fn rollup_of_empty_fleet_is_clean() {
        let snap = rollup(0, Vec::new(), 2);
        assert_eq!(snap.max_lag_versions, 0);
        assert_eq!(snap.fleet_p50_w_s, None);
        assert_eq!(snap.max_ae_round_age_ms, None);
    }

    #[test]
    fn csv_row_renders_optionals_empty() {
        let mut n = health("edge-a");
        n.p50_w_s = None;
        n.staleness_ms = Some(12);
        let row = csv_row(42, &n);
        assert_eq!(row, "42,edge-a,2.000,,0.030000,1,0,0,12,40,1000,500.0");
        assert_eq!(
            row.matches(',').count(),
            CSV_HEADER.matches(',').count(),
            "row and header column counts must agree"
        );
    }

    #[test]
    fn render_table_lists_nodes_and_rollup() {
        let snap = rollup(5, vec![health("edge-a"), health("edge-b")], 0);
        let table = FleetAggregator::render_table(&snap);
        assert!(table.contains("edge-a"));
        assert!(table.contains("edge-b"));
        assert!(table.lines().next().unwrap().contains("2 node(s)"));
        assert!(table.lines().last().unwrap().starts_with("fleet"));
    }

    #[test]
    fn aggregator_with_no_targets_writes_header_once() {
        let name = format!("discedge-fleet-test-{}.csv", std::process::id());
        let out = std::env::temp_dir().join(name);
        let _ = std::fs::remove_file(&out);
        let cfg = FleetConfig {
            enabled: true,
            poll_ms: 1000,
            out: out.clone(),
        };
        let agg = FleetAggregator::new(&cfg, Vec::new());
        let snap = agg.poll_once().unwrap();
        assert_eq!(snap.nodes.len(), 0);
        agg.poll_once().unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        assert_eq!(text.lines().count(), 1, "header only, written once");
        assert_eq!(text.lines().next().unwrap(), CSV_HEADER);
        let _ = std::fs::remove_file(&out);
    }
}
