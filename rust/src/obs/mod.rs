//! Zero-dependency observability: per-turn distributed trace spans, a
//! bounded per-node span ring buffer, and structured leveled events.
//!
//! The paper's headline numbers are end-to-end medians; this module is
//! what lets a slow turn be *attributed* — tokenize vs inference vs a
//! roaming remote fetch vs replication — without pulling in a tracing
//! framework (the default build stays at zero external dependencies).
//!
//! **Span model.** A [`TraceCtx`] (128-bit trace id + 64-bit span id) is
//! minted at `/completion` admission and carried across threads via a
//! scoped thread-local ([`set_current`]) and across *node boundaries*
//! via the [`TRACE_HEADER`] request header: [`crate::transport`] injects
//! it on every pooled round trip when a context is installed, and
//! [`crate::http`]'s server extracts it before invoking the handler. A
//! roaming turn's remote fetch, async delta push, and anti-entropy
//! repair pull therefore stitch under one trace id spanning every node
//! they touched.
//!
//! **Header wire format.** `x-pallas-trace: <32 hex trace id>-<16 hex
//! span id>` — 49 bytes of value, injected **only** when a trace context
//! is installed. With `observability.enabled = false` (the default) no
//! context is ever created, so replication/fetch/AE wire bytes are
//! byte-for-byte the seed protocol; a test pins this.
//!
//! **Ring buffer.** Completed spans land in a bounded per-node ring
//! (`observability.trace_buffer` entries, default 1024); the oldest span
//! is evicted on overflow and counted in `obs_spans_dropped`. `GET
//! /trace` serves the ring as JSON, filterable by trace id.
//!
//! **Events.** [`Obs::event`] replaces ad-hoc `eprintln!` on the
//! replication/AE/cluster paths (a pallas-lint rule keeps it that way):
//! leveled, per-subsystem filterable (`observability.level`, e.g.
//! `"info,ae=debug"`), counted by level in `/metrics`. Events still
//! reach stderr when observability is disabled — they are operator
//! output, not wire traffic — so the seed's warning behaviour is
//! preserved by the default `info` threshold.

pub mod fleet;

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::http::Request;
use crate::json::Value;

/// Request header carrying the trace context across node boundaries.
pub const TRACE_HEADER: &str = "x-pallas-trace";

/// `observability` config section. Default **off**: no spans, no ring,
/// no header injection — wire bytes identical to the seed.
#[derive(Debug, Clone)]
pub struct ObservabilityConfig {
    /// Master switch for span recording and trace propagation.
    pub enabled: bool,
    /// Ring-buffer capacity in spans (`trace_buffer`).
    pub trace_buffer: usize,
    /// Event threshold spec: a default level optionally followed by
    /// per-subsystem overrides, e.g. `"info"` or `"warn,ae=debug"`.
    pub level: String,
    /// Width of one metrics window in milliseconds (`window_ms`). `0`
    /// (the default) keeps windowed metrics off: `/metrics` emits only
    /// the seed's cumulative lines, byte-for-byte.
    pub window_ms: u64,
}

impl Default for ObservabilityConfig {
    fn default() -> ObservabilityConfig {
        ObservabilityConfig {
            enabled: false,
            trace_buffer: 1024,
            level: "info".into(),
            window_ms: 0,
        }
    }
}

/// Event severity, ordered `Debug < Info < Warn < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Diagnostic chatter (suppressed by the default threshold).
    Debug,
    /// Normal operational milestones.
    Info,
    /// Something degraded but handled (e.g. a lost replication push).
    Warn,
    /// Something failed.
    Error,
}

impl Level {
    /// Parse a level name.
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }

    /// Canonical lower-case name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

/// Parsed event threshold: a default level plus per-subsystem overrides.
#[derive(Debug, Clone)]
pub struct LevelFilter {
    default: Level,
    overrides: Vec<(String, Level)>,
}

impl LevelFilter {
    /// Parse a spec like `"info"` or `"warn,ae=debug,repl=error"`.
    /// `None` on any malformed segment.
    pub fn parse(spec: &str) -> Option<LevelFilter> {
        let mut parts = spec.split(',');
        let default = Level::parse(parts.next()?.trim())?;
        let mut overrides = Vec::new();
        for part in parts {
            let (subsystem, level) = part.split_once('=')?;
            let subsystem = subsystem.trim();
            if subsystem.is_empty() {
                return None;
            }
            overrides.push((subsystem.to_string(), Level::parse(level.trim())?));
        }
        Some(LevelFilter { default, overrides })
    }

    /// Threshold for a subsystem (the default unless overridden).
    pub fn threshold(&self, subsystem: &str) -> Level {
        self.overrides
            .iter()
            .find(|(s, _)| s == subsystem)
            .map(|(_, l)| *l)
            .unwrap_or(self.default)
    }
}

/// A trace context: which trace this work belongs to and which span is
/// its parent. Copied freely across threads and encoded into the
/// [`TRACE_HEADER`] across nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    /// 128-bit trace id shared by every span of one logical turn.
    pub trace_id: u128,
    /// The current span id (children record it as their parent).
    pub span_id: u64,
}

impl TraceCtx {
    /// Header wire encoding: `<32 hex>-<16 hex>`.
    pub fn encode(&self) -> String {
        format!("{:032x}-{:016x}", self.trace_id, self.span_id)
    }

    /// Parse the header wire encoding; `None` on any malformation.
    pub fn decode(s: &str) -> Option<TraceCtx> {
        let (trace, span) = s.split_once('-')?;
        if trace.len() != 32 || span.len() != 16 {
            return None;
        }
        Some(TraceCtx {
            trace_id: u128::from_str_radix(trace, 16).ok()?,
            span_id: u64::from_str_radix(span, 16).ok()?,
        })
    }
}

/// One completed span as held in the ring buffer.
#[derive(Debug, Clone)]
pub struct Span {
    /// Owning trace.
    pub trace_id: u128,
    /// This span's id.
    pub span_id: u64,
    /// Parent span id (`None` for a trace root).
    pub parent: Option<u64>,
    /// Node that recorded the span.
    pub node: String,
    /// Operation name (`turn`, `remote_fetch`, `repl_apply`, ...).
    pub name: String,
    /// Free-form detail (keygroup/key, peer address, ...).
    pub detail: String,
    /// Start offset in microseconds on the recording node's monotonic
    /// clock (offsets are comparable within a node, not across nodes —
    /// stitching across nodes uses parent ids, not clocks).
    pub start_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
}

impl Span {
    /// JSON object served by `GET /trace`.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj()
            .set("trace_id", format!("{:032x}", self.trace_id))
            .set("span_id", format!("{:016x}", self.span_id))
            .set("node", self.node.as_str())
            .set("name", self.name.as_str())
            .set("start_us", self.start_us)
            .set("dur_us", self.dur_us);
        if let Some(p) = self.parent {
            v = v.set("parent", format!("{p:016x}"));
        }
        if !self.detail.is_empty() {
            v = v.set("detail", self.detail.as_str());
        }
        v
    }
}

/// splitmix64 finalizer — id whitening, module-private (the kvstore has
/// its own copy scoped to ring placement).
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Per-node observability state: the span ring buffer, id generator,
/// event filter, and the counters `/metrics` exports as `obs_*`.
#[derive(Debug)]
pub struct Obs {
    enabled: bool,
    node: String,
    epoch: Instant,
    cap: usize,
    filter: LevelFilter,
    ring: Mutex<VecDeque<Span>>,
    /// Monotonic id source, whitened per draw with the node-derived seed.
    next_id: AtomicU64,
    seed: u64,
    spans_started: AtomicU64,
    spans_exported: AtomicU64,
    spans_dropped: AtomicU64,
    /// Event counts indexed by [`Level`] discriminant order.
    events: [AtomicU64; 4],
}

impl Obs {
    /// Build a node's observability state from its config section. An
    /// unparseable `level` spec falls back to `info` (config validation
    /// rejects it up front when the section is enabled).
    pub fn new(node: &str, cfg: &ObservabilityConfig) -> Arc<Obs> {
        let filter = LevelFilter::parse(&cfg.level)
            .unwrap_or_else(|| LevelFilter::parse("info").expect("static spec parses"));
        Arc::new(Obs {
            enabled: cfg.enabled,
            node: node.to_string(),
            epoch: Instant::now(),
            cap: cfg.trace_buffer.max(1),
            filter,
            ring: Mutex::new(VecDeque::new()),
            next_id: AtomicU64::new(1),
            seed: crate::testkit::fnv1a(node.as_bytes()) ^ u64::from(std::process::id()),
            spans_started: AtomicU64::new(0),
            spans_exported: AtomicU64::new(0),
            spans_dropped: AtomicU64::new(0),
            events: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        })
    }

    /// The default-off state every [`crate::kvstore::KvConfig`] starts
    /// with: events flow, spans and header injection stay off.
    pub fn disabled() -> Arc<Obs> {
        Obs::new("-", &ObservabilityConfig::default())
    }

    /// Is span recording (and thus trace propagation) on?
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Node name this state belongs to.
    pub fn node(&self) -> &str {
        &self.node
    }

    fn next_span_id(&self) -> u64 {
        // Whitened counter: unique within the node, seed-separated
        // across nodes sharing a test process.
        mix(self.next_id.fetch_add(1, Ordering::Relaxed) ^ self.seed).max(1)
    }

    /// Mint a fresh trace root context; `None` while disabled (the
    /// single gate keeping every downstream path wire-silent).
    pub fn begin_trace(&self) -> Option<TraceCtx> {
        if !self.enabled {
            return None;
        }
        let hi = mix(self.next_id.fetch_add(1, Ordering::Relaxed) ^ self.seed.rotate_left(17));
        let lo = self.next_span_id();
        Some(TraceCtx {
            trace_id: (u128::from(hi) << 64) | u128::from(lo),
            span_id: lo,
        })
    }

    /// A child context of `ctx`: same trace, fresh span id.
    pub fn child(&self, ctx: TraceCtx) -> TraceCtx {
        TraceCtx {
            trace_id: ctx.trace_id,
            span_id: self.next_span_id(),
        }
    }

    /// Record a completed span into the ring (no-op while disabled).
    /// `ctx` names the span itself; `parent` its parent span id.
    pub fn record_span(
        &self,
        ctx: TraceCtx,
        parent: Option<u64>,
        name: &str,
        detail: &str,
        start: Instant,
        dur: Duration,
    ) {
        if !self.enabled {
            return;
        }
        self.spans_started.fetch_add(1, Ordering::Relaxed);
        let span = Span {
            trace_id: ctx.trace_id,
            span_id: ctx.span_id,
            parent,
            node: self.node.clone(),
            name: name.to_string(),
            detail: detail.to_string(),
            start_us: start.saturating_duration_since(self.epoch).as_micros() as u64,
            dur_us: dur.as_micros() as u64,
        };
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= self.cap {
            ring.pop_front();
            self.spans_dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(span);
    }

    /// Snapshot the ring, oldest first, optionally filtered to one
    /// trace. Counts the returned spans as exported.
    pub fn spans(&self, trace_id: Option<u128>) -> Vec<Span> {
        let ring = self.ring.lock().unwrap();
        let out: Vec<Span> = ring
            .iter()
            .filter(|s| trace_id.is_none_or(|t| s.trace_id == t))
            .cloned()
            .collect();
        drop(ring);
        self.spans_exported
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        out
    }

    /// Emit a leveled event. Always counted (the `obs_events_*`
    /// counters in `/metrics`); written to stderr when `level` clears
    /// the subsystem's threshold. Active regardless of `enabled` —
    /// events are operator output, not wire traffic, and the seed's
    /// replication-loss warning must keep printing by default.
    pub fn event(&self, level: Level, subsystem: &str, msg: &str) {
        self.events[level as usize].fetch_add(1, Ordering::Relaxed);
        if level >= self.filter.threshold(subsystem) {
            eprintln!("[{} {} {subsystem}] {msg}", level.as_str(), self.node);
        }
    }

    /// Spans recorded into the ring since start.
    pub fn spans_started(&self) -> u64 {
        self.spans_started.load(Ordering::Relaxed)
    }

    /// Spans returned by `GET /trace` scrapes since start.
    pub fn spans_exported(&self) -> u64 {
        self.spans_exported.load(Ordering::Relaxed)
    }

    /// Spans evicted from the full ring since start.
    pub fn spans_dropped(&self) -> u64 {
        self.spans_dropped.load(Ordering::Relaxed)
    }

    /// Events emitted at `level` since start (filtered or not).
    pub fn events_at(&self, level: Level) -> u64 {
        self.events[level as usize].load(Ordering::Relaxed)
    }
}

thread_local! {
    /// The trace context of the work this thread is currently doing.
    static CURRENT: Cell<Option<TraceCtx>> = const { Cell::new(None) };
}

/// The thread's installed trace context, if any. The transport layer
/// injects [`TRACE_HEADER`] on outbound round trips exactly when this
/// is `Some`.
pub fn current() -> Option<TraceCtx> {
    CURRENT.with(|c| c.get())
}

/// Scope guard restoring the previously-installed context on drop, so
/// nesting (a traced request handled on a long-lived server thread)
/// unwinds correctly.
pub struct CtxGuard {
    prev: Option<TraceCtx>,
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
    }
}

/// Install `ctx` as the thread's trace context until the guard drops.
pub fn set_current(ctx: Option<TraceCtx>) -> CtxGuard {
    let prev = CURRENT.with(|c| c.replace(ctx));
    CtxGuard { prev }
}

/// Extract the trace context from an inbound request's [`TRACE_HEADER`]
/// (if present and well-formed) and install it for the handler's
/// duration. Called by the HTTP server's connection loop.
pub fn enter_inbound(req: &Request) -> CtxGuard {
    set_current(req.headers.get(TRACE_HEADER).and_then(|v| TraceCtx::decode(v)))
}

/// Clone `req` with the [`TRACE_HEADER`] carrying `ctx`. The transport
/// layer calls this only when a context is installed, so the
/// observability-off wire format is untouched.
pub fn with_trace_header(req: &Request, ctx: TraceCtx) -> Request {
    let mut out = req.clone();
    out.headers.insert(TRACE_HEADER.into(), ctx.encode());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled_obs(buffer: usize) -> Arc<Obs> {
        Obs::new(
            "t",
            &ObservabilityConfig {
                enabled: true,
                trace_buffer: buffer,
                ..Default::default()
            },
        )
    }

    #[test]
    fn header_encoding_round_trips() {
        let obs = enabled_obs(16);
        let ctx = obs.begin_trace().unwrap();
        let encoded = ctx.encode();
        assert_eq!(encoded.len(), 32 + 1 + 16);
        assert_eq!(TraceCtx::decode(&encoded), Some(ctx));
        // Extremes survive the hex framing.
        let edge = TraceCtx {
            trace_id: u128::MAX,
            span_id: 1,
        };
        assert_eq!(TraceCtx::decode(&edge.encode()), Some(edge));
        // Malformed inputs are rejected, not mis-parsed.
        for bad in ["", "xyz", "00-00", &encoded[1..], &encoded.replace('-', "_")] {
            assert_eq!(TraceCtx::decode(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn ring_evicts_oldest_first() {
        let obs = enabled_obs(3);
        let ctx = obs.begin_trace().unwrap();
        let t0 = Instant::now();
        for i in 0..5u64 {
            let child = obs.child(ctx);
            obs.record_span(child, Some(ctx.span_id), &format!("s{i}"), "", t0, Duration::ZERO);
        }
        let spans = obs.spans(None);
        assert_eq!(spans.len(), 3);
        // The two oldest (s0, s1) were evicted; order is preserved.
        let names: Vec<&str> = spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["s2", "s3", "s4"]);
        assert_eq!(obs.spans_dropped(), 2);
        assert_eq!(obs.spans_started(), 5);
        assert_eq!(obs.spans_exported(), 3);
    }

    #[test]
    fn spans_filter_by_trace_id() {
        let obs = enabled_obs(16);
        let a = obs.begin_trace().unwrap();
        let b = obs.begin_trace().unwrap();
        assert_ne!(a.trace_id, b.trace_id);
        let t0 = Instant::now();
        obs.record_span(a, None, "a", "", t0, Duration::ZERO);
        obs.record_span(b, None, "b", "", t0, Duration::ZERO);
        let only_a = obs.spans(Some(a.trace_id));
        assert_eq!(only_a.len(), 1);
        assert_eq!(only_a[0].name, "a");
    }

    #[test]
    fn disabled_state_records_nothing() {
        let obs = Obs::disabled();
        assert!(!obs.enabled());
        assert!(obs.begin_trace().is_none());
        let ctx = TraceCtx {
            trace_id: 7,
            span_id: 7,
        };
        obs.record_span(ctx, None, "x", "", Instant::now(), Duration::ZERO);
        assert!(obs.spans(None).is_empty());
        assert_eq!(obs.spans_started(), 0);
    }

    #[test]
    fn thread_local_guard_nests_and_restores() {
        assert_eq!(current(), None);
        let outer = TraceCtx {
            trace_id: 1,
            span_id: 1,
        };
        let inner = TraceCtx {
            trace_id: 2,
            span_id: 2,
        };
        let _g1 = set_current(Some(outer));
        assert_eq!(current(), Some(outer));
        {
            let _g2 = set_current(Some(inner));
            assert_eq!(current(), Some(inner));
        }
        assert_eq!(current(), Some(outer));
        drop(_g1);
        assert_eq!(current(), None);
    }

    #[test]
    fn inbound_extraction_and_header_injection() {
        let req = Request::post_json("/replicate", "{}");
        {
            // No header -> no context installed.
            let _g = enter_inbound(&req);
            assert_eq!(current(), None);
        }
        let ctx = TraceCtx {
            trace_id: 0xabc,
            span_id: 0xdef,
        };
        let traced = with_trace_header(&req, ctx);
        // Only the one header differs from the original request.
        assert_eq!(traced.headers.len(), req.headers.len() + 1);
        assert_eq!(traced.body, req.body);
        {
            let _g = enter_inbound(&traced);
            assert_eq!(current(), Some(ctx));
        }
        assert_eq!(current(), None);
    }

    #[test]
    fn level_filter_parses_and_thresholds() {
        let f = LevelFilter::parse("warn,ae=debug").unwrap();
        assert_eq!(f.threshold("repl"), Level::Warn);
        assert_eq!(f.threshold("ae"), Level::Debug);
        assert!(LevelFilter::parse("info").is_some());
        for bad in ["", "verbose", "info,ae", "info,=debug", "info,ae=nope"] {
            assert!(LevelFilter::parse(bad).is_none(), "{bad:?}");
        }
        assert!(Level::Debug < Level::Info && Level::Warn < Level::Error);
    }

    #[test]
    fn events_count_by_level_even_when_filtered() {
        let obs = Obs::new(
            "t",
            &ObservabilityConfig {
                enabled: false,
                trace_buffer: 1,
                level: "error".into(),
                ..Default::default()
            },
        );
        obs.event(Level::Debug, "ae", "quiet");
        obs.event(Level::Warn, "repl", "also quiet");
        obs.event(Level::Error, "repl", "loud");
        assert_eq!(obs.events_at(Level::Debug), 1);
        assert_eq!(obs.events_at(Level::Info), 0);
        assert_eq!(obs.events_at(Level::Warn), 1);
        assert_eq!(obs.events_at(Level::Error), 1);
    }

    #[test]
    fn ids_are_distinct_across_nodes_and_draws() {
        let a = enabled_obs(4);
        let b = Obs::new(
            "other",
            &ObservabilityConfig {
                enabled: true,
                trace_buffer: 4,
                ..Default::default()
            },
        );
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            assert!(seen.insert(a.begin_trace().unwrap().trace_id));
            assert!(seen.insert(b.begin_trace().unwrap().trace_id));
        }
    }
}
