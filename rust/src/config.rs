//! Configuration system: cluster topology, node profiles, links, model and
//! consistency settings — loadable from JSON files and constructible in
//! code for tests/benches.

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::cluster::{HintConfig, MembershipConfig};
use crate::json::{self, Value};
use crate::kvstore::{AntiEntropyConfig, ReplicationConfig, StorageConfig};
use crate::netsim::LinkModel;
use crate::profile::NodeProfile;
use crate::transport::TransportConfig;
use crate::{Error, Result};

/// Context storage mode (paper §4.1: raw / tokenized / client-side).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ContextMode {
    /// Server stores raw text; re-tokenizes the full history every turn.
    Raw,
    /// Server stores token ids; tokenizes only the new prompt (DisCEdge).
    Tokenized,
    /// Client ships the full history each request; server stores nothing.
    ClientSide,
}

impl ContextMode {
    /// Parse from the wire/config string.
    pub fn parse(s: &str) -> Result<ContextMode> {
        match s {
            "raw" => Ok(ContextMode::Raw),
            "tokenized" => Ok(ContextMode::Tokenized),
            "client_side" | "client-side" => Ok(ContextMode::ClientSide),
            _ => Err(Error::Config(format!("unknown context mode {s}"))),
        }
    }

    /// Wire/config string.
    pub fn as_str(&self) -> &'static str {
        match self {
            ContextMode::Raw => "raw",
            ContextMode::Tokenized => "tokenized",
            ContextMode::ClientSide => "client_side",
        }
    }
}

/// Consistency policy when the local replica is stale after retries
/// (paper §3.3: strong by default, availability as an option).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConsistencyPolicy {
    /// Fail the request (paper default).
    Strict,
    /// Proceed with the stale context.
    Available,
}

impl ConsistencyPolicy {
    /// Parse from the wire/config string.
    pub fn parse(s: &str) -> Result<ConsistencyPolicy> {
        match s {
            "strict" => Ok(ConsistencyPolicy::Strict),
            "available" => Ok(ConsistencyPolicy::Available),
            _ => Err(Error::Config(format!("unknown consistency policy {s}"))),
        }
    }
}

/// Turn-counter consistency protocol tuning (paper §4.2: 3 retries,
/// 10 ms backoff).
#[derive(Debug, Clone)]
pub struct ConsistencyConfig {
    /// Max re-reads of the local replica when stale.
    pub retries: u32,
    /// Backoff between re-reads.
    pub backoff: Duration,
    /// Behaviour on exhaustion.
    pub policy: ConsistencyPolicy,
}

impl Default for ConsistencyConfig {
    fn default() -> ConsistencyConfig {
        ConsistencyConfig {
            retries: 3,
            backoff: Duration::from_millis(10),
            policy: ConsistencyPolicy::Strict,
        }
    }
}

/// Generation settings (paper §4.2: temp 0, seed 123, max 128 tokens).
#[derive(Debug, Clone)]
pub struct GenerationConfig {
    /// Maximum new tokens per turn.
    pub max_tokens: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f64,
    /// Sampling seed (unused at temperature 0, kept for fidelity).
    pub seed: u64,
}

impl Default for GenerationConfig {
    fn default() -> GenerationConfig {
        GenerationConfig {
            max_tokens: 128,
            temperature: 0.0,
            seed: 123,
        }
    }
}

/// Inference scheduler: admission queue + continuous batching in front of
/// the engine, and streamed (chunked) `/completion` responses (default off:
/// every request runs solo through `Engine::generate` and the response is
/// buffered — byte-for-byte the seed's wire behaviour).
#[derive(Debug, Clone)]
pub struct InferenceConfig {
    /// Route completions through the batch scheduler.
    pub enabled: bool,
    /// Max sequences decoded together per step.
    pub max_batch: usize,
    /// Admission queue bound; requests beyond it are rejected with 503.
    pub queue_depth: usize,
    /// Stream tokens to the client as decode steps complete (chunked
    /// transfer) instead of buffering the full response.
    pub stream: bool,
}

impl Default for InferenceConfig {
    fn default() -> InferenceConfig {
        InferenceConfig {
            enabled: false,
            max_batch: 8,
            queue_depth: 64,
            stream: false,
        }
    }
}

/// Session placement across the nodes of a keygroup (consistent-hash ring,
/// see [`crate::kvstore::HashRing`]).
#[derive(Debug, Clone)]
pub struct ShardingConfig {
    /// Replicas per session (`None` = replicate to every node serving the
    /// model — the paper's two-node testbed behaviour, and the default).
    pub replication_factor: Option<usize>,
    /// Ring points per node; more points smooth the load split.
    pub virtual_nodes: usize,
}

impl Default for ShardingConfig {
    fn default() -> ShardingConfig {
        ShardingConfig {
            replication_factor: None,
            virtual_nodes: 128,
        }
    }
}

/// Per-node configuration.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// Node name (e.g. "edge-m2").
    pub name: String,
    /// Hardware profile.
    pub profile: NodeProfile,
    /// API port (0 = ephemeral).
    pub api_port: u16,
    /// KV replication port (0 = ephemeral).
    pub kv_port: u16,
    /// Models served by this node (keygroups joined).
    pub models: Vec<String>,
}

/// Engine selection.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineKind {
    /// AOT-compiled transformer via PJRT (the real stack).
    Pjrt,
    /// Deterministic mock engine (tests and protocol-only benches).
    Mock {
        /// Emulated per-context-token prefill cost.
        prefill_ns_per_token: u64,
        /// Emulated per-generated-token decode cost.
        decode_ns_per_token: u64,
    },
}

/// Whole-cluster configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Edge nodes.
    pub nodes: Vec<NodeConfig>,
    /// Inter-node link (replication traffic).
    pub peer_link: LinkModel,
    /// Client uplink (client -> edge API traffic).
    pub client_link: LinkModel,
    /// Replication behaviour.
    pub replication: ReplicationConfig,
    /// Session sharding / ring placement.
    pub sharding: ShardingConfig,
    /// Heartbeat failure detection / runtime membership (default off:
    /// topology frozen at launch, exactly the seed behaviour).
    pub membership: MembershipConfig,
    /// Hinted handoff for unreachable peers (active only with
    /// membership enabled).
    pub hints: HintConfig,
    /// Merkle-tree anti-entropy repair (default off: no digest listener,
    /// no background rounds — the seed's wire behaviour).
    pub antientropy: AntiEntropyConfig,
    /// Transport layer: outbound pool idle bound and the per-listener
    /// inbound connection budget (applies to every node's API, KV, and
    /// anti-entropy listeners).
    pub transport: TransportConfig,
    /// Local KV persistence: WAL + snapshot + crash recovery (default
    /// off: the seed's memory-only replica, no files touched). The
    /// configured `dir` is the fleet root; each node persists under
    /// `dir/<node-name>/`.
    pub storage: StorageConfig,
    /// Distributed tracing + leveled events (default off: no spans, no
    /// trace header on the wire — replication/fetch/AE bytes identical
    /// to the seed).
    pub observability: crate::obs::ObservabilityConfig,
    /// Fleet aggregator: poll every node's `/status` + `/metrics` and
    /// append rollup snapshots to a CSV (default off: no poller thread,
    /// no scrape traffic, no files).
    pub fleet: crate::obs::fleet::FleetConfig,
    /// Inference scheduler: admission queue, continuous batching, and
    /// streamed responses (default off: solo `generate` per request,
    /// buffered responses — the seed's wire behaviour).
    pub inference: InferenceConfig,
    /// Turn-counter protocol settings.
    pub consistency: ConsistencyConfig,
    /// Generation settings.
    pub generation: GenerationConfig,
    /// Engine to run.
    pub engine: EngineKind,
    /// Directory with AOT artifacts (tokenizer.json, *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Session TTL in the KV store.
    pub session_ttl: Duration,
}

impl ClusterConfig {
    /// The paper's two-node testbed: one M2-profile node, one TX2-profile
    /// node, LAN peer link, mobile client uplink, PJRT engine.
    pub fn two_node_testbed() -> ClusterConfig {
        ClusterConfig {
            nodes: vec![
                NodeConfig {
                    name: "edge-m2".into(),
                    profile: NodeProfile::m2(),
                    api_port: 0,
                    kv_port: 0,
                    models: vec!["discedge/tiny-chat".into()],
                },
                NodeConfig {
                    name: "edge-tx2".into(),
                    profile: NodeProfile::tx2(),
                    api_port: 0,
                    kv_port: 0,
                    models: vec!["discedge/tiny-chat".into()],
                },
            ],
            peer_link: LinkModel::lan(),
            client_link: LinkModel::mobile_uplink(),
            replication: ReplicationConfig::default(),
            sharding: ShardingConfig::default(),
            membership: MembershipConfig::default(),
            hints: HintConfig::default(),
            antientropy: AntiEntropyConfig::default(),
            transport: TransportConfig::default(),
            storage: StorageConfig::default(),
            observability: crate::obs::ObservabilityConfig::default(),
            fleet: crate::obs::fleet::FleetConfig::default(),
            inference: InferenceConfig::default(),
            consistency: ConsistencyConfig::default(),
            generation: GenerationConfig::default(),
            engine: EngineKind::Pjrt,
            artifacts_dir: default_artifacts_dir(),
            session_ttl: Duration::from_secs(3600),
        }
    }

    /// Single-node config for quick tests (mock engine, ideal links).
    pub fn single_node_mock() -> ClusterConfig {
        let mut cfg = ClusterConfig::two_node_testbed();
        cfg.nodes.truncate(1);
        cfg.peer_link = LinkModel::ideal();
        cfg.client_link = LinkModel::ideal();
        cfg.engine = EngineKind::Mock {
            prefill_ns_per_token: 0,
            decode_ns_per_token: 0,
        };
        cfg
    }

    /// An `n`-node fleet serving one model with the zero-cost mock engine
    /// and ideal links — the scaffold for the sharding tests and the
    /// sharded scaling benches. `replication_factor = None` keeps the
    /// seed's replicate-to-all behaviour.
    pub fn mock_fleet(n: usize, replication_factor: Option<usize>) -> ClusterConfig {
        let mut cfg = ClusterConfig::single_node_mock();
        cfg.nodes = (0..n)
            .map(|i| NodeConfig {
                name: format!("edge-{i}"),
                profile: NodeProfile::m2_native(),
                api_port: 0,
                kv_port: 0,
                models: vec!["discedge/tiny-chat".into()],
            })
            .collect();
        cfg.sharding.replication_factor = replication_factor;
        cfg
    }

    /// Turn on membership with failure-detection knobs tight enough for
    /// tests and failover demos: 15 ms heartbeats, suspect after 2
    /// misses, down after 120 ms — a kill is detected in well under a
    /// second without flapping on scheduler hiccups.
    pub fn enable_fast_membership(&mut self) {
        self.membership.enabled = true;
        self.membership.heartbeat = Duration::from_millis(15);
        self.membership.suspect_after = 2;
        self.membership.down_after = Duration::from_millis(120);
    }

    /// Load from a JSON config file. Unspecified fields keep testbed
    /// defaults.
    pub fn load(path: &Path) -> Result<ClusterConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("read {}: {e}", path.display())))?;
        ClusterConfig::from_json(&text)
    }

    /// Parse from a JSON string.
    pub fn from_json(text: &str) -> Result<ClusterConfig> {
        let v = json::parse(text)?;
        let mut cfg = ClusterConfig::two_node_testbed();
        if let Some(nodes) = v.get("nodes").and_then(|n| n.as_array()) {
            cfg.nodes = nodes
                .iter()
                .map(parse_node)
                .collect::<Result<Vec<NodeConfig>>>()?;
        }
        if let Some(e) = v.get("engine").and_then(|e| e.as_str()) {
            cfg.engine = match e {
                "pjrt" => EngineKind::Pjrt,
                "mock" => EngineKind::Mock {
                    prefill_ns_per_token: 1000,
                    decode_ns_per_token: 100_000,
                },
                other => return Err(Error::Config(format!("unknown engine {other}"))),
            };
        }
        if let Some(d) = v.get("artifacts_dir").and_then(|d| d.as_str()) {
            cfg.artifacts_dir = PathBuf::from(d);
        }
        if let Some(c) = v.get("consistency") {
            if let Some(r) = c.get("retries").and_then(|x| x.as_u64()) {
                cfg.consistency.retries = r as u32;
            }
            if let Some(b) = c.get("backoff_ms").and_then(|x| x.as_u64()) {
                cfg.consistency.backoff = Duration::from_millis(b);
            }
            if let Some(p) = c.get("policy").and_then(|x| x.as_str()) {
                cfg.consistency.policy = ConsistencyPolicy::parse(p)?;
            }
        }
        if let Some(g) = v.get("generation") {
            if let Some(m) = g.get("max_tokens").and_then(|x| x.as_u64()) {
                cfg.generation.max_tokens = m as usize;
            }
            if let Some(t) = g.get("temperature").and_then(|x| x.as_f64()) {
                cfg.generation.temperature = t;
            }
            if let Some(s) = g.get("seed").and_then(|x| x.as_u64()) {
                cfg.generation.seed = s;
            }
        }
        if let Some(r) = v.get("replication") {
            if let Some(d) = r.get("delay_ms").and_then(|x| x.as_u64()) {
                cfg.replication.delay = Duration::from_millis(d);
            }
            if let Some(a) = r.get("max_attempts").and_then(|x| x.as_u64()) {
                cfg.replication.max_attempts = a as u32;
            }
            if let Some(b) = r.get("retry_backoff_ms").and_then(|x| x.as_u64()) {
                cfg.replication.retry_backoff = Duration::from_millis(b);
            }
            if let Some(ds) = r.get("delta_sync").and_then(|x| x.as_bool()) {
                cfg.replication.delta_sync = ds;
            }
        }
        if let Some(s) = v.get("sharding") {
            if let Some(rf) = s.get("replication_factor").and_then(|x| x.as_u64()) {
                cfg.sharding.replication_factor = Some(rf as usize);
            }
            if let Some(vn) = s.get("virtual_nodes").and_then(|x| x.as_u64()) {
                cfg.sharding.virtual_nodes = vn as usize;
            }
        }
        if let Some(m) = v.get("membership") {
            if let Some(e) = m.get("enabled").and_then(|x| x.as_bool()) {
                cfg.membership.enabled = e;
            }
            if let Some(h) = m.get("heartbeat_ms").and_then(|x| x.as_u64()) {
                cfg.membership.heartbeat = Duration::from_millis(h);
            }
            if let Some(s) = m.get("suspect_after").and_then(|x| x.as_u64()) {
                cfg.membership.suspect_after = s as u32;
            }
            if let Some(d) = m.get("down_after_ms").and_then(|x| x.as_u64()) {
                cfg.membership.down_after = Duration::from_millis(d);
            }
        }
        if let Some(h) = v.get("hints") {
            if let Some(n) = h.get("max_per_peer").and_then(|x| x.as_u64()) {
                cfg.hints.max_per_peer = n as usize;
            }
        }
        if let Some(a) = v.get("antientropy") {
            if let Some(e) = a.get("enabled").and_then(|x| x.as_bool()) {
                cfg.antientropy.enabled = e;
            }
            if let Some(ms) = a.get("interval_ms").and_then(|x| x.as_u64()) {
                cfg.antientropy.interval = Duration::from_millis(ms);
            }
            if let Some(f) = a.get("fanout").and_then(|x| x.as_u64()) {
                cfg.antientropy.fanout = f as usize;
            }
            if let Some(k) = a.get("max_keys_per_round").and_then(|x| x.as_u64()) {
                cfg.antientropy.max_keys_per_round = k as usize;
            }
        }
        if let Some(s) = v.get("storage") {
            if let Some(e) = s.get("enabled").and_then(|x| x.as_bool()) {
                cfg.storage.enabled = e;
            }
            if let Some(d) = s.get("dir").and_then(|x| x.as_str()) {
                cfg.storage.dir = PathBuf::from(d);
            }
            if let Some(n) = s.get("snapshot_every").and_then(|x| x.as_u64()) {
                cfg.storage.snapshot_every = n;
            }
            if let Some(f) = s.get("fsync").and_then(|x| x.as_bool()) {
                cfg.storage.fsync = f;
            }
        }
        if let Some(o) = v.get("observability") {
            if let Some(e) = o.get("enabled").and_then(|x| x.as_bool()) {
                cfg.observability.enabled = e;
            }
            if let Some(n) = o.get("trace_buffer").and_then(|x| x.as_u64()) {
                cfg.observability.trace_buffer = n as usize;
            }
            if let Some(l) = o.get("level").and_then(|x| x.as_str()) {
                cfg.observability.level = l.to_string();
            }
            if let Some(w) = o.get("window_ms").and_then(|x| x.as_u64()) {
                cfg.observability.window_ms = w;
            }
        }
        if let Some(f) = v.get("fleet") {
            if let Some(e) = f.get("enabled").and_then(|x| x.as_bool()) {
                cfg.fleet.enabled = e;
            }
            if let Some(p) = f.get("poll_ms").and_then(|x| x.as_u64()) {
                cfg.fleet.poll_ms = p;
            }
            if let Some(o) = f.get("out").and_then(|x| x.as_str()) {
                cfg.fleet.out = PathBuf::from(o);
            }
        }
        if let Some(i) = v.get("inference") {
            if let Some(e) = i.get("enabled").and_then(|x| x.as_bool()) {
                cfg.inference.enabled = e;
            }
            if let Some(b) = i.get("max_batch").and_then(|x| x.as_u64()) {
                cfg.inference.max_batch = b as usize;
            }
            if let Some(q) = i.get("queue_depth").and_then(|x| x.as_u64()) {
                cfg.inference.queue_depth = q as usize;
            }
            if let Some(s) = i.get("stream").and_then(|x| x.as_bool()) {
                cfg.inference.stream = s;
            }
        }
        if let Some(t) = v.get("transport") {
            if let Some(n) = t.get("max_server_conns").and_then(|x| x.as_u64()) {
                cfg.transport.max_server_conns = n as usize;
            }
            if let Some(ms) = t.get("idle_timeout_ms").and_then(|x| x.as_u64()) {
                cfg.transport.idle_timeout = Duration::from_millis(ms);
            }
            if let Some(n) = t.get("max_idle_per_peer").and_then(|x| x.as_u64()) {
                cfg.transport.max_idle_per_peer = n as usize;
            }
        }
        if let Some(t) = v.get("session_ttl_s").and_then(|x| x.as_u64()) {
            cfg.session_ttl = Duration::from_secs(t);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check the configuration.
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            return Err(Error::Config("no nodes configured".into()));
        }
        let mut names: Vec<&str> = self.nodes.iter().map(|n| n.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != self.nodes.len() {
            return Err(Error::Config("duplicate node names".into()));
        }
        for n in &self.nodes {
            if n.models.is_empty() {
                return Err(Error::Config(format!("node {} serves no models", n.name)));
            }
        }
        if self.sharding.replication_factor == Some(0) {
            return Err(Error::Config("replication_factor must be >= 1".into()));
        }
        if self.sharding.virtual_nodes == 0 {
            return Err(Error::Config("virtual_nodes must be >= 1".into()));
        }
        if self.membership.enabled {
            if self.membership.heartbeat.is_zero() {
                return Err(Error::Config("membership.heartbeat_ms must be >= 1".into()));
            }
            if self.membership.suspect_after == 0 {
                return Err(Error::Config("membership.suspect_after must be >= 1".into()));
            }
        }
        if self.hints.max_per_peer == 0 {
            return Err(Error::Config("hints.max_per_peer must be >= 1".into()));
        }
        if self.transport.max_server_conns == 0 {
            return Err(Error::Config("transport.max_server_conns must be >= 1".into()));
        }
        if self.transport.idle_timeout.is_zero() {
            return Err(Error::Config("transport.idle_timeout_ms must be >= 1".into()));
        }
        if self.antientropy.enabled {
            if self.antientropy.interval.is_zero() {
                return Err(Error::Config("antientropy.interval_ms must be >= 1".into()));
            }
            if self.antientropy.fanout < 2 {
                return Err(Error::Config("antientropy.fanout must be >= 2".into()));
            }
            if self.antientropy.max_keys_per_round == 0 {
                return Err(Error::Config(
                    "antientropy.max_keys_per_round must be >= 1".into(),
                ));
            }
        }
        if self.storage.enabled {
            if self.storage.dir.as_os_str().is_empty() {
                return Err(Error::Config("storage.dir must be set".into()));
            }
            if self.storage.snapshot_every == 0 {
                return Err(Error::Config("storage.snapshot_every must be >= 1".into()));
            }
        }
        if self.observability.enabled {
            if self.observability.trace_buffer == 0 {
                return Err(Error::Config(
                    "observability.trace_buffer must be >= 1".into(),
                ));
            }
            if crate::obs::LevelFilter::parse(&self.observability.level).is_none() {
                return Err(Error::Config(format!(
                    "observability.level {:?} is not a valid level spec",
                    self.observability.level
                )));
            }
        }
        if self.fleet.enabled {
            if self.fleet.poll_ms == 0 {
                return Err(Error::Config("fleet.poll_ms must be >= 1".into()));
            }
            if self.fleet.out.as_os_str().is_empty() {
                return Err(Error::Config("fleet.out must be set".into()));
            }
        }
        if self.inference.enabled {
            if self.inference.max_batch == 0 {
                return Err(Error::Config("inference.max_batch must be >= 1".into()));
            }
            if self.inference.queue_depth == 0 {
                return Err(Error::Config("inference.queue_depth must be >= 1".into()));
            }
        }
        Ok(())
    }
}

fn parse_node(v: &Value) -> Result<NodeConfig> {
    let name = v.req_str("name")?;
    let profile_name = v.req_str("profile")?;
    let profile = NodeProfile::by_name(&profile_name)
        .ok_or_else(|| Error::Config(format!("unknown profile {profile_name}")))?;
    let models = match v.get("models").and_then(|m| m.as_array()) {
        Some(ms) => ms
            .iter()
            .map(|m| {
                m.as_str()
                    .map(|s| s.to_string())
                    .ok_or_else(|| Error::Config("model name must be a string".into()))
            })
            .collect::<Result<Vec<String>>>()?,
        None => vec!["discedge/tiny-chat".into()],
    };
    Ok(NodeConfig {
        name,
        profile,
        api_port: v.get("api_port").and_then(|p| p.as_u64()).unwrap_or(0) as u16,
        kv_port: v.get("kv_port").and_then(|p| p.as_u64()).unwrap_or(0) as u16,
        models,
    })
}

/// Default artifacts directory: `$DISCEDGE_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("DISCEDGE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testbed_defaults() {
        let cfg = ClusterConfig::two_node_testbed();
        assert_eq!(cfg.nodes.len(), 2);
        assert_eq!(cfg.consistency.retries, 3);
        assert_eq!(cfg.consistency.backoff, Duration::from_millis(10));
        assert_eq!(cfg.generation.max_tokens, 128);
        assert_eq!(cfg.generation.seed, 123);
        cfg.validate().unwrap();
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(ContextMode::parse("raw").unwrap(), ContextMode::Raw);
        assert_eq!(
            ContextMode::parse("tokenized").unwrap(),
            ContextMode::Tokenized
        );
        assert_eq!(
            ContextMode::parse("client_side").unwrap(),
            ContextMode::ClientSide
        );
        assert!(ContextMode::parse("nope").is_err());
        assert_eq!(ContextMode::Tokenized.as_str(), "tokenized");
    }

    #[test]
    fn json_config_roundtrip() {
        let cfg = ClusterConfig::from_json(
            r#"{
              "nodes": [
                {"name": "a", "profile": "m2", "models": ["m"]},
                {"name": "b", "profile": "tx2", "models": ["m"]}
              ],
              "engine": "mock",
              "consistency": {"retries": 5, "backoff_ms": 20, "policy": "available"},
              "generation": {"max_tokens": 64},
              "replication": {"delay_ms": 15, "max_attempts": 7,
                              "retry_backoff_ms": 9, "delta_sync": true}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.nodes[1].profile.name, "tx2");
        assert_eq!(cfg.consistency.retries, 5);
        assert_eq!(cfg.consistency.policy, ConsistencyPolicy::Available);
        assert_eq!(cfg.generation.max_tokens, 64);
        assert_eq!(cfg.replication.delay, Duration::from_millis(15));
        assert_eq!(cfg.replication.max_attempts, 7);
        assert_eq!(cfg.replication.retry_backoff, Duration::from_millis(9));
        assert!(cfg.replication.delta_sync);
        assert!(matches!(cfg.engine, EngineKind::Mock { .. }));
    }

    #[test]
    fn delta_sync_defaults_off() {
        // The seed wire format must stay the default.
        assert!(!ClusterConfig::two_node_testbed().replication.delta_sync);
        let cfg = ClusterConfig::from_json(r#"{"engine": "mock"}"#).unwrap();
        assert!(!cfg.replication.delta_sync);
    }

    #[test]
    fn sharding_config_parses_and_defaults() {
        // Default: replicate-to-all, exactly the seed behaviour.
        let cfg = ClusterConfig::two_node_testbed();
        assert_eq!(cfg.sharding.replication_factor, None);
        assert_eq!(cfg.sharding.virtual_nodes, 128);
        let cfg = ClusterConfig::from_json(
            r#"{
              "nodes": [
                {"name": "a", "profile": "m2", "models": ["m"]},
                {"name": "b", "profile": "tx2", "models": ["m"]}
              ],
              "engine": "mock",
              "sharding": {"replication_factor": 2, "virtual_nodes": 64}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.sharding.replication_factor, Some(2));
        assert_eq!(cfg.sharding.virtual_nodes, 64);
        assert!(ClusterConfig::from_json(
            r#"{"engine": "mock", "sharding": {"replication_factor": 0}}"#
        )
        .is_err());
    }

    #[test]
    fn membership_defaults_off_and_parses() {
        // The seed's frozen topology must stay the default.
        let cfg = ClusterConfig::two_node_testbed();
        assert!(!cfg.membership.enabled);
        assert_eq!(cfg.membership.heartbeat, Duration::from_millis(100));
        assert_eq!(cfg.membership.suspect_after, 3);
        assert_eq!(cfg.membership.down_after, Duration::from_millis(1000));
        assert_eq!(cfg.hints.max_per_peer, 512);
        let cfg = ClusterConfig::from_json(
            r#"{
              "engine": "mock",
              "membership": {"enabled": true, "heartbeat_ms": 25,
                             "suspect_after": 2, "down_after_ms": 150},
              "hints": {"max_per_peer": 64}
            }"#,
        )
        .unwrap();
        assert!(cfg.membership.enabled);
        assert_eq!(cfg.membership.heartbeat, Duration::from_millis(25));
        assert_eq!(cfg.membership.suspect_after, 2);
        assert_eq!(cfg.membership.down_after, Duration::from_millis(150));
        assert_eq!(cfg.hints.max_per_peer, 64);
        // Degenerate knobs are rejected.
        assert!(ClusterConfig::from_json(
            r#"{"engine": "mock", "membership": {"enabled": true, "heartbeat_ms": 0}}"#
        )
        .is_err());
        assert!(ClusterConfig::from_json(
            r#"{"engine": "mock", "membership": {"enabled": true, "suspect_after": 0}}"#
        )
        .is_err());
        assert!(
            ClusterConfig::from_json(r#"{"engine": "mock", "hints": {"max_per_peer": 0}}"#)
                .is_err()
        );
    }

    #[test]
    fn antientropy_defaults_off_and_parses() {
        // The seed's wire behaviour (no digest listener) must stay the
        // default.
        let cfg = ClusterConfig::two_node_testbed();
        assert!(!cfg.antientropy.enabled);
        assert_eq!(cfg.antientropy.interval, Duration::from_millis(1000));
        assert_eq!(cfg.antientropy.fanout, 16);
        assert_eq!(cfg.antientropy.max_keys_per_round, 256);
        let cfg = ClusterConfig::from_json(
            r#"{
              "engine": "mock",
              "antientropy": {"enabled": true, "interval_ms": 250,
                              "fanout": 8, "max_keys_per_round": 32}
            }"#,
        )
        .unwrap();
        assert!(cfg.antientropy.enabled);
        assert_eq!(cfg.antientropy.interval, Duration::from_millis(250));
        assert_eq!(cfg.antientropy.fanout, 8);
        assert_eq!(cfg.antientropy.max_keys_per_round, 32);
        // Degenerate knobs are rejected.
        for bad in [
            r#"{"engine": "mock", "antientropy": {"enabled": true, "interval_ms": 0}}"#,
            r#"{"engine": "mock", "antientropy": {"enabled": true, "fanout": 1}}"#,
            r#"{"engine": "mock", "antientropy": {"enabled": true, "max_keys_per_round": 0}}"#,
        ] {
            assert!(ClusterConfig::from_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn observability_defaults_off_and_parses() {
        // The seed wire format (no trace header) must stay the default.
        let cfg = ClusterConfig::two_node_testbed();
        assert!(!cfg.observability.enabled);
        assert_eq!(cfg.observability.trace_buffer, 1024);
        assert_eq!(cfg.observability.level, "info");
        assert_eq!(cfg.observability.window_ms, 0, "windowed metrics default off");
        let cfg = ClusterConfig::from_json(
            r#"{
              "engine": "mock",
              "observability": {"enabled": true, "trace_buffer": 64,
                                "level": "warn,ae=debug", "window_ms": 250}
            }"#,
        )
        .unwrap();
        assert!(cfg.observability.enabled);
        assert_eq!(cfg.observability.trace_buffer, 64);
        assert_eq!(cfg.observability.level, "warn,ae=debug");
        assert_eq!(cfg.observability.window_ms, 250);
        // Degenerate knobs are rejected (only once enabled).
        for bad in [
            r#"{"engine": "mock", "observability": {"enabled": true, "trace_buffer": 0}}"#,
            r#"{"engine": "mock", "observability": {"enabled": true, "level": "loud"}}"#,
        ] {
            assert!(ClusterConfig::from_json(bad).is_err(), "{bad}");
        }
        assert!(
            ClusterConfig::from_json(r#"{"engine": "mock", "observability": {"level": "loud"}}"#)
                .is_ok(),
            "degenerate knobs are inert while observability is off"
        );
    }

    #[test]
    fn fleet_defaults_off_and_parses() {
        // No poller thread, no scrape traffic by default.
        let cfg = ClusterConfig::two_node_testbed();
        assert!(!cfg.fleet.enabled);
        assert_eq!(cfg.fleet.poll_ms, 1000);
        assert_eq!(cfg.fleet.out, PathBuf::from("results/fleet_health.csv"));
        let cfg = ClusterConfig::from_json(
            r#"{
              "engine": "mock",
              "fleet": {"enabled": true, "poll_ms": 200,
                        "out": "/tmp/fh.csv"}
            }"#,
        )
        .unwrap();
        assert!(cfg.fleet.enabled);
        assert_eq!(cfg.fleet.poll_ms, 200);
        assert_eq!(cfg.fleet.out, PathBuf::from("/tmp/fh.csv"));
        // Degenerate knobs are rejected (only once enabled).
        for bad in [
            r#"{"engine": "mock", "fleet": {"enabled": true, "poll_ms": 0}}"#,
            r#"{"engine": "mock", "fleet": {"enabled": true, "out": ""}}"#,
        ] {
            assert!(ClusterConfig::from_json(bad).is_err(), "{bad}");
        }
        assert!(
            ClusterConfig::from_json(r#"{"engine": "mock", "fleet": {"poll_ms": 0}}"#).is_ok(),
            "degenerate knobs are inert while the aggregator is off"
        );
    }

    #[test]
    fn inference_defaults_off_and_parses() {
        // The seed's serving path (solo generate, buffered responses)
        // must stay the default.
        let cfg = ClusterConfig::two_node_testbed();
        assert!(!cfg.inference.enabled);
        assert_eq!(cfg.inference.max_batch, 8);
        assert_eq!(cfg.inference.queue_depth, 64);
        assert!(!cfg.inference.stream);
        let cfg = ClusterConfig::from_json(
            r#"{
              "engine": "mock",
              "inference": {"enabled": true, "max_batch": 16,
                            "queue_depth": 256, "stream": true}
            }"#,
        )
        .unwrap();
        assert!(cfg.inference.enabled);
        assert_eq!(cfg.inference.max_batch, 16);
        assert_eq!(cfg.inference.queue_depth, 256);
        assert!(cfg.inference.stream);
        // Degenerate knobs are rejected (only once enabled).
        for bad in [
            r#"{"engine": "mock", "inference": {"enabled": true, "max_batch": 0}}"#,
            r#"{"engine": "mock", "inference": {"enabled": true, "queue_depth": 0}}"#,
        ] {
            assert!(ClusterConfig::from_json(bad).is_err(), "{bad}");
        }
        assert!(
            ClusterConfig::from_json(r#"{"engine": "mock", "inference": {"max_batch": 0}}"#)
                .is_ok(),
            "degenerate knobs are inert while the scheduler is off"
        );
    }

    #[test]
    fn storage_defaults_off_and_parses() {
        // The seed's memory-only replica must stay the default: no WAL,
        // no snapshot, no files.
        let cfg = ClusterConfig::two_node_testbed();
        assert!(!cfg.storage.enabled);
        assert_eq!(cfg.storage.snapshot_every, 4096);
        assert!(!cfg.storage.fsync);
        let cfg = ClusterConfig::from_json(
            r#"{
              "engine": "mock",
              "storage": {"enabled": true, "dir": "/tmp/discedge-t",
                          "snapshot_every": 128, "fsync": true}
            }"#,
        )
        .unwrap();
        assert!(cfg.storage.enabled);
        assert_eq!(cfg.storage.dir, PathBuf::from("/tmp/discedge-t"));
        assert_eq!(cfg.storage.snapshot_every, 128);
        assert!(cfg.storage.fsync);
        // Degenerate knobs are rejected (only once enabled).
        for bad in [
            r#"{"engine": "mock", "storage": {"enabled": true, "dir": ""}}"#,
            r#"{"engine": "mock", "storage": {"enabled": true, "snapshot_every": 0}}"#,
        ] {
            assert!(ClusterConfig::from_json(bad).is_err(), "{bad}");
        }
        assert!(
            ClusterConfig::from_json(r#"{"engine": "mock", "storage": {"snapshot_every": 0}}"#)
                .is_ok(),
            "degenerate knobs are inert while storage is off"
        );
    }

    #[test]
    fn transport_defaults_and_parses() {
        // Defaults: bounded listener, pooling on.
        let cfg = ClusterConfig::two_node_testbed();
        assert_eq!(cfg.transport.max_server_conns, 256);
        assert_eq!(cfg.transport.idle_timeout, Duration::from_secs(60));
        assert_eq!(cfg.transport.max_idle_per_peer, 4);
        let cfg = ClusterConfig::from_json(
            r#"{
              "engine": "mock",
              "transport": {"max_server_conns": 32, "idle_timeout_ms": 500,
                            "max_idle_per_peer": 0}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.transport.max_server_conns, 32);
        assert_eq!(cfg.transport.idle_timeout, Duration::from_millis(500));
        // 0 is legal: it means connect-per-request (the ablation baseline).
        assert_eq!(cfg.transport.max_idle_per_peer, 0);
        // Degenerate knobs are rejected.
        for bad in [
            r#"{"engine": "mock", "transport": {"max_server_conns": 0}}"#,
            r#"{"engine": "mock", "transport": {"idle_timeout_ms": 0}}"#,
        ] {
            assert!(ClusterConfig::from_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn fast_membership_helper_enables_detection() {
        let mut cfg = ClusterConfig::mock_fleet(3, Some(2));
        cfg.enable_fast_membership();
        assert!(cfg.membership.enabled);
        assert!(cfg.membership.heartbeat < Duration::from_millis(100));
        cfg.validate().unwrap();
    }

    #[test]
    fn mock_fleet_builds_n_nodes() {
        let cfg = ClusterConfig::mock_fleet(6, Some(2));
        assert_eq!(cfg.nodes.len(), 6);
        assert_eq!(cfg.sharding.replication_factor, Some(2));
        cfg.validate().unwrap();
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ClusterConfig::from_json(r#"{"nodes": []}"#).is_err());
        assert!(ClusterConfig::from_json(
            r#"{"nodes": [{"name":"a","profile":"warp9"}]}"#
        )
        .is_err());
        assert!(ClusterConfig::from_json(
            r#"{"nodes": [{"name":"a","profile":"m2"},{"name":"a","profile":"m2"}]}"#
        )
        .is_err());
        assert!(ClusterConfig::from_json(r#"{"engine": "quantum"}"#).is_err());
    }
}
