//! Simulated hardware profiles (paper Table 1 substitute).
//!
//! The paper's testbed pairs a fast edge node (Apple M2, hardware-
//! accelerated llama.cpp) with a slow one (Jetson TX2); the client is a
//! Raspberry Pi 4. We run every node on the same host, so device
//! heterogeneity is emulated per work type:
//!
//! **Inference** uses *measured-work scaling*: the node measures how long
//! the real PJRT execution took and deterministically extends it to
//! `inference_scale ×` that duration (TX2 ≈ 6× the M2, the ratio the
//! paper observed for identical input/output). Extending measured work
//! preserves the real shape — inference cost keeps growing with context
//! length exactly as the XLA executables do.
//!
//! **Text processing (tokenization)** uses an *emulated throughput*
//! model: processing `n` bytes costs `n / tokenizer_kBps` seconds
//! (the real Rust-BPE work runs first; the remainder is slept). A
//! throughput model is used instead of work scaling because our
//! from-scratch BPE is orders of magnitude faster relative to our
//! model's inference (~110 MB/s) than llama.cpp's raw-text path is
//! relative to llama.cpp inference — and because wall-clock work scaling
//! is noisy on a single-core host. Calibration:
//!
//! - `m2`: 90 kB/s (request path), 600 kB/s (async update) — puts full-history re-tokenization at ≈ 9 % of the
//!   response time at the median turn, the share implied by the paper's
//!   8.75 % median speedup; the async fragment update lands ≈ 1–3 ms
//!   (paper: < 1 ms).
//! - `tx2`: 5 kB/s (request path), 15 kB/s (async update) — ≈ 17 % share (paper: 14.46 % median speedup) on
//!   6×-slower inference; the async update lands at 4–50 ms, exactly the
//!   range the paper reports for the TX2.
//!
//! `m2_native` / `tx2_native` disable the throughput model (the
//! honest-ratio ablation A5: with our tokenizer, re-tokenization is
//! nearly free and the paper's gap all but vanishes).

use std::time::{Duration, Instant};

/// A simulated device class.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeProfile {
    /// Profile name (e.g. "m2").
    pub name: String,
    /// Emulated text-processing throughput in kilobytes/second for the
    /// *request path* (`None` = native Rust-BPE speed).
    pub tokenizer_kbps: Option<f64>,
    /// Emulated throughput for the *asynchronous* context update,
    /// calibrated separately to the paper's direct measurement of that
    /// step (< 1 ms on M2, 4–50 ms on TX2; §4.2.1). The request path and
    /// the async path are measured quantities of their own in the paper
    /// and are not consistent with a single throughput (the raw-mode
    /// penalty includes more than tokenization).
    pub update_kbps: Option<f64>,
    /// Multiplier on inference CPU time.
    pub inference_scale: f64,
    /// Paper hardware this profile stands in for.
    pub emulates: String,
}

impl NodeProfile {
    /// Apple Mac M2 edge node (Table 1): the fast node.
    pub fn m2() -> NodeProfile {
        NodeProfile {
            name: "m2".into(),
            tokenizer_kbps: Some(90.0),
            update_kbps: Some(600.0),
            inference_scale: 1.0,
            emulates: "Apple Mac M2, 8-core CPU (4P+4E), 16GB unified, 8-core GPU".into(),
        }
    }

    /// Nvidia Jetson TX2 edge node (Table 1): older hardware, no
    /// llama.cpp acceleration — much slower on both text and inference.
    pub fn tx2() -> NodeProfile {
        NodeProfile {
            name: "tx2".into(),
            tokenizer_kbps: Some(5.0),
            update_kbps: Some(15.0),
            inference_scale: 6.0,
            emulates: "Nvidia Jetson TX2, ARM Cortex-A57 4-core, 8GB unified, Pascal GPU".into(),
        }
    }

    /// M2 with the *native* tokenizer — honest-ratio ablation (A5).
    pub fn m2_native() -> NodeProfile {
        NodeProfile {
            name: "m2_native".into(),
            tokenizer_kbps: None,
            update_kbps: None,
            inference_scale: 1.0,
            emulates: "M2 profile, native Rust-BPE speed".into(),
        }
    }

    /// TX2 with the native tokenizer (hardware inference ratio only).
    pub fn tx2_native() -> NodeProfile {
        NodeProfile {
            name: "tx2_native".into(),
            tokenizer_kbps: None,
            update_kbps: None,
            inference_scale: 6.0,
            emulates: "TX2 profile, native Rust-BPE speed".into(),
        }
    }

    /// Raspberry Pi 4 client device (Table 1). Clients never tokenize or
    /// infer in DisCEdge; the profile exists for Table-1 completeness and
    /// client-side-compute extensions.
    pub fn rpi4() -> NodeProfile {
        NodeProfile {
            name: "rpi4".into(),
            tokenizer_kbps: Some(40.0),
            update_kbps: Some(40.0),
            inference_scale: f64::INFINITY,
            emulates: "Raspberry Pi 4, ARM Cortex-A72 4-core, 4GB RAM".into(),
        }
    }

    /// Look up a built-in profile by name.
    pub fn by_name(name: &str) -> Option<NodeProfile> {
        match name {
            "m2" => Some(NodeProfile::m2()),
            "tx2" => Some(NodeProfile::tx2()),
            "m2_native" => Some(NodeProfile::m2_native()),
            "tx2_native" => Some(NodeProfile::tx2_native()),
            "rpi4" => Some(NodeProfile::rpi4()),
            _ => None,
        }
    }

    /// Run `f`, then extend its wall time to `scale ×` the measured
    /// duration. Returns `f`'s output.
    pub fn run_scaled<T>(scale: f64, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        if scale > 1.0 {
            let real = start.elapsed();
            let extra = real.mul_f64(scale - 1.0);
            precise_sleep(extra);
        }
        out
    }

    /// Run request-path text processing over `bytes` input bytes under
    /// this profile: the real work runs first, then the wall time is
    /// extended to `bytes / tokenizer_kbps` (deterministic emulated
    /// throughput).
    pub fn tokenize_emulated<T>(&self, bytes: usize, f: impl FnOnce() -> T) -> T {
        Self::throughput_emulated(self.tokenizer_kbps, bytes, f)
    }

    /// Run async-update text processing under this profile.
    pub fn update_tokenize_emulated<T>(&self, bytes: usize, f: impl FnOnce() -> T) -> T {
        Self::throughput_emulated(self.update_kbps, bytes, f)
    }

    fn throughput_emulated<T>(kbps: Option<f64>, bytes: usize, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        if let Some(kbps) = kbps {
            let target = Duration::from_secs_f64(bytes as f64 / (kbps * 1000.0));
            let real = start.elapsed();
            if target > real {
                precise_sleep(target - real);
            }
        }
        out
    }

    /// Run inference work under this profile.
    pub fn infer_scaled<T>(&self, f: impl FnOnce() -> T) -> T {
        Self::run_scaled(self.inference_scale, f)
    }

    /// Extend wall time for inference work whose *CPU* cost was measured
    /// externally (the engine reports process-CPU seconds; sleeping
    /// `(scale-1) × measured` here is insensitive to scheduler noise,
    /// unlike wrapping the call in [`NodeProfile::run_scaled`]).
    pub fn extend_inference(&self, engine_cpu_s: f64) {
        if self.inference_scale > 1.0 && engine_cpu_s > 0.0 {
            precise_sleep(Duration::from_secs_f64(
                engine_cpu_s * (self.inference_scale - 1.0),
            ));
        }
    }

    /// The engine cost as perceived on this device class.
    pub fn scaled_inference_s(&self, engine_cpu_s: f64) -> f64 {
        engine_cpu_s * self.inference_scale.max(1.0)
    }

    /// Markdown rendering of the built-in profile table (Table 1 analog).
    pub fn table_markdown() -> String {
        let mut out = String::from(
            "| Profile | Emulates | Text throughput | Inference scale |\n|---|---|---|---|\n",
        );
        for p in [
            NodeProfile::m2(),
            NodeProfile::tx2(),
            NodeProfile::m2_native(),
            NodeProfile::tx2_native(),
            NodeProfile::rpi4(),
        ] {
            out.push_str(&format!(
                "| {} | {} | {} | {} |\n",
                p.name,
                p.emulates,
                match p.tokenizer_kbps {
                    Some(k) => format!("{k} kB/s"),
                    None => "native".into(),
                },
                if p.inference_scale.is_finite() {
                    format!("{}x", p.inference_scale)
                } else {
                    "n/a".into()
                }
            ));
        }
        out
    }
}

/// Sleep `d` with sub-millisecond accuracy: OS sleep for the bulk, then a
/// short spin for the tail (plain `thread::sleep` over-shoots by up to a
/// scheduler quantum, which would distort emulated costs).
fn precise_sleep(d: Duration) {
    if d.is_zero() {
        return;
    }
    let start = Instant::now();
    if d > Duration::from_micros(500) {
        std::thread::sleep(d - Duration::from_micros(300));
    }
    while start.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_profiles() {
        assert_eq!(NodeProfile::by_name("m2").unwrap(), NodeProfile::m2());
        assert_eq!(NodeProfile::by_name("tx2").unwrap().inference_scale, 6.0);
        assert_eq!(
            NodeProfile::by_name("tx2_native").unwrap().tokenizer_kbps,
            None
        );
        assert!(NodeProfile::by_name("nope").is_none());
    }

    #[test]
    fn scaling_extends_duration() {
        // Real work of ~2 ms scaled 3x should take >= ~6 ms.
        let start = Instant::now();
        NodeProfile::run_scaled(3.0, || {
            let t = Instant::now();
            while t.elapsed() < Duration::from_millis(2) {
                std::hint::spin_loop();
            }
        });
        let total = start.elapsed();
        assert!(total >= Duration::from_millis(5), "total {total:?}");
        assert!(total < Duration::from_millis(60), "total {total:?}");
    }

    #[test]
    fn scale_one_adds_nothing() {
        let start = Instant::now();
        NodeProfile::run_scaled(1.0, || {});
        assert!(start.elapsed() < Duration::from_millis(2));
    }

    #[test]
    fn emulated_throughput_is_deterministic() {
        // 1 KB at 100 kB/s = 10 ms regardless of how fast f runs.
        let p = NodeProfile {
            name: "t".into(),
            tokenizer_kbps: Some(100.0),
            update_kbps: Some(100.0),
            inference_scale: 1.0,
            emulates: String::new(),
        };
        let start = Instant::now();
        p.tokenize_emulated(1000, || {});
        let took = start.elapsed();
        assert!(took >= Duration::from_millis(10), "{took:?}");
        assert!(took < Duration::from_millis(25), "{took:?}");
    }

    #[test]
    fn native_profile_adds_nothing() {
        let p = NodeProfile::m2_native();
        let start = Instant::now();
        p.tokenize_emulated(1_000_000, || {});
        assert!(start.elapsed() < Duration::from_millis(2));
    }

    #[test]
    fn returns_inner_value() {
        let v = NodeProfile::m2_native().tokenize_emulated(10, || 42);
        assert_eq!(v, 42);
    }

    #[test]
    fn table_renders() {
        let t = NodeProfile::table_markdown();
        assert!(t.contains("Jetson TX2"));
        assert!(t.contains("Raspberry Pi 4"));
        assert!(t.contains("90 kB/s"));
    }
}
