//! LLM client (paper §3.4): same request format as a centralized service
//! plus the DisCEdge extensions (ids, turn counter), with mobility
//! policies for the roaming experiments.
//!
//! In `client_side` mode the client keeps the full conversation history and
//! ships it with every request — the baseline of §4.2.2. In the edge-side
//! modes it only tracks ids + turn counter. Per-turn request/response byte
//! counts come from the per-endpoint pool meter (Fig 7).
//!
//! Connections ride one [`PeerPool`] per endpoint: keep-alive reuse
//! across turns, with a stale cached socket (a node restarted, or the
//! server reaped the idle connection) surfacing as at most one failed
//! turn before being discarded — the caller's retry reconnects, so a
//! single broken socket can no longer wedge an endpoint forever. The
//! pool's transparent re-send stays off here: `/completion` is not
//! replay-safe (a duplicate of a committed turn trips the turn-counter
//! guard), so the retry decision belongs to the caller, who owns the
//! turn counter.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Instant;

use crate::config::ContextMode;
use crate::context::{CompletionRequest, CompletionResponse};
use crate::http::Request;
use crate::llm::Message;
use crate::netsim::{LinkModel, TrafficMeter};
use crate::transport::{NetStats, PeerPool, TransportConfig};
use crate::{Error, Result};

/// Which node serves which turn (paper §4.2.2 mobility).
#[derive(Debug, Clone)]
pub enum MobilityPolicy {
    /// Always the same node index.
    Sticky(usize),
    /// Switch to the next node every `every` turns over `nodes` — the
    /// paper's scenario is `alternate(2)` over two nodes: switches happen
    /// on turns 3, 5, 7 (after two turns, then every other turn...).
    Alternate {
        /// Node indices to cycle through.
        nodes: Vec<usize>,
        /// Turns spent on a node before moving on.
        every: u32,
    },
    /// Explicit node index per turn (1-based turn -> index into vec).
    Schedule(Vec<usize>),
}

impl MobilityPolicy {
    /// The paper's mobile scenario: two nodes, switch after every 2 turns
    /// until turn 7 (switch turns 3, 5, 7).
    pub fn paper_alternate() -> MobilityPolicy {
        // Turn:   1 2 3 4 5 6 7 8 9
        // Node:   0 0 1 1 0 0 1 1 1   (switches at 3, 5, 7)
        MobilityPolicy::Schedule(vec![0, 0, 1, 1, 0, 0, 1, 1, 1])
    }

    /// Node index for a 1-based turn.
    pub fn node_for_turn(&self, turn: u64) -> usize {
        match self {
            MobilityPolicy::Sticky(i) => *i,
            MobilityPolicy::Alternate { nodes, every } => {
                let hop = ((turn - 1) / *every as u64) as usize;
                nodes[hop % nodes.len()]
            }
            MobilityPolicy::Schedule(s) => {
                let idx = (turn as usize - 1).min(s.len().saturating_sub(1));
                s[idx]
            }
        }
    }
}

/// Result of one client turn, including wire-level accounting.
#[derive(Debug, Clone)]
pub struct TurnResult {
    /// Server response.
    pub response: CompletionResponse,
    /// End-to-end client-observed seconds.
    pub e2e_s: f64,
    /// Seconds from finishing the request write to the first response
    /// byte. Against a streaming server the response head is only sent
    /// once the first token exists, so this is the client-observed
    /// time-to-first-token; against a buffered server head and body
    /// arrive together and this converges to `e2e_s`.
    pub ttft_s: f64,
    /// Request bytes on the wire (HTTP head + body).
    pub request_bytes: u64,
    /// Response bytes on the wire.
    pub response_bytes: u64,
    /// Node name that served the turn.
    pub node: String,
}

/// A chat client with a turn counter and optional client-side history.
pub struct Client {
    endpoints: Vec<(String, SocketAddr)>,
    policy: MobilityPolicy,
    link: LinkModel,
    /// One keep-alive pool per endpoint index, each with its own meter
    /// so per-node byte accounting survives mobility switches.
    pools: HashMap<usize, PeerPool>,
    transport: TransportConfig,
    net: Arc<NetStats>,
    /// Context mode for all requests.
    pub mode: ContextMode,
    /// Target model.
    pub model: String,
    user_id: Option<String>,
    session_id: Option<String>,
    turn: u64,
    history: Vec<Message>,
    max_tokens: Option<usize>,
}

impl Client {
    /// New client over the cluster endpoints with a mobility policy.
    pub fn connect(endpoints: Vec<(String, SocketAddr)>, policy: MobilityPolicy) -> Client {
        Client {
            endpoints,
            policy,
            link: LinkModel::ideal(),
            pools: HashMap::new(),
            transport: TransportConfig::default(),
            net: NetStats::new(),
            mode: ContextMode::Tokenized,
            model: "discedge/tiny-chat".into(),
            user_id: None,
            session_id: None,
            turn: 0,
            history: Vec::new(),
            max_tokens: None,
        }
    }

    /// Builder: client uplink model (e.g. [`LinkModel::mobile_uplink`]).
    pub fn with_link(mut self, link: LinkModel) -> Client {
        self.link = link;
        self
    }

    /// Builder: context mode.
    pub fn with_mode(mut self, mode: ContextMode) -> Client {
        self.mode = mode;
        self
    }

    /// Builder: model name.
    pub fn with_model(mut self, model: &str) -> Client {
        self.model = model.into();
        self
    }

    /// Builder: max tokens per response.
    pub fn with_max_tokens(mut self, n: usize) -> Client {
        self.max_tokens = Some(n);
        self
    }

    /// Builder: transport tuning (pool idle bound; `max_idle_per_peer =
    /// 0` reverts to a fresh connect per request — the A7 ablation
    /// baseline).
    pub fn with_transport(mut self, transport: TransportConfig) -> Client {
        self.transport = transport;
        self
    }

    /// Connection-lifecycle counters aggregated across this client's
    /// per-endpoint pools.
    pub fn net_stats(&self) -> &Arc<NetStats> {
        &self.net
    }

    /// Current turn counter (turns completed).
    pub fn turns_done(&self) -> u64 {
        self.turn
    }

    /// Session identifiers once assigned.
    pub fn session(&self) -> (Option<&str>, Option<&str>) {
        (self.user_id.as_deref(), self.session_id.as_deref())
    }

    /// Send the next turn.
    pub fn chat(&mut self, prompt: &str) -> Result<TurnResult> {
        let turn = self.turn + 1;
        let node_idx = self.policy.node_for_turn(turn);
        let (node_name, addr) = self
            .endpoints
            .get(node_idx)
            .cloned()
            .ok_or_else(|| Error::Config(format!("mobility chose node {node_idx}, none such")))?;

        let mut req = CompletionRequest::new(&self.model, prompt, turn, self.mode);
        req.user_id = self.user_id.clone();
        req.session_id = self.session_id.clone();
        req.max_tokens = self.max_tokens;
        if self.mode == ContextMode::ClientSide {
            req.messages = self.history.clone();
        }

        let link = self.link.clone();
        let transport = self.transport.clone();
        let net = self.net.clone();
        let pool = self.pools.entry(node_idx).or_insert_with(|| {
            // No transparent re-send: `/completion` is not replay-safe
            // (a duplicate of a committed turn trips the turn-counter
            // guard), so a failure on a stale socket surfaces as this
            // turn's error — the caller retries with the same counter,
            // exactly the seed's contract — while the dead socket is
            // discarded, so the *next* call reconnects instead of
            // wedging the endpoint forever.
            transport
                .pool(TrafficMeter::new(), link, net)
                .without_stale_retry()
        });
        let meter = pool.meter().clone();

        let tx0 = meter.tx.get();
        let rx0 = meter.rx.get();
        let t = Instant::now();
        let (http_resp, ttft_s) = {
            let mut conn = pool.checkout(addr)?;
            conn.round_trip_ttft(&Request::post_json("/completion", &req.to_json()))?
        };
        let e2e_s = t.elapsed().as_secs_f64();
        if http_resp.status != 200 {
            return Err(Error::Http(format!(
                "node {node_name} returned {}: {}",
                http_resp.status,
                http_resp.body_str().unwrap_or("?")
            )));
        }
        let response = CompletionResponse::from_json(http_resp.body_str()?)?;

        // Commit client state only on success (failed turns are retried by
        // the caller with the same counter — the client stays the source
        // of truth for the interaction sequence).
        self.turn = turn;
        self.user_id = Some(response.user_id.clone());
        self.session_id = Some(response.session_id.clone());
        if self.mode == ContextMode::ClientSide {
            self.history.push(Message::new("user", prompt));
            self.history
                .push(Message::new("assistant", &response.text));
        }

        Ok(TurnResult {
            e2e_s,
            ttft_s,
            request_bytes: meter.tx.get() - tx0,
            response_bytes: meter.rx.get() - rx0,
            node: node_name,
            response,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_schedule_switches_on_3_5_7() {
        let p = MobilityPolicy::paper_alternate();
        let nodes: Vec<usize> = (1..=9).map(|t| p.node_for_turn(t)).collect();
        assert_eq!(nodes, vec![0, 0, 1, 1, 0, 0, 1, 1, 1]);
        // Switch turns are exactly 3, 5, 7.
        let switches: Vec<u64> = (2..=9)
            .filter(|&t| p.node_for_turn(t) != p.node_for_turn(t - 1))
            .collect();
        assert_eq!(switches, vec![3, 5, 7]);
    }

    #[test]
    fn alternate_policy() {
        let p = MobilityPolicy::Alternate {
            nodes: vec![0, 1],
            every: 2,
        };
        let nodes: Vec<usize> = (1..=8).map(|t| p.node_for_turn(t)).collect();
        assert_eq!(nodes, vec![0, 0, 1, 1, 0, 0, 1, 1]);
    }

    #[test]
    fn sticky_policy() {
        let p = MobilityPolicy::Sticky(1);
        assert_eq!(p.node_for_turn(1), 1);
        assert_eq!(p.node_for_turn(99), 1);
    }

    #[test]
    fn schedule_clamps_past_end() {
        let p = MobilityPolicy::Schedule(vec![0, 1]);
        assert_eq!(p.node_for_turn(1), 0);
        assert_eq!(p.node_for_turn(2), 1);
        assert_eq!(p.node_for_turn(10), 1);
    }
}
